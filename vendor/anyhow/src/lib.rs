//! Vendored offline subset of the `anyhow` crate.
//!
//! The build environment has no network access, so this shim provides the
//! slice of anyhow's API the repo actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, [`Error::msg`], and the [`Context`]
//! extension trait.  Error values are flat strings — context is folded
//! into the message (`"context: cause"`), which is what both the `{}` and
//! `{:#}` call sites here expect to read.

use std::fmt;

/// A string-backed error value (no backtrace, no downcasting).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Real anyhow renders the cause chain under `{:#}`; the shim's
        // chain is already folded into one message, so both forms match.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversions from std error types (io::Error etc.).  `Error` itself
// deliberately does NOT implement `std::error::Error`, exactly like real
// anyhow, so this blanket impl cannot collide with the reflexive
// `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e2 = anyhow!("bad {}: {}", "k", 7);
        assert_eq!(e2.to_string(), "bad k: 7");

        let r: Result<(), String> = Err("inner".into());
        let c = r.context("outer").unwrap_err();
        assert_eq!(c.to_string(), "outer: inner");

        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_returns() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }
}
