//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The real bindings need the xla_extension C++ archive, which is not
//! available in the offline build environment.  This stub keeps the crate
//! API-compatible with every call site in `pro_prophet`:
//!
//! * [`Literal`] is FULLY functional host-side (typed storage + shape) —
//!   the runtime's literal construction/extraction helpers and their unit
//!   tests run for real.
//! * The PJRT execution surface ([`PjRtClient::compile`],
//!   [`HloModuleProto::from_text_file`], [`PjRtLoadedExecutable::execute`])
//!   returns a clear "PJRT unavailable" error at run time.  Callers
//!   already gate on artifact availability, so tests skip rather than
//!   fail.
//!
//! Swapping the real bindings back in is a one-line change in the root
//! Cargo.toml (point the `xla` dependency at the registry crate).

use std::borrow::Borrow;
use std::fmt;

const STUB_MSG: &str =
    "PJRT unavailable: built against the offline xla stub (vendor/xla)";

/// Error type matching the `Display` usage of the real crate's error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// --- literals ---------------------------------------------------------------

/// Typed element storage for [`Literal`].  Public only because it appears
/// in the [`NativeType`] trait signature; not part of the stable surface.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {
    fn wrap(v: Vec<Self>) -> Data;
    fn slice(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn slice(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn slice(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

/// A host tensor: typed flat storage plus a shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { dims: vec![], data: T::wrap(vec![x]) }
    }

    /// Reinterpret the flat data under a new shape.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: shape {:?} wants {want} elements, literal has {}",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Flat element vector (errors on element-type mismatch).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// First element (errors on type mismatch or empty literal).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("literal is empty or type mismatch".into()))
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Decompose a tuple literal.  Stub literals are never tuples (they
    /// only come from [`PjRtLoadedExecutable::execute`], which is
    /// unavailable), so this is always an error.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(STUB_MSG.into()))
    }
}

// --- PJRT surface (unavailable in the stub) ---------------------------------

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!("{STUB_MSG}; cannot parse {path}")))
    }
}

/// Computation handle (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host "device" client.  Construction succeeds (so `info`-style probes
/// can report the platform); compilation and execution do not.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "host-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_and_i32() {
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
        assert_eq!(Literal::scalar(2.5f32).get_first_element::<f32>().unwrap(), 2.5);
        let l = Literal::vec1(&[5i32, -3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -3]);
    }

    #[test]
    fn pjrt_surface_is_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "host-stub");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }
}
