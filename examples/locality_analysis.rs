//! Locality analysis (paper §II, Fig 3 + Fig 4) on either a synthetic
//! trace or REAL gate loads captured from training.
//!
//!   cargo run --release --example locality_analysis            # synthetic
//!   cargo run --release --example locality_analysis -- --real  # train tiny
//!                                                              # model first
//!
//! Reports per-layer skew (top-3 share), adjacent-iteration similarity,
//! and what those statistics mean for the planner's replan interval.

use pro_prophet::config::TrainingConfig;
use pro_prophet::planner::locality::similarity;
use pro_prophet::runtime;
use pro_prophet::trainer::Trainer;
use pro_prophet::util::cli::Args;
use pro_prophet::util::stats;
use pro_prophet::workload::{top_share, Trace, WorkloadConfig, WorkloadGen};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["real"]).map_err(anyhow::Error::msg)?;

    let (trace, source) = if args.flag("real") {
        if !runtime::artifacts_available("tiny") {
            anyhow::bail!("run `make artifacts` first for --real");
        }
        let steps = args.usize_or("steps", 40);
        println!("training tiny model for {steps} steps to capture real gate loads...");
        let mut trainer = Trainer::new(TrainingConfig {
            preset: "tiny".into(),
            seed: 3,
            ..Default::default()
        })?;
        let report = trainer.run(steps, |_| {})?;
        let e = trainer.manifest.n_experts;
        (report.to_trace(e), "real (tiny model gate)")
    } else {
        let mut gen =
            WorkloadGen::new(WorkloadConfig::paper_default(12, 16, 16, 16384));
        (Trace::capture(&mut gen, 40), "synthetic (paper-calibrated)")
    };

    println!("\n== locality analysis over {} iterations [{source}] ==", trace.len());

    // Fig 3: skew per layer at a fixed iteration.
    println!("\nskew (top-3 expert share per layer, iteration 1):");
    for (l, w) in trace.iterations[1].iter().enumerate() {
        let share = top_share(&w.distribution(), 3);
        let bar: String =
            std::iter::repeat('#').take((share * 40.0) as usize).collect();
        println!("  layer {l:>2} {bar} {:.1}%", share * 100.0);
    }

    // Fig 4: adjacent-iteration similarity per layer.
    println!("\nadjacent-iteration similarity per layer (mean / min):");
    let mut all_sims = Vec::new();
    for l in 0..trace.n_layers {
        let mut sims = Vec::new();
        for it in 1..trace.len() {
            sims.push(similarity(
                &trace.iterations[it - 1][l].distribution(),
                &trace.iterations[it][l].distribution(),
            ));
        }
        println!(
            "  layer {l:>2}: {:.4} / {:.4}",
            stats::mean(&sims),
            stats::min(&sims)
        );
        all_sims.extend(sims);
    }
    let mean_sim = stats::mean(&all_sims);
    println!("\noverall mean similarity: {mean_sim:.4}");

    // What this buys the planner: replan every 1/(1-sim) iterations keeps
    // placements fresh relative to drift.
    let suggested = (1.0 / (1.0 - mean_sim).max(0.01)).floor().clamp(1.0, 50.0);
    println!(
        "suggested planner replan interval (locality-based): every {suggested:.0} iterations"
    );
    Ok(())
}
