//! Quickstart: the Pro-Prophet public API in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Builds a 16-GPU HPWNV cluster model, samples one skewed MoE iteration,
//! runs the planner (Algorithm 1), prices the result with the performance
//! model (Eq 1-8), and compares a blocking vs block-wise schedule.

use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::moe::Placement;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::scheduler::{build_blocking, build_blockwise, LoadBalanceOps};
use pro_prophet::sim::Engine;
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn main() {
    // 1. A model (paper Table III) and a cluster (paper §VI testbed).
    let cluster = ClusterSpec::hpwnv(4); // 4 nodes x 4 RTX 3090
    let d = cluster.n_devices();
    let model = ModelSpec::moe_gpt_m(d, 1, 16384);
    let pm = PerfModel::new(&model, &cluster);

    // 2. One iteration of gate routing (skewed + local, like Fig 3/4).
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(
        model.n_layers,
        d,
        d,
        model.tokens_per_iter,
    ));
    let layers = gen.next_iteration();
    let w = &layers[0];
    println!("expert loads (layer 0): {:?}", w.distribution());

    // 3. Plan a lightweight expert placement (Algorithm 1).
    let result = greedy_search(w, &pm, &PlannerConfig::default());
    println!(
        "planner selected experts {:?}; replica counts {:?}",
        result.selected,
        result.placement.replica_counts()
    );
    println!(
        "modeled layer time: {:.3} ms -> {:.3} ms",
        result.t_identity * 1e3,
        result.t_est * 1e3
    );

    // 4. Price a whole iteration on the discrete-event engine and compare
    //    schedules (blocking vs the paper's block-wise overlap).
    let eng = Engine::new(&cluster, &pm);
    let ident = Placement::identity(d, d);
    let baseline: Vec<_> = layers.iter().map(|w| eng.block_costs(w, &ident, 0.0)).collect();
    let planned: Vec<_> = layers
        .iter()
        .map(|w| {
            let p = greedy_search(w, &pm, &PlannerConfig::default()).placement;
            eng.block_costs(w, &p, pm.t_plan)
        })
        .collect();
    let t_deepspeed = build_blocking(&baseline, LoadBalanceOps::None).total_time();
    let t_blocking = build_blocking(&planned, LoadBalanceOps::Blocking).total_time();
    let t_prophet = build_blockwise(&planned).total_time();
    println!("\niteration time, {} layers on {}:", layers.len(), cluster.name);
    println!("  pure EP (Deepspeed-MoE)     {:.2} ms", t_deepspeed * 1e3);
    println!("  planned, blocking           {:.2} ms", t_blocking * 1e3);
    println!(
        "  planned + block-wise overlap {:.2} ms   ({:.2}x vs pure EP)",
        t_prophet * 1e3,
        t_deepspeed / t_prophet
    );
}
