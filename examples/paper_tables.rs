//! Regenerate the paper's headline numbers in one shot (condensed; the
//! full per-table harnesses live in rust/benches/, one per table/figure).
//!
//!   cargo run --release --example paper_tables

use pro_prophet::benchkit::scenario;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::TableReport;

fn main() {
    println!("Pro-Prophet — condensed paper reproduction (see cargo bench for full set)\n");

    // Headline: Fig 10a (16 GPUs HPWNV, k=1).
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut t = TableReport::new(
        "Fig 10a — speedup vs Deepspeed-MoE (16 GPUs HPWNV, k=1)",
        &["FasterMoE", "Pro-Prophet"],
    );
    for model in ModelSpec::table3(d, 1, 16384) {
        let (s_fm, s_pp) = scenario::speedup_row(&model, &cluster, 8, 42);
        t.row(&model.name, vec![s_fm, s_pp]);
    }
    println!("{}", t.render());

    // Table I condensed: FasterMoE LB overhead.
    let model = ModelSpec::moe_gpt_m(d, 1, 16384);
    let trace = scenario::trace_for(&model, d, 8, 42);
    let fm = scenario::report_for("fastermoe", &model, &cluster, &trace);
    println!(
        "Table I (MoE-GPT-M): FasterMoE-style LB overhead = {:.1}% of iteration (paper 29-37%)\n",
        100.0 * fm.lb_fraction()
    );

    // Table IV/V condensed.
    for (name, cluster, tokens) in [
        ("Table IV (HPNV, 16 GPUs)", ClusterSpec::hpnv(4), 16384u64),
        ("Table V (LPWNV, 8 GPUs)", ClusterSpec::lpwnv(2), 4096),
    ] {
        let d = cluster.n_devices();
        let model = ModelSpec::moe_gpt_s(d, 1, tokens);
        let (s_fm, s_pp) = scenario::speedup_row(&model, &cluster, 8, 7);
        println!(
            "{name}: MoE-GPT-S k=1 — FasterMoE {s_fm:.2}x, Pro-Prophet {s_pp:.2}x vs Deepspeed-MoE"
        );
    }
    println!("\nDone. Full tables: cargo bench  (results under bench_results/)");
}
