//! Expert-parallel demo: a leader + virtual-device workers execute REAL
//! routed tokens through the AOT'd Pallas expert-FFN kernel, comparing
//! the traditional placement against the Pro-Prophet planner's placement.
//!
//!   make artifacts
//!   cargo run --release --example ep_demo -- [--preset tiny] [--iters 5]
//!
//! Each worker owns its own PJRT client and compiled executable; mpsc
//! channels play the interconnect (tokio is unavailable offline).  Watch
//! the per-device token queue flatten when the planner's placement is
//! applied.

use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::coordinator::{extract_expert_weights, EpCluster};
use pro_prophet::moe::{LoadMatrix, Placement};
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::runtime::{self, Runtime};
use pro_prophet::util::cli::Args;
use pro_prophet::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "tiny");
    let iters = args.usize_or("iters", 5);

    let rt = Runtime::cpu()?;
    let man = runtime::load_manifest(&preset)?;
    println!(
        "== EP demo: {} experts on {} virtual devices, d_model {} ==",
        man.n_experts, man.n_experts, man.d_model
    );

    // Real expert weights from the init artifact (layer 0).
    let init = rt.load_tagged(&man, "init")?;
    let state = init.run(&[runtime::i32_scalar(7)])?;
    let weights = extract_expert_weights(&man, &state, 0)?;
    let cluster = EpCluster::new(man.clone(), weights)?;

    let e = man.n_experts;
    let t = man.tokens_per_step;
    let d_model = man.d_model;
    let mut rng = Rng::new(11);

    // Skewed routing like Fig 3: ~55% of tokens to one hot expert.
    let x: Vec<f32> = (0..t * d_model).map(|_| rng.normal() as f32 * 0.3).collect();
    let assignment: Vec<usize> = (0..t)
        .map(|i| if rng.f64() < 0.55 { 0 } else { 1 + (i % (e - 1)) })
        .collect();

    // Plan with the real load matrix (single source device pool split
    // round-robin over virtual devices).  The demo batch is tiny, so the
    // matrix is scaled to a production-iteration magnitude for the
    // cost/benefit analysis — the placement depends on the *relative*
    // skew, which is what the demo routing then applies.
    const SCALE: u64 = 512;
    let mut w = LoadMatrix::zeros(e, e);
    for (i, &ex) in assignment.iter().enumerate() {
        w.add(i % e, ex, SCALE);
    }
    let model = ModelSpec::new(
        "demo", 1, man.d_model, man.d_ff, e, man.k, t as u64 * SCALE,
    );
    let pm = PerfModel::new(&model, &ClusterSpec::hpwnv(e.div_ceil(4).max(1)));
    let planned = greedy_search(&w, &pm, &PlannerConfig::default()).placement;
    let identity = Placement::identity(e, e);

    println!("\nexpert loads: {:?}", w.distribution());
    println!("planner replica counts: {:?}", planned.replica_counts());

    for (name, placement) in [("traditional EP", &identity), ("Pro-Prophet", &planned)] {
        let mut busy_imbalance = 0.0;
        let mut max_tokens = 0u64;
        let mut wall = 0.0;
        let mut reference: Option<Vec<f32>> = None;
        for _ in 0..iters {
            let r = cluster.run_iteration(&x, &assignment, placement)?;
            busy_imbalance += r.imbalance;
            max_tokens = max_tokens.max(*r.per_device_tokens.iter().max().unwrap());
            wall += r.wall_seconds;
            match &reference {
                None => reference = Some(r.output),
                Some(prev) => {
                    let err = prev
                        .iter()
                        .zip(&r.output)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(err < 1e-4, "nondeterministic outputs: {err}");
                }
            }
        }
        println!(
            "\n{name}: max device queue {max_tokens} tokens, busy imbalance {:.2}x, {:.3}s/iter",
            busy_imbalance / iters as f64,
            wall / iters as f64
        );
    }

    // Cross-placement numerics must agree exactly (placement only moves
    // work, never changes results).
    let out_ident = cluster.run_iteration(&x, &assignment, &identity)?.output;
    let out_plan = cluster.run_iteration(&x, &assignment, &planned)?.output;
    let max_err = out_ident
        .iter()
        .zip(&out_plan)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\nnumerics identical across placements: max |diff| = {max_err:.2e}");
    assert!(max_err < 1e-4);

    cluster.shutdown();
    println!("ep_demo OK");
    Ok(())
}
