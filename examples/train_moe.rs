//! END-TO-END DRIVER (the repo's headline validation): train a MoE-GPT
//! through the full three-layer stack and feed its REAL gate statistics to
//! the Pro-Prophet planner + cluster simulator.
//!
//!   make artifacts                         # once (python, build time)
//!   cargo run --release --example train_moe -- [--preset e2e] [--steps 300]
//!
//! What happens:
//!   L1/L2  the AOT'd JAX model (Pallas expert-FFN + gate kernels inside)
//!          executes on the PJRT CPU client — python is NOT running;
//!   L3     this binary owns the training loop: synthetic Markov corpus,
//!          fused fwd+bwd+Adam step, loss curve;
//!   then   the observed per-layer expert loads become a workload trace,
//!          and the simulator prices Deepspeed-MoE / FasterMoE /
//!          Pro-Prophet on the paper's HPWNV cluster for that REAL trace.
//!
//! Results are recorded in EXPERIMENTS.md ("End-to-end validation").

use pro_prophet::balancer::{registry, ProphetOptions};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::{ModelSpec, TrainingConfig};
use pro_prophet::metrics::{balance_degree, write_result};
use pro_prophet::sim::simulate_policy;
use pro_prophet::trainer::Trainer;
use pro_prophet::util::cli::Args;
use pro_prophet::util::json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "e2e");
    let steps = args.usize_or("steps", 300);
    let seed = args.u64_or("seed", 42);

    let cfg = TrainingConfig { preset: preset.clone(), steps, seed, ..Default::default() };
    println!("== Pro-Prophet end-to-end driver ==");
    let mut trainer = Trainer::new(cfg)?;
    let man = trainer.manifest.clone();
    println!(
        "model: {} layers x (attn + MoE[{} experts, k={}]), d_model {}, {:.1}M params",
        man.n_layers,
        man.n_experts,
        man.k,
        man.d_model,
        man.num_params as f64 / 1e6
    );
    println!(
        "corpus: synthetic Markov chain over {} tokens; {} tokens/step",
        man.vocab, man.tokens_per_step
    );

    // ---- phase 1: real training through the AOT artifacts ----
    let t0 = std::time::Instant::now();
    let report = trainer.run(steps, |r| {
        if r.step == 1 || r.step % 20 == 0 {
            println!(
                "step {:>5}  loss {:.4}   ({:.2}s/step)",
                r.step, r.loss, r.seconds
            );
        }
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nloss: {:.4} -> {:.4} (tail mean {:.4}) over {} steps, {:.1}s total ({:.2}s/step)",
        report.initial_loss(),
        report.final_loss(),
        report.mean_loss_tail(20),
        steps,
        wall,
        report.mean_step_seconds()
    );

    // ---- phase 2: the real gate loads drive the L3 system ----
    // Pretend the same model trains with EP on the paper's default
    // testbed: 16 GPUs across 4 HPWNV nodes (experts = devices).
    let cluster = ClusterSpec::hpwnv(man.n_experts.div_ceil(4).max(1));
    let d = man.n_experts;
    let trace = report.to_trace(d);
    let model = ModelSpec::new(
        &format!("{preset}-real"),
        man.n_layers,
        man.d_model,
        man.d_ff,
        man.n_experts,
        man.k,
        (man.tokens_per_step * man.k) as u64,
    );
    println!(
        "\n== replaying {} real iterations on simulated {} ({} devices) ==",
        trace.len(),
        cluster.name,
        d
    );

    let opts = ProphetOptions::full();
    let policy = |name: &str| registry::build(name, &opts).expect("registered policy");
    let ds = simulate_policy(&model, &cluster, &trace, policy("deepspeed"));
    let fm = simulate_policy(&model, &cluster, &trace, policy("fastermoe"));
    let pp = simulate_policy(&model, &cluster, &trace, policy("pro-prophet"));
    println!("avg iteration time (s):");
    println!("  Deepspeed-MoE  {:.6}", ds.avg_iter_time());
    println!("  FasterMoE      {:.6}", fm.avg_iter_time());
    println!(
        "  Pro-Prophet    {:.6}   ({:.2}x vs DS, {:.2}x vs FM)",
        pp.avg_iter_time(),
        ds.avg_iter_time() / pp.avg_iter_time(),
        fm.avg_iter_time() / pp.avg_iter_time()
    );
    println!(
        "balance degree (mean std of device load): {:.1} -> {:.1} (RB {:.2}x)",
        pp.iters.iter().map(|i| i.balance_before).sum::<f64>() / pp.iters.len() as f64,
        pp.iters.iter().map(|i| i.balance_after).sum::<f64>() / pp.iters.len() as f64,
        pp.mean_rb()
    );

    // Last-step per-layer balance snapshot from REAL loads.
    if let Some(last) = report.loads.last() {
        println!("\nreal per-layer expert loads at step {steps} (std in tokens):");
        for (l, hist) in last.iter().enumerate() {
            println!(
                "  layer {l}: max {:>5} min {:>5} std {:>7.1}",
                hist.iter().max().unwrap(),
                hist.iter().min().unwrap(),
                balance_degree(hist)
            );
        }
    }

    let out = json::obj(vec![
        ("train", report.to_json()),
        (
            "sim",
            json::obj(vec![
                ("deepspeed_s", json::num(ds.avg_iter_time())),
                ("fastermoe_s", json::num(fm.avg_iter_time())),
                ("prophet_s", json::num(pp.avg_iter_time())),
                ("rb", json::num(pp.mean_rb())),
            ]),
        ),
    ]);
    let path = write_result(&format!("train_moe_{preset}"), &out)?;
    println!("\nreport -> {}", path.display());
    Ok(())
}
