"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes, seeds, block sizes and activation; every property
asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, moe_ffn, ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    block=st.sampled_from([8, 16, 32]),
    act=st.sampled_from(["none", "gelu", "relu"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_bias_act_matches_ref(m, k, n, block, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = moe_ffn.matmul_bias_act(
        x, w, b, act=act, block_m=block, block_n=block, block_k=block
    )
    want = ref.matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))  # inner mismatch
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        moe_ffn.matmul_bias_act(x, w, b)


def test_matmul_rejects_bad_act():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((5, 7))
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        moe_ffn.matmul_bias_act(x, w, b, act="swish")


def test_matmul_block_shape_invariance():
    """Same numerics no matter how the GEMM is tiled."""
    x, w, b = _rand(0, (65, 33)), _rand(1, (33, 47)), _rand(2, (47,))
    outs = [
        moe_ffn.matmul_bias_act(
            x, w, b, act="gelu", block_m=bm, block_n=bn, block_k=bk
        )
        for bm, bn, bk in [(8, 8, 8), (16, 32, 8), (128, 128, 128), (64, 16, 32)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(2, 40),
    k=st.integers(2, 24),
    n=st.integers(2, 24),
    act=st.sampled_from(["none", "gelu", "relu"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_gradients_match_ref(m, k, n, act, seed):
    """The custom VJP (pallas backward) equals autodiff of the jnp oracle."""
    x = _rand(seed, (m, k), 0.5)
    w = _rand(seed + 1, (k, n), 0.5)
    b = _rand(seed + 2, (n,), 0.5)

    def f_kernel(x, w, b):
        return jnp.sum(
            moe_ffn.matmul_bias_act(
                x, w, b, act=act, block_m=16, block_n=16, block_k=16
            )
            ** 2
        )

    def f_ref(x, w, b):
        return jnp.sum(ref.matmul_bias_act_ref(x, w, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# expert_ffn
# ---------------------------------------------------------------------------
@given(
    t=st.integers(1, 50),
    d=st.integers(2, 32),
    f=st.integers(2, 48),
    seed=st.integers(0, 2**16),
)
def test_expert_ffn_matches_ref(t, d, f, seed):
    x = _rand(seed, (t, d))
    w1, b1 = _rand(seed + 1, (d, f), 0.3), _rand(seed + 2, (f,), 0.1)
    w2, b2 = _rand(seed + 3, (f, d), 0.3), _rand(seed + 4, (d,), 0.1)
    got = moe_ffn.expert_ffn(x, w1, b1, w2, b2, block_m=16, block_n=16, block_k=16)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_expert_ffn_vmap_over_experts():
    e, c, d, f = 4, 24, 16, 32
    xs = _rand(0, (e, c, d))
    w1, b1 = _rand(1, (e, d, f), 0.3), _rand(2, (e, f), 0.1)
    w2, b2 = _rand(3, (e, f, d), 0.3), _rand(4, (e, d), 0.1)
    fn = jax.vmap(
        lambda x, a, b, c_, dd: moe_ffn.expert_ffn(
            x, a, b, c_, dd, block_m=8, block_n=8, block_k=8
        )
    )
    got = fn(xs, w1, b1, w2, b2)
    want = jax.vmap(ref.expert_ffn_ref)(xs, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# gating
# ---------------------------------------------------------------------------
@given(
    t=st.integers(1, 80),
    e=st.sampled_from([2, 4, 8, 16]),
    k=st.integers(1, 3),
    block_t=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_topk_gate_matches_ref(t, e, k, block_t, seed):
    k = min(k, e)
    logits = _rand(seed, (t, e), 2.0)
    p, i, w = gating.topk_gate(logits, k=k, block_t=block_t)
    pr, ir, wr = ref.topk_gate_ref(logits, k)
    np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
    # Ties can legitimately order differently; compare selected probs.
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(p), np.asarray(i), 1),
        np.take_along_axis(np.asarray(pr), np.asarray(ir), 1),
        rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(w, wr, rtol=1e-5, atol=1e-6)


def test_topk_gate_weights_sum_to_one():
    logits = _rand(7, (33, 8), 3.0)
    _, _, w = gating.topk_gate(logits, k=2)
    np.testing.assert_allclose(np.asarray(w).sum(1), np.ones(33), rtol=1e-5)


def test_topk_gate_k_equals_e():
    logits = _rand(3, (17, 4))
    p, i, w = gating.topk_gate(logits, k=4)
    assert sorted(np.asarray(i)[0].tolist()) == [0, 1, 2, 3]
    np.testing.assert_allclose(np.asarray(w).sum(1), np.ones(17), rtol=1e-5)


def test_topk_gate_rejects_bad_k():
    logits = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        gating.topk_gate(logits, k=0)
    with pytest.raises(ValueError):
        gating.topk_gate(logits, k=5)


def test_gate_decision_zero_gradient():
    logits = _rand(11, (12, 4), 2.0)

    def f(lg):
        idx = gating.topk_gate_decision(lg, 2)
        return jnp.sum(idx.astype(jnp.float32))

    g = jax.grad(f)(logits)
    np.testing.assert_allclose(g, np.zeros_like(g))


@given(t=st.integers(1, 60), e=st.sampled_from([4, 8]), seed=st.integers(0, 999))
def test_expert_load_counts(t, e, seed):
    logits = _rand(seed, (t, e))
    _, idx, _ = gating.topk_gate(logits, k=2)
    load = gating.expert_load(idx, e)
    assert float(np.asarray(load).sum()) == 2 * t
    np.testing.assert_allclose(load, ref.expert_load_ref(idx, e))


# ---------------------------------------------------------------------------
# dispatch/combine oracle self-consistency (used directly by the model)
# ---------------------------------------------------------------------------
@given(
    t=st.integers(4, 40),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 999),
)
def test_dispatch_combine_roundtrip_identity_expert(t, e, k, seed):
    """With identity experts and capacity >= T, combine(dispatch(x)) == x
    scaled by the (renormalized) gate weights summing to 1."""
    d = 8
    x = _rand(seed, (t, d))
    logits = _rand(seed + 1, (t, e), 2.0)
    _, idx, w = ref.topk_gate_ref(logits, k)
    inputs, combine = ref.dispatch_combine_ref(x, idx, w, e, capacity=t * k)
    out = combine(inputs)
    np.testing.assert_allclose(out, x, rtol=1e-4, atol=1e-5)


def test_dispatch_capacity_drops_tokens():
    """Tokens beyond expert capacity are dropped (output rows go to 0)."""
    t, d, e = 16, 4, 2
    x = jnp.ones((t, d))
    idx = jnp.zeros((t, 1), jnp.int32)  # everyone picks expert 0
    w = jnp.ones((t, 1))
    inputs, combine = ref.dispatch_combine_ref(x, idx, w, e, capacity=4)
    out = np.asarray(combine(inputs))
    kept = (np.abs(out).sum(1) > 0).sum()
    assert kept == 4


# ---------------------------------------------------------------------------
# VMEM / MXU structural estimates (perf deliverable sanity)
# ---------------------------------------------------------------------------
def test_vmem_budget_of_default_blocks():
    bytes_ = moe_ffn.vmem_bytes_per_step(128, 128, 128)
    assert bytes_ < 8 * 1024 * 1024  # far under a 16 MiB VMEM core


def test_mxu_estimate_full_tiles():
    assert moe_ffn.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert moe_ffn.mxu_utilization_estimate(64, 128, 128) == 0.5
