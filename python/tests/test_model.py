"""L2 model correctness: shapes, determinism, training signal, pallas-vs-ref.

The pallas path and the pure-jnp path of the model must agree exactly (same
routing, same FFN numerics), and the fused Adam step must actually learn.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


def _state(cfg, seed=7):
    state = M.init_state(cfg, jnp.int32(seed))
    n = cfg.num_tensors
    return list(state[:n]), list(state[n : 2 * n]), list(state[2 * n : 3 * n])


def _tokens(cfg, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab
    )


def test_param_specs_shapes_and_count():
    specs = CFG.param_specs()
    assert len(specs) == CFG.num_tensors
    assert specs[0] == ("tok_emb", (CFG.vocab, CFG.d_model))
    assert specs[-1] == ("lnf_bias", (CFG.d_model,))
    # 13 tensors per layer with the documented stride.
    assert specs[2][0] == "l0.ln1_scale"
    assert specs[2 + M.LAYER_STRIDE][0] == "l1.ln1_scale"


def test_init_deterministic_and_seed_sensitive():
    p1, _, _ = _state(CFG, seed=1)
    p2, _, _ = _state(CFG, seed=1)
    p3, _, _ = _state(CFG, seed=2)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert any(
        not np.array_equal(a, b) for a, b in zip(p1, p3)
    ), "different seeds must give different params"


def test_init_state_zero_moments():
    state = M.init_state(CFG, jnp.int32(3))
    n = CFG.num_tensors
    for t in state[n : 3 * n]:
        assert float(jnp.abs(t).max()) == 0.0


def test_forward_shapes_and_load_conservation():
    params, _, _ = _state(CFG)
    loss, loads = M.forward(CFG, params, _tokens(CFG))
    assert loss.shape == ()
    assert loads.shape == (CFG.n_layers, CFG.n_experts)
    # Every (token, choice) lands on exactly one expert, pre-capacity.
    expect = CFG.tokens_per_step * CFG.k
    np.testing.assert_allclose(np.asarray(loads).sum(1), expect * np.ones(CFG.n_layers))


def test_pallas_and_ref_paths_agree():
    cfg_ref = dataclasses.replace(CFG, use_pallas=False)
    params, _, _ = _state(CFG)
    toks = _tokens(CFG, 5)
    loss_p, loads_p = M.forward(CFG, params, toks)
    loss_r, loads_r = M.forward(cfg_ref, params, toks)
    np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-4)
    np.testing.assert_allclose(loads_p, loads_r)


def test_train_step_learns_structured_data():
    """On a deterministic repeating sequence the LM must drop well below
    the uniform-entropy floor within a few dozen steps."""
    cfg = CFG
    params, m, v = _state(cfg, seed=11)
    step_fn = jax.jit(lambda p, m, v, s, t: M.train_step(cfg, p, m, v, s, t))
    # tokens cycle 0,1,2,...: next-token is fully predictable.
    base = jnp.arange(cfg.seq_len, dtype=jnp.int32) % cfg.vocab
    toks = jnp.tile(base[None, :], (cfg.batch, 1))
    n = cfg.num_tensors
    losses = []
    for i in range(60):
        out = step_fn(params, m, v, jnp.float32(i + 1), toks)
        params, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        losses.append(float(out[3 * n]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_train_step_output_arity():
    cfg = CFG
    params, m, v = _state(cfg)
    out = M.train_step(cfg, params, m, v, jnp.float32(1), _tokens(cfg))
    n = cfg.num_tensors
    assert len(out) == 3 * n + 2
    assert out[3 * n].shape == ()  # loss
    assert out[3 * n + 1].shape == (cfg.n_layers, cfg.n_experts)


def test_train_step_preserves_shapes():
    cfg = CFG
    params, m, v = _state(cfg)
    out = M.train_step(cfg, params, m, v, jnp.float32(1), _tokens(cfg))
    for got, (name, shape) in zip(out, cfg.param_specs()):
        assert got.shape == shape, name


def test_eval_step_matches_forward():
    params, _, _ = _state(CFG)
    toks = _tokens(CFG, 9)
    l1, d1 = M.eval_step(CFG, params, toks)
    l2, d2 = M.forward(CFG, params, toks)
    np.testing.assert_allclose(float(l1), float(l2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_capacity_property():
    cfg = M.ModelConfig(batch=8, seq_len=16, n_experts=4, k=2, capacity_factor=1.0)
    # k*T/E = 2*128/4 = 64
    assert cfg.capacity == 64


def test_gate_only_consistency():
    """gate_only must agree with the routing the full model performs."""
    cfg = CFG
    params, _, _ = _state(cfg)
    t, d = cfg.tokens_per_step, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    gate_w = params[2 + 8]  # l0.gate_w
    idx, w, load = M.gate_only(cfg, x, gate_w)
    assert idx.shape == (t, cfg.k)
    assert float(np.asarray(load).sum()) == t * cfg.k
    np.testing.assert_allclose(np.asarray(w).sum(1), np.ones(t), rtol=1e-5)


def test_single_expert_ffn_matches_ref():
    from compile.kernels import ref as R

    cfg = CFG
    c, d, f = cfg.capacity, cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (c, d))
    w1 = 0.2 * jax.random.normal(ks[1], (d, f))
    b1 = jnp.zeros((f,))
    w2 = 0.2 * jax.random.normal(ks[2], (f, d))
    b2 = jnp.zeros((d,))
    got = M.single_expert_ffn(cfg, x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, R.expert_ffn_ref(x, w1, b1, w2, b2),
                               rtol=5e-4, atol=5e-4)


def test_presets_well_formed():
    for name, cfg in M.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert 1 <= cfg.k <= cfg.n_experts, name
        assert cfg.num_tensors == M.NUM_HEADER + 13 * cfg.n_layers + M.NUM_FOOTER


def test_e2e_preset_param_count():
    cfg = M.PRESETS["e2e"]
    total = cfg.num_params
    assert 20_000_000 < total < 40_000_000, total
