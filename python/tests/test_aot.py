"""AOT path: lowering produces parseable HLO text with the right interface.

These tests re-lower the tiny preset in-process (fast) and sanity-check the
artifacts `make artifacts` ships to the rust runtime.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def hlo_texts():
    return {
        "expert_ffn": aot.lower_expert_ffn(CFG),
        "gate": aot.lower_gate(CFG),
        "init": aot.lower_init(CFG),
    }


def test_hlo_text_has_entry(hlo_texts):
    for tag, text in hlo_texts.items():
        assert "ENTRY" in text, tag
        assert "HloModule" in text, tag


def test_hlo_is_plain_hlo_no_mosaic(hlo_texts):
    """interpret=True must lower pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the CPU PJRT plugin."""
    for tag, text in hlo_texts.items():
        assert "mosaic" not in text.lower(), tag


def test_expert_ffn_parameter_arity(hlo_texts):
    # x, w1, b1, w2, b2 = 5 parameters
    entry = hlo_texts["expert_ffn"][hlo_texts["expert_ffn"].index("ENTRY") :]
    assert "parameter(4)" in entry and "parameter(5)" not in entry


def test_init_roundtrip_values():
    """Executing the lowered init on the python side matches eager init."""
    text = aot.lower_init(CFG)
    # The text itself is executed by rust integration tests; here we check
    # the eager function (the AOT source of truth) for layout invariants.
    state = M.init_state(CFG, jnp.int32(123))
    assert len(state) == 3 * CFG.num_tensors
    specs = CFG.param_specs()
    for arr, (_, shape) in zip(state[: CFG.num_tensors], specs):
        assert arr.shape == shape


def test_manifest_contents(tmp_path):
    arts = {"train_step": "tiny_train_step.hlo.txt"}
    man = aot.manifest(CFG, arts)
    assert man["config"]["n_experts"] == CFG.n_experts
    assert man["config"]["num_tensors"] == CFG.num_tensors
    assert len(man["tensors"]) == CFG.num_tensors
    # JSON-serializable end to end.
    json.dumps(man)


def test_shipped_artifacts_exist_if_built():
    """If `make artifacts` has run, the inventory must be complete."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art_dir, "tiny_manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as fh:
        man = json.load(fh)
    for tag, fname in man["artifacts"].items():
        assert os.path.exists(os.path.join(art_dir, fname)), tag
    assert man["config"]["num_tensors"] == CFG.num_tensors


def test_lowered_gate_matches_eager():
    """Round-trip the gate artifact through jax's own HLO runtime."""
    t, d, e = CFG.tokens_per_step, CFG.d_model, CFG.n_experts
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d), jnp.float32)
    gw = jax.random.normal(jax.random.PRNGKey(1), (d, e), jnp.float32)
    idx, w, load = M.gate_only(CFG, x, gw)
    assert float(np.asarray(load).sum()) == t * CFG.k
