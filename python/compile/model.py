"""Layer-2: MoE-GPT in JAX — forward/backward + Adam, calling the L1 kernels.

This is the build-time model definition.  ``aot.py`` lowers ``train_step``
(and friends) to HLO text once; the rust coordinator executes the artifacts
on its PJRT client and NEVER imports python.

Model family = the paper's Table III MoE-GPT variants: a GPT stack where
every FFN is replaced by a MoE layer (top-k gate + E experts), experts
per layer = #devices.

Parameters are carried as a FLAT LIST of arrays with a fixed documented
order (see ``param_specs``) so the AOT'd HLO has a flat, stable interface
the rust side can drive without a pytree library:

  [0] tok_emb (V, D)          token embedding, tied softmax head
  [1] pos_emb (S, D)          learned positions
  per layer l (13 tensors):
      ln1_scale (D,)  ln1_bias (D,)
      wq (D, D)  wk (D, D)  wv (D, D)  wo (D, D)
      ln2_scale (D,)  ln2_bias (D,)
      gate_w (D, E)
      w1 (E, D, F)  b1 (E, F)  w2 (E, F, D)  b2 (E, D)
  [-2] lnf_scale (D,)  [-1] lnf_bias (D,)

``train_step`` additionally returns the per-layer expert load histogram
(the "input distribution" of the paper) — this is how the L3 profiler
observes real routing statistics without touching python.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import gating, moe_ffn, ref

LAYER_STRIDE = 13
NUM_HEADER = 2  # tok_emb, pos_emb
NUM_FOOTER = 2  # lnf_scale, lnf_bias


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one MoE-GPT variant."""

    name: str = "tiny"
    vocab: int = 64
    seq_len: int = 16
    d_model: int = 32
    d_ff: int = 64
    n_layers: int = 2
    n_heads: int = 2
    n_experts: int = 4
    k: int = 2
    capacity_factor: float = 1.5
    batch: int = 4
    lr: float = 1e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    use_pallas: bool = True
    block_m: int = 128
    block_n: int = 128
    block_k: int = 128

    @property
    def tokens_per_step(self) -> int:
        return self.batch * self.seq_len

    @property
    def capacity(self) -> int:
        """Per-expert token capacity (Gshard-style), over the whole batch."""
        return max(
            1,
            int(
                math.ceil(
                    self.k * self.tokens_per_step * self.capacity_factor
                    / self.n_experts
                )
            ),
        )

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """(name, shape) for every tensor, in flat order."""
        d, f, e, s, v = (
            self.d_model, self.d_ff, self.n_experts, self.seq_len, self.vocab,
        )
        specs: List[Tuple[str, Tuple[int, ...]]] = [
            ("tok_emb", (v, d)),
            ("pos_emb", (s, d)),
        ]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.ln1_scale", (d,)),
                (f"l{l}.ln1_bias", (d,)),
                (f"l{l}.wq", (d, d)),
                (f"l{l}.wk", (d, d)),
                (f"l{l}.wv", (d, d)),
                (f"l{l}.wo", (d, d)),
                (f"l{l}.ln2_scale", (d,)),
                (f"l{l}.ln2_bias", (d,)),
                (f"l{l}.gate_w", (d, e)),
                (f"l{l}.w1", (e, d, f)),
                (f"l{l}.b1", (e, f)),
                (f"l{l}.w2", (e, f, d)),
                (f"l{l}.b2", (e, d)),
            ]
        specs += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
        return specs

    @property
    def num_tensors(self) -> int:
        return NUM_HEADER + LAYER_STRIDE * self.n_layers + NUM_FOOTER

    @property
    def num_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())


# Presets used throughout the repo.  "tiny" keeps pytest fast; "e2e" is the
# end-to-end training demo (~27M params — sized for a single CPU core, see
# DESIGN.md section 3).
PRESETS = {
    "tiny": ModelConfig(),
    "e2e": ModelConfig(
        name="e2e",
        vocab=1024,
        seq_len=128,
        d_model=256,
        d_ff=1024,
        n_layers=6,
        n_heads=8,
        n_experts=8,
        k=1,
        batch=4,
        lr=1e-3,
    ),
}


def init_params(cfg: ModelConfig, seed: jnp.ndarray) -> List[jnp.ndarray]:
    """Deterministic init from an int32 seed (AOT-friendly: seed is a
    runtime input, so one compiled init artifact serves any seed)."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    params: List[jnp.ndarray] = []
    for i, (name, shape) in enumerate(cfg.param_specs()):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.startswith("ln") or base == "b1" or base == "b2":
            if base.endswith("scale"):
                params.append(jnp.ones(shape, jnp.float32))
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if base in ("tok_emb", "pos_emb") else 1.0 / math.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layer_slice(params: Sequence[jnp.ndarray], l: int) -> Sequence[jnp.ndarray]:
    off = NUM_HEADER + l * LAYER_STRIDE
    return params[off : off + LAYER_STRIDE]


def moe_layer(
    cfg: ModelConfig,
    x: jnp.ndarray,
    gate_w: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE FFN over flattened tokens x (T, D).

    Returns (output (T, D), load (E,)) where load is the pre-capacity input
    distribution the Pro-Prophet planner consumes.
    """
    t, d = x.shape
    logits = x @ gate_w  # (T, E)
    if cfg.use_pallas:
        idx = gating.topk_gate_decision(logits, cfg.k)  # no grad through idx
    else:
        _, idx, _ = ref.topk_gate_ref(logits, cfg.k)
        idx = jax.lax.stop_gradient(idx)
    # Routing weights re-derived differentiably so gate_w trains (the
    # discrete decision stays in the kernel; see kernels/gating.py).
    probs = jax.nn.softmax(logits, axis=-1)
    weight = jnp.take_along_axis(probs, idx, axis=1)
    weight = weight / jnp.maximum(jnp.sum(weight, axis=1, keepdims=True), 1e-9)
    load = jax.lax.stop_gradient(gating.expert_load(idx, cfg.n_experts))

    expert_inputs, combine = ref.dispatch_combine_ref(
        x, idx, weight, cfg.n_experts, cfg.capacity
    )  # (E, C, D)

    if cfg.use_pallas:
        fn = lambda xe, a, b, c, dd: moe_ffn.expert_ffn(
            xe, a, b, c, dd,
            block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
        )
        expert_outputs = jax.vmap(fn)(expert_inputs, w1, b1, w2, b2)
    else:
        expert_outputs = jax.vmap(ref.expert_ffn_ref)(expert_inputs, w1, b1, w2, b2)

    return combine(expert_outputs), load


def forward(
    cfg: ModelConfig, params: Sequence[jnp.ndarray], tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Next-token LM loss + per-layer expert loads.

    tokens: (B, S) int32.  Returns (scalar loss, loads (L, E)).
    """
    b, s = tokens.shape
    d = cfg.d_model
    tok_emb, pos_emb = params[0], params[1]

    h = tok_emb[tokens] + pos_emb[None, :s, :]  # (B, S, D)
    loads = []
    for l in range(cfg.n_layers):
        (
            ln1_s, ln1_b, wq, wk, wv, wo, ln2_s, ln2_b,
            gate_w, w1, b1, w2, b2,
        ) = _layer_slice(params, l)
        # Attention sublayer (batched over B; plain jnp — see ref.py).
        a_in = ref.layernorm_ref(h, ln1_s, ln1_b)
        att = jax.vmap(
            lambda xb: ref.attention_ref(xb, wq, wk, wv, wo, cfg.n_heads)
        )(a_in)
        h = h + att
        # MoE sublayer over flattened tokens (B*S, D) — EP's token pool.
        m_in = ref.layernorm_ref(h, ln2_s, ln2_b).reshape(b * s, d)
        moe_out, load = moe_layer(cfg, m_in, gate_w, w1, b1, w2, b2)
        h = h + moe_out.reshape(b, s, d)
        loads.append(load)

    h = ref.layernorm_ref(h, params[-2], params[-1])
    logits = h @ params[0].T  # tied head: (B, S, V)

    # Shifted next-token cross-entropy.
    logits = logits[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, jnp.stack(loads)  # (L, E)


def loss_fn(cfg, params, tokens):
    return forward(cfg, params, tokens)


def train_step(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    m: Sequence[jnp.ndarray],
    v: Sequence[jnp.ndarray],
    step: jnp.ndarray,
    tokens: jnp.ndarray,
):
    """One fused fwd+bwd+Adam step.

    Args (all runtime inputs of the AOT artifact, in this order):
      params, m, v: flat tensor lists (see param_specs).
      step: f32 scalar, 1-based Adam timestep.
      tokens: (B, S) int32.
    Returns (tuple in the HLO):
      new_params..., new_m..., new_v..., loss (f32), loads (L, E) f32.
    """
    (loss, loads), grads = jax.value_and_grad(
        lambda p: forward(cfg, p, tokens), has_aux=True
    )(list(params))

    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = [], [], []
    for p, mm, vv, g in zip(params, m, v, grads):
        mm = b1 * mm + (1.0 - b1) * g
        vv = b2 * vv + (1.0 - b2) * g * g
        mhat = mm / bc1
        vhat = vv / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mm)
        new_v.append(vv)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, loads)


def init_state(cfg: ModelConfig, seed: jnp.ndarray):
    """params + zeroed Adam moments, as one flat tuple (the init artifact)."""
    params = init_params(cfg, seed)
    zeros = [jnp.zeros_like(p) for p in params]
    return tuple(params) + tuple(zeros) + tuple(jnp.zeros_like(p) for p in params)


def eval_step(cfg: ModelConfig, params: Sequence[jnp.ndarray], tokens: jnp.ndarray):
    """Forward-only loss + loads (for validation from rust)."""
    loss, loads = forward(cfg, params, tokens)
    return loss, loads


def single_expert_ffn(cfg: ModelConfig, x: jnp.ndarray, w1, b1, w2, b2):
    """One expert's FFN on a (C, D) token slab — the artifact the threaded
    EP coordinator executes per virtual device (examples/ep_demo.rs)."""
    if cfg.use_pallas:
        return moe_ffn.expert_ffn(
            x, w1, b1, w2, b2,
            block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
        )
    return ref.expert_ffn_ref(x, w1, b1, w2, b2)


def gate_only(cfg: ModelConfig, x: jnp.ndarray, gate_w: jnp.ndarray):
    """Gate of one MoE layer on (T, D) tokens -> (idx (T,k), weight (T,k),
    load (E,)).  Used by the EP coordinator to route real tokens."""
    logits = x @ gate_w
    if cfg.use_pallas:
        _, idx, weight = gating.topk_gate(logits, k=cfg.k)
    else:
        _, idx, weight = ref.topk_gate_ref(logits, cfg.k)
    return idx, weight, gating.expert_load(idx, cfg.n_experts)
