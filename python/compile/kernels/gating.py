"""Layer-1 Pallas kernel: top-k gate of a MoE layer.

The gate computes, per token, a softmax over experts and the top-k expert
ids + normalized routing weights.  On the VPU this is a row-wise vector
kernel (no MXU work): the token axis is tiled into blocks, the expert axis
(E = #devices, small) stays resident per block.

Data-dependent scatter (the A2A dispatch itself) deliberately lives OUTSIDE
the kernel, at Layer 3 — exactly as in the paper, where the gate produces
the routing decision and the system layer moves the bytes.

interpret=True for CPU-PJRT execution; correctness vs ref.py in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128


def _topk_gate_kernel(logits_ref, probs_ref, idx_ref, weight_ref, *, k: int):
    """One token-block step: softmax + iterative masked argmax (k rounds)."""
    logits = logits_ref[...]  # (bt, E) f32
    e = logits.shape[1]

    # Numerically stable softmax along the expert axis.
    m = jnp.max(logits, axis=1, keepdims=True)
    z = jnp.exp(logits - m)
    probs = z / jnp.sum(z, axis=1, keepdims=True)
    probs_ref[...] = probs

    # Iterative top-k: k rounds of argmax with already-taken experts masked
    # to -inf.  k is tiny (1 or 2 in the paper) so the loop is unrolled.
    masked = probs
    col = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[0], e), 1)
    for kk in range(k):
        best = jnp.argmax(masked, axis=1).astype(jnp.int32)  # (bt,)
        idx_ref[:, kk] = best
        weight_ref[:, kk] = jnp.take_along_axis(
            probs, best[:, None], axis=1
        )[:, 0]
        taken = col == best[:, None]
        masked = jnp.where(taken, -jnp.inf, masked)


@functools.partial(
    jax.jit, static_argnames=("k", "block_t", "interpret", "renormalize")
)
def topk_gate(
    logits: jnp.ndarray,
    *,
    k: int,
    block_t: int = DEFAULT_BLOCK_T,
    interpret: bool = True,
    renormalize: bool = True,
):
    """Top-k gating over expert logits.

    Args:
      logits: (T, E) f32 gate scores.
      k: experts per token (1 or 2 in the paper's evaluation).
      renormalize: if True the k routing weights are renormalized to sum to
        one (Gshard-style), otherwise raw softmax probabilities are used.

    Returns:
      probs:   (T, E) full softmax (used for aux stats / losses).
      idx:     (T, k) int32 expert ids, descending by probability.
      weight:  (T, k) f32 routing weights.
    """
    if logits.ndim != 2:
        raise ValueError(f"logits must be (T, E), got {logits.shape}")
    t, e = logits.shape
    if not 1 <= k <= e:
        raise ValueError(f"k={k} out of range for E={e}")

    bt = min(block_t, t)
    pad = (-t) % bt
    lp = jnp.pad(logits.astype(jnp.float32), ((0, pad), (0, 0)))
    tp = lp.shape[0]
    grid = (tp // bt,)

    probs, idx, weight = pl.pallas_call(
        functools.partial(_topk_gate_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, e), jnp.float32),
            jax.ShapeDtypeStruct((tp, k), jnp.int32),
            jax.ShapeDtypeStruct((tp, k), jnp.float32),
        ],
        interpret=interpret,
    )(lp)

    probs, idx, weight = probs[:t], idx[:t], weight[:t]
    if renormalize:
        weight = weight / jnp.maximum(
            jnp.sum(weight, axis=1, keepdims=True), 1e-9
        )
    return probs, idx, weight


# The routing decision is discrete: no gradient flows through idx, and the
# pallas interpret-mode call has no reverse-mode rule anyway.  The model
# re-derives the routing WEIGHTS differentiably in jnp from the logits
# (Gshard-style), so gate_w still trains; the decision itself is wrapped in
# a custom-vjp with zero cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def topk_gate_decision(logits, k, block_t=DEFAULT_BLOCK_T, interpret=True):
    """Top-k expert ids only (T, k) — non-differentiable routing decision."""
    _, idx, _ = topk_gate(logits, k=k, block_t=block_t, interpret=interpret)
    return idx


def _decision_fwd(logits, k, block_t, interpret):
    return topk_gate_decision(logits, k, block_t, interpret), logits.shape


def _decision_bwd(k, block_t, interpret, shape, _dout):
    return (jnp.zeros(shape, jnp.float32),)


topk_gate_decision.defvjp(_decision_fwd, _decision_bwd)


def expert_load(idx: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Input distribution: tokens routed to each expert (pre-capacity).

    This is the statistic the Pro-Prophet profiler feeds to the planner
    (paper section II "Locality"): counts[e] = |{(t, kk) : idx[t, kk] = e}|.
    """
    onehot = jax.nn.one_hot(idx.reshape(-1), num_experts, dtype=jnp.float32)
    return jnp.sum(onehot, axis=0)
