"""Layer-1 Pallas kernels: the expert-FFN hot spot of a MoE layer.

The paper's compute hot path is the per-expert FFN (x @ W1 -> GeLU -> @ W2)
executed after the A2A dispatch.  On the authors' CUDA testbed this is a
pair of cuBLAS GEMMs per expert; here we re-express it for the TPU-shaped
Pallas model (see DESIGN.md section "Hardware adaptation"):

* the GEMM is tiled into (block_m x block_n) output tiles with a reduction
  grid over k-blocks — the MXU-systolic-array analogue of the paper's
  threadblock tiling;
* each grid step stages one (block_m, block_k) activation tile and one
  (block_k, block_n) weight tile from HBM into VMEM via ``BlockSpec``;
* partial products accumulate directly in the f32 output tile, which Pallas
  keeps resident in VMEM across the k-grid ("revisiting" the same output
  block), i.e. the classic k-inner matmul pipeline;
* bias add + activation are fused into the final k-step so the activation
  never round-trips to HBM.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime executes.  Correctness is pinned to ``ref.py`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: 128 matches the MXU systolic array edge; f32 tiles of
# (128, 128) are 64 KiB each, so one grid step touches ~192 KiB of VMEM —
# far below the ~16 MiB/core budget, leaving room for double buffering.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GeLU (same form the paper's GPT models use)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


_ACTIVATIONS: dict[str, Callable[[jnp.ndarray], jnp.ndarray]] = {
    "none": lambda x: x,
    "gelu": gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One (m, n, k) grid step of the tiled fused GEMM.

    o[m, n] accumulates x[m, k] @ w[k, n]; on the last k step the bias is
    added and the activation applied in-register (VMEM), fusing the epilogue.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...][None, :]
        o_ref[...] = _ACTIVATIONS[act](acc)


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _mba_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    act: str,
    block_m: int,
    block_n: int,
    block_k: int,
    interpret: bool,
) -> jnp.ndarray:
    """Raw fused pallas GEMM (no autodiff rule) — see matmul_bias_act."""
    m, k = x.shape
    _, n = w.shape

    # Clamp blocks to the (padded) problem so tiny problems stay tiny.
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    bp = _pad_to(b.astype(jnp.float32), 0, bn)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, nk=grid[2], act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def _act_grad(act: str, z: jnp.ndarray) -> jnp.ndarray:
    """d act(z) / dz, elementwise."""
    if act == "none":
        return jnp.ones_like(z)
    if act == "relu":
        return (z > 0).astype(z.dtype)
    if act == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        u = c * (z + 0.044715 * z**3)
        th = jnp.tanh(u)
        du = c * (1.0 + 3.0 * 0.044715 * z * z)
        return 0.5 * (1.0 + th) + 0.5 * z * (1.0 - th * th) * du
    raise ValueError(act)


# Pallas interpret-mode calls do not support reverse-mode autodiff, so the
# public GEMM carries a custom VJP whose backward pass is expressed with the
# SAME Pallas kernel: dx = dz @ w^T, dw = x^T @ dz (three kernel launches
# per GEMM in the backward graph — exactly the dataflow the paper's Eq. (3)
# "backward ~ 2x forward" cost model assumes).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _mba_core(x, w, b, act, block_m, block_n, block_k, interpret):
    return _mba_pallas(x, w, b, act, block_m, block_n, block_k, interpret)


def _mba_fwd(x, w, b, act, block_m, block_n, block_k, interpret):
    # Pre-activation z is the residual needed by the activation gradient.
    z = _mba_pallas(x, w, b, "none", block_m, block_n, block_k, interpret)
    return _ACTIVATIONS[act](z), (x, w, z)


def _mba_bwd(act, block_m, block_n, block_k, interpret, res, dout):
    x, w, z = res
    dz = dout * _act_grad(act, z)
    zk = jnp.zeros((w.shape[0],), jnp.float32)
    zn = jnp.zeros((w.shape[1],), jnp.float32)
    dx = _mba_pallas(dz, w.T, zk, "none", block_m, block_n, block_k, interpret)
    dw = _mba_pallas(x.T, dz, zn, "none", block_m, block_n, block_k, interpret)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


_mba_core.defvjp(_mba_fwd, _mba_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("act", "block_m", "block_n", "block_k", "interpret"),
)
def matmul_bias_act(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    act: str = "none",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused ``act(x @ w + b)`` as a tiled Pallas kernel (differentiable).

    Shapes: x (M, K), w (K, N), b (N,) -> (M, N), f32.
    Inputs whose dimensions are not multiples of the block sizes are
    zero-padded (zeros contribute nothing to the accumulation; padded rows
    and columns are sliced away afterwards).
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    if x.shape[1] != w.shape[0] or w.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes: x{x.shape} w{w.shape} b{b.shape}")
    if act not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    return _mba_core(
        x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32),
        act, block_m, block_n, block_k, interpret,
    )


def expert_ffn(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """One expert's FFN: ``gelu(x @ w1 + b1) @ w2 + b2``.

    Shapes: x (T, D), w1 (D, F), b1 (F,), w2 (F, D), b2 (D,) -> (T, D).
    Two fused Pallas GEMMs; the GeLU is fused into the first epilogue so the
    (T, F) intermediate is written to HBM exactly once.
    """
    h = matmul_bias_act(
        x, w1, b1, act="gelu",
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    return matmul_bias_act(
        h, w2, b2, act="none",
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


def vmem_bytes_per_step(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM residency of one grid step of the fused GEMM (f32).

    x tile + w tile + bias tile + output tile; used by DESIGN.md/EXPERIMENTS.md
    to justify the chosen block shapes (interpret-mode wallclock is not a TPU
    proxy, so we reason about structure instead).
    """
    return 4 * (block_m * block_k + block_k * block_n + block_n + block_m * block_n)


def mxu_utilization_estimate(block_m: int, block_n: int, block_k: int) -> float:
    """Fraction of MXU issue slots a (bm, bn, bk) tile keeps busy.

    The 128x128 MXU retires one 128x128x128 MAC block per pass; partial tiles
    waste the remainder of the systolic wavefront.
    """
    eff_m = min(block_m, 128) / 128.0
    eff_n = min(block_n, 128) / 128.0
    eff_k = min(block_k, 128) / 128.0
    return eff_m * eff_n * eff_k
