"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package has an exact reference here, written with
nothing but jax.numpy so it is trivially auditable.  pytest/hypothesis
sweeps shapes and dtypes and asserts allclose(kernel, ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def matmul_bias_act_ref(x, w, b, act: str = "none"):
    out = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if act == "gelu":
        return gelu_ref(out)
    if act == "relu":
        return jnp.maximum(out, 0.0)
    if act == "none":
        return out
    raise ValueError(act)


def expert_ffn_ref(x, w1, b1, w2, b2):
    """gelu(x @ w1 + b1) @ w2 + b2 — one expert's FFN."""
    h = matmul_bias_act_ref(x, w1, b1, act="gelu")
    return matmul_bias_act_ref(h, w2, b2, act="none")


def topk_gate_ref(logits, k: int, renormalize: bool = True):
    """softmax + top-k expert selection, matching kernels.gating.topk_gate."""
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weight, idx = jax.lax.top_k(probs, k)
    idx = idx.astype(jnp.int32)
    if renormalize:
        weight = weight / jnp.maximum(
            jnp.sum(weight, axis=-1, keepdims=True), 1e-9
        )
    return probs, idx, weight


def expert_load_ref(idx, num_experts: int):
    return jnp.bincount(idx.reshape(-1), length=num_experts).astype(jnp.float32)


def dispatch_combine_ref(x, idx, weight, num_experts: int, capacity: int):
    """Gshard-style capacity-bounded dispatch/combine (oracle for model.py).

    Args:
      x: (T, D) tokens.
      idx: (T, k) expert assignment.
      weight: (T, k) routing weights.
    Returns:
      expert_inputs: (E, C, D) per-expert token slabs (zero-padded).
      combine: function (E, C, D) -> (T, D) that scatters expert outputs
        back to token order, weighted by the gate.
    """
    t, d = x.shape
    k = idx.shape[1]
    # Position of each (token, choice) within its expert queue, in token
    # order (tokens beyond capacity are dropped, as in Gshard/Tutel).
    flat_idx = idx.T.reshape(-1)  # choice-major like the model: (k*T,)
    onehot = jax.nn.one_hot(flat_idx, num_experts, dtype=jnp.int32)  # (kT, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1  # (kT, E), -1 where absent
    pos_in_expert = jnp.sum(pos * onehot, axis=1)  # (kT,)
    keep = (pos_in_expert >= 0) & (pos_in_expert < capacity)

    disp = (
        jax.nn.one_hot(flat_idx, num_experts, dtype=jnp.float32)[:, :, None]
        * jax.nn.one_hot(
            jnp.clip(pos_in_expert, 0, capacity - 1), capacity,
            dtype=jnp.float32,
        )[:, None, :]
        * keep[:, None, None].astype(jnp.float32)
    )  # (kT, E, C)
    xk = jnp.tile(x, (k, 1))  # (kT, D)
    expert_inputs = jnp.einsum("tec,td->ecd", disp, xk)

    wk = weight.T.reshape(-1)  # (kT,)

    def combine(expert_outputs):
        back = jnp.einsum("ecd,tec->td", expert_outputs, disp)  # (kT, D)
        back = back * wk[:, None]
        return back.reshape(k, t, d).sum(axis=0)

    return expert_inputs, combine


def layernorm_ref(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def attention_ref(x, wq, wk, wv, wo, n_heads: int):
    """Causal multi-head self-attention (plain jnp; not a paper contribution)."""
    t, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(t, n_heads, hd).transpose(1, 0, 2)
    k = (x @ wk).reshape(t, n_heads, hd).transpose(1, 0, 2)
    v = (x @ wv).reshape(t, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", att, v)
    return out.transpose(1, 0, 2).reshape(t, d) @ wo
