"""AOT entry point: lower the L2/L1 stack to HLO **text** artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per preset P (written under --out-dir):
  P_train_step.hlo.txt   fused fwd+bwd+Adam step
  P_init.hlo.txt         seed (i32) -> (params..., m..., v...) tuple
  P_eval_step.hlo.txt    forward-only loss + loads
  P_expert_ffn.hlo.txt   one expert FFN on a (C, D) slab  (EP coordinator)
  P_gate.hlo.txt         gate of one MoE layer             (EP coordinator)
  P_manifest.json        config, tensor specs, artifact inventory

``make artifacts`` runs this once; nothing here executes at training time.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: M.ModelConfig) -> str:
    spec = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    pspecs = [spec(s) for _, s in cfg.param_specs()]
    step = jax.ShapeDtypeStruct((), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    fn = functools.partial(M.train_step, cfg)
    lowered = jax.jit(fn).lower(pspecs, pspecs, pspecs, step, tokens)
    return to_hlo_text(lowered)


def lower_init(cfg: M.ModelConfig) -> str:
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(functools.partial(M.init_state, cfg)).lower(seed)
    return to_hlo_text(lowered)


def lower_eval_step(cfg: M.ModelConfig) -> str:
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.param_specs()]
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lowered = jax.jit(functools.partial(M.eval_step, cfg)).lower(pspecs, tokens)
    return to_hlo_text(lowered)


def lower_expert_ffn(cfg: M.ModelConfig) -> str:
    d, f, c = cfg.d_model, cfg.d_ff, cfg.capacity
    s32 = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    lowered = jax.jit(functools.partial(M.single_expert_ffn, cfg)).lower(
        s32((c, d)), s32((d, f)), s32((f,)), s32((f, d)), s32((d,))
    )
    return to_hlo_text(lowered)


def lower_gate(cfg: M.ModelConfig) -> str:
    t, d, e = cfg.tokens_per_step, cfg.d_model, cfg.n_experts
    s32 = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    lowered = jax.jit(functools.partial(M.gate_only, cfg)).lower(
        s32((t, d)), s32((d, e))
    )
    return to_hlo_text(lowered)


def manifest(cfg: M.ModelConfig, artifacts: dict) -> dict:
    return {
        "preset": cfg.name,
        "config": {
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_experts": cfg.n_experts,
            "k": cfg.k,
            "capacity": cfg.capacity,
            "capacity_factor": cfg.capacity_factor,
            "batch": cfg.batch,
            "lr": cfg.lr,
            "tokens_per_step": cfg.tokens_per_step,
            "num_tensors": cfg.num_tensors,
            "num_params": int(cfg.num_params),
        },
        "tensors": [
            {"name": n, "shape": list(s)} for n, s in cfg.param_specs()
        ],
        "artifacts": artifacts,
        # Train-step HLO interface, flat argument order.
        "train_step_interface": {
            "inputs": "params*N, m*N, v*N, step(f32[]), tokens(i32[B,S])",
            "outputs": "tuple(params*N, m*N, v*N, loss(f32[]), loads(f32[L,E]))",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument(
        "--skip-train-step", action="store_true",
        help="only emit the small artifacts (faster iteration)",
    )
    args = ap.parse_args()
    cfg = M.PRESETS[args.preset]
    os.makedirs(args.out_dir, exist_ok=True)

    arts = {}

    def emit(tag: str, text: str) -> None:
        fname = f"{cfg.name}_{tag}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as fh:
            fh.write(text)
        arts[tag] = fname
        print(f"[aot] {fname}: {len(text)/1e6:.2f} MB")

    emit("expert_ffn", lower_expert_ffn(cfg))
    emit("gate", lower_gate(cfg))
    emit("init", lower_init(cfg))
    emit("eval_step", lower_eval_step(cfg))
    if not args.skip_train_step:
        emit("train_step", lower_train_step(cfg))

    mpath = os.path.join(args.out_dir, f"{cfg.name}_manifest.json")
    with open(mpath, "w") as fh:
        json.dump(manifest(cfg, arts), fh, indent=2)
    print(f"[aot] {mpath}")


if __name__ == "__main__":
    main()
