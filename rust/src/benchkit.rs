//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Benches are plain binaries (`harness = false`) that call [`bench_fn`]
//! for wall-clock micro-measurements and print paper-style tables via
//! [`crate::metrics::TableReport`].  Results are also written to
//! `bench_results/*.json` for EXPERIMENTS.md.

use crate::util::stats::Welford;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms  (±{:.3} ms, min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean_ms(),
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with warmup; adaptive iteration count targeting ~`budget_ms`
/// of total measurement.
pub fn bench_fn<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_ms / 1e3 / once).ceil() as u64).clamp(3, 10_000);

    let mut w = Welford::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        w.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: w.mean(),
        std_s: w.std(),
        min_s: w.min(),
        max_s: w.max(),
    }
}

/// Time a single invocation.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Standard bench header so all bench binaries look alike.
pub fn header(id: &str, what: &str) {
    println!("\n###############################################################");
    println!("# {id}: {what}");
    println!("# pro-prophet {} — simulated testbed (see DESIGN.md §3)", crate::VERSION);
    println!("###############################################################");
}

/// Shared experiment scaffolding for the paper-table benches.
pub mod scenario {
    use crate::balancer::{registry, ProphetOptions};
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;
    use crate::sim::{simulate_policy, SimReport};
    use crate::workload::{Trace, WorkloadConfig, WorkloadGen};

    /// Synthetic trace matching a model on a cluster (top-k slots).
    pub fn trace_for(model: &ModelSpec, d: usize, iters: usize, seed: u64) -> Trace {
        let mut cfg = WorkloadConfig::paper_default(
            model.n_layers,
            model.n_experts,
            d,
            model.tokens_per_iter * model.k as u64,
        );
        cfg.seed = seed;
        Trace::capture(&mut WorkloadGen::new(cfg), iters)
    }

    /// Simulate one registry policy (default options) on a scenario —
    /// the bench-side entry to the open policy API.
    pub fn report_for(
        policy: &str,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        trace: &Trace,
    ) -> SimReport {
        report_with(policy, &ProphetOptions::default(), model, cluster, trace)
    }

    /// Like [`report_for`] with explicit options (ablation arms).
    pub fn report_with(
        policy: &str,
        opts: &ProphetOptions,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        trace: &Trace,
    ) -> SimReport {
        let p = registry::build(policy, opts)
            .unwrap_or_else(|| panic!("unknown policy {policy:?}"));
        simulate_policy(model, cluster, trace, p)
    }

    /// (Deepspeed-MoE, FasterMoE, Pro-Prophet) reports on one scenario.
    pub fn three_way(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        trace: &Trace,
    ) -> (SimReport, SimReport, SimReport) {
        (
            report_for("deepspeed", model, cluster, trace),
            report_for("fastermoe", model, cluster, trace),
            report_for("pro-prophet", model, cluster, trace),
        )
    }

    /// Speedups (FasterMoE/DS, Pro-Prophet/DS) like Table IV/V rows.
    pub fn speedup_row(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        iters: usize,
        seed: u64,
    ) -> (f64, f64) {
        let trace = trace_for(model, cluster.n_devices(), iters, seed);
        let (ds, fm, pp) = three_way(model, cluster, &trace);
        (
            ds.avg_iter_time() / fm.avg_iter_time(),
            ds.avg_iter_time() / pp.avg_iter_time(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_three_way_runs() {
        use crate::cluster::ClusterSpec;
        use crate::config::ModelSpec;
        let model = ModelSpec::moe_gpt_s(8, 1, 8192);
        let cluster = ClusterSpec::hpwnv(2);
        let trace = scenario::trace_for(&model, 8, 3, 1);
        let (ds, fm, pp) = scenario::three_way(&model, &cluster, &trace);
        assert!(ds.avg_iter_time() > 0.0);
        assert!(fm.avg_iter_time() > 0.0);
        assert!(pp.avg_iter_time() > 0.0);
    }

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_fn("spin", 5.0, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
        assert!(std::hint::black_box(x) != 1);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
