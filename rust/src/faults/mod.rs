//! Deterministic fault injection for the simulator (ROADMAP Next-direction 1).
//!
//! A [`FaultTimeline`] is a validated set of [`FaultEvent`]s against a
//! fixed-size device fleet.  Per iteration it yields either `None` —
//! no fault is active, the caller MUST take its ordinary static-slowdown
//! path so fault-free runs stay bit-identical to a build without this
//! module — or a [`FaultView`]: the *effective* per-device slowdown
//! vector (the cluster's static `device_slowdown` composed
//! multiplicatively with every active fault) plus the down-device set.
//!
//! Event vocabulary (one comma-free spec line per event, so the flat
//! TOML layer's comma-split arrays can carry them):
//!
//! * `transient dev=D factor=F start=S dur=N` — device `D` computes
//!   `F`x slower for iterations `[S, S+N)`, then recovers.
//! * `degrade dev=D factor=F start=S` — permanent `F`x slowdown from
//!   iteration `S` on (thermal damage, a lost NVLink lane).
//! * `down dev=D start=S` — device `D` performs no work from `S` until
//!   a matching `recover`; its effective slowdown is
//!   [`DOWN_SLOWDOWN`] (0.0) and the balancer must fail its experts
//!   over to live devices.
//! * `recover dev=D start=S` — device `D` rejoins at iteration `S`
//!   (ties with a same-start `down` resolve to recovered).
//!
//! Determinism contract: a timeline is a pure function of its event
//! list; [`FaultTimeline::generate`] derives the list from a seed via
//! the repo's portable xoshiro PRNG, so `--fault-seed N` reproduces the
//! same faults on every run, machine, and resume.

use crate::cluster::ClusterSpec;
use crate::perfmodel::PerfModel;
use crate::util::rng::Rng;

/// Effective slowdown assigned to a down device: it performs no work
/// (its compute lanes price to zero); failover replicas on live devices
/// carry its load.  Deliberately NOT a valid static slowdown factor —
/// only fault views produce it, and only the DES pricing path sees it.
pub const DOWN_SLOWDOWN: f64 = 0.0;

/// One injected fault.  Iteration indices are 0-based and absolute
/// (an event outlasting the trace simply stays active to the end).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEvent {
    /// Device computes `factor`x slower for `[start_iter, start_iter + duration)`.
    TransientSlowdown { device: usize, factor: f64, start_iter: usize, duration: usize },
    /// Device computes `factor`x slower from `start_iter` forever.
    PersistentDegrade { device: usize, factor: f64, start_iter: usize },
    /// Device performs no work from `start_iter` until a later `DeviceRecover`.
    DeviceDown { device: usize, start_iter: usize },
    /// Device rejoins at `start_iter`.
    DeviceRecover { device: usize, start_iter: usize },
}

fn req<T>(v: Option<T>, spec: &str, key: &str) -> Result<T, String> {
    v.ok_or_else(|| format!("fault spec `{spec}`: missing `{key}=`"))
}

impl FaultEvent {
    /// Device the event targets.
    pub fn device(&self) -> usize {
        match *self {
            FaultEvent::TransientSlowdown { device, .. }
            | FaultEvent::PersistentDegrade { device, .. }
            | FaultEvent::DeviceDown { device, .. }
            | FaultEvent::DeviceRecover { device, .. } => device,
        }
    }

    /// Iteration the event first takes effect.
    pub fn start_iter(&self) -> usize {
        match *self {
            FaultEvent::TransientSlowdown { start_iter, .. }
            | FaultEvent::PersistentDegrade { start_iter, .. }
            | FaultEvent::DeviceDown { start_iter, .. }
            | FaultEvent::DeviceRecover { start_iter, .. } => start_iter,
        }
    }

    /// Parse one spec line (see the module docs for the vocabulary).
    pub fn parse(spec: &str) -> Result<FaultEvent, String> {
        let mut toks = spec.split_whitespace();
        let kind = toks.next().ok_or_else(|| "empty fault spec".to_string())?;
        let (mut dev, mut factor, mut start, mut dur) = (None, None, None, None);
        for tok in toks {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| format!("fault spec `{spec}`: expected key=value, got `{tok}`"))?;
            match k {
                "dev" => {
                    dev = Some(v.parse::<usize>().map_err(|_| {
                        format!("fault spec `{spec}`: bad device id `{v}`")
                    })?)
                }
                "factor" => {
                    factor = Some(v.parse::<f64>().map_err(|_| {
                        format!("fault spec `{spec}`: bad factor `{v}`")
                    })?)
                }
                "start" => {
                    start = Some(v.parse::<usize>().map_err(|_| {
                        format!("fault spec `{spec}`: bad start iteration `{v}`")
                    })?)
                }
                "dur" => {
                    dur = Some(v.parse::<usize>().map_err(|_| {
                        format!("fault spec `{spec}`: bad duration `{v}`")
                    })?)
                }
                other => {
                    return Err(format!(
                        "fault spec `{spec}`: unknown key `{other}` (expected dev/factor/start/dur)"
                    ))
                }
            }
        }
        match kind {
            "transient" => Ok(FaultEvent::TransientSlowdown {
                device: req(dev, spec, "dev")?,
                factor: req(factor, spec, "factor")?,
                start_iter: req(start, spec, "start")?,
                duration: req(dur, spec, "dur")?,
            }),
            "degrade" => Ok(FaultEvent::PersistentDegrade {
                device: req(dev, spec, "dev")?,
                factor: req(factor, spec, "factor")?,
                start_iter: req(start, spec, "start")?,
            }),
            "down" => Ok(FaultEvent::DeviceDown {
                device: req(dev, spec, "dev")?,
                start_iter: req(start, spec, "start")?,
            }),
            "recover" => Ok(FaultEvent::DeviceRecover {
                device: req(dev, spec, "dev")?,
                start_iter: req(start, spec, "start")?,
            }),
            other => Err(format!(
                "fault spec `{spec}`: unknown event kind `{other}` \
                 (expected transient/degrade/down/recover)"
            )),
        }
    }

    /// Canonical spec line; `FaultEvent::parse(e.to_spec())` round-trips
    /// bit-exactly (factors print shortest-roundtrip).
    pub fn to_spec(&self) -> String {
        match *self {
            FaultEvent::TransientSlowdown { device, factor, start_iter, duration } => {
                format!("transient dev={device} factor={factor} start={start_iter} dur={duration}")
            }
            FaultEvent::PersistentDegrade { device, factor, start_iter } => {
                format!("degrade dev={device} factor={factor} start={start_iter}")
            }
            FaultEvent::DeviceDown { device, start_iter } => {
                format!("down dev={device} start={start_iter}")
            }
            FaultEvent::DeviceRecover { device, start_iter } => {
                format!("recover dev={device} start={start_iter}")
            }
        }
    }
}

/// Whether a slowdown-type event scales compute at `iter` (down/recover
/// are a per-device state machine, handled by [`FaultTimeline::down_at`]).
fn slowdown_active(e: &FaultEvent, iter: usize) -> bool {
    match *e {
        FaultEvent::TransientSlowdown { start_iter, duration, .. } => {
            start_iter <= iter && iter < start_iter + duration
        }
        FaultEvent::PersistentDegrade { start_iter, .. } => iter >= start_iter,
        FaultEvent::DeviceDown { .. } | FaultEvent::DeviceRecover { .. } => false,
    }
}

/// The per-iteration product of a [`FaultTimeline`]: what the cluster
/// *effectively* looks like while faults are active.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultView {
    /// Effective per-device slowdown, INCLUDING the cluster's static
    /// vector; down devices are [`DOWN_SLOWDOWN`].
    pub slowdown: Vec<f64>,
    /// `down[d]` — device `d` performs no work this iteration.
    pub down: Vec<bool>,
}

impl FaultView {
    pub fn n_down(&self) -> usize {
        self.down.iter().filter(|&&d| d).count()
    }

    pub fn all_down(&self) -> bool {
        !self.down.is_empty() && self.down.iter().all(|&d| d)
    }

    /// The cluster as the DES should price it this iteration.  Writes
    /// the slowdown field directly: `with_slowdowns` (correctly)
    /// rejects the 0.0 a down device carries.
    pub fn effective_cluster(&self, base: &ClusterSpec) -> ClusterSpec {
        let mut c = base.clone();
        c.device_slowdown = self.slowdown.clone();
        c
    }

    /// The planner cost model under this view (slack-aware pricing sees
    /// the faulted slowdowns; the frozen Eq 1–6 scalar estimates ignore
    /// the vector either way).
    pub fn effective_perf_model(&self, base: &PerfModel) -> PerfModel {
        let mut pm = base.clone();
        pm.device_slowdown = self.slowdown.clone();
        pm
    }
}

/// A validated, immutable fault schedule over a fixed device fleet.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultTimeline {
    events: Vec<FaultEvent>,
    n_devices: usize,
}

impl FaultTimeline {
    /// The no-fault timeline; `effective()` is `None` at every iteration.
    pub fn empty() -> Self {
        FaultTimeline::default()
    }

    /// Validate `events` against an `n_devices`-device fleet.
    pub fn new(events: Vec<FaultEvent>, n_devices: usize) -> Result<Self, String> {
        if !events.is_empty() && n_devices == 0 {
            return Err("fault timeline: events on a zero-device cluster".into());
        }
        for e in &events {
            let spec = e.to_spec();
            if e.device() >= n_devices {
                return Err(format!(
                    "fault `{spec}`: device {} out of range (cluster has {n_devices})",
                    e.device()
                ));
            }
            match *e {
                FaultEvent::TransientSlowdown { factor, duration, .. } => {
                    if !(factor.is_finite() && factor > 0.0) {
                        return Err(format!("fault `{spec}`: factor must be finite and > 0"));
                    }
                    if duration == 0 {
                        return Err(format!("fault `{spec}`: duration must be >= 1"));
                    }
                }
                FaultEvent::PersistentDegrade { factor, .. } => {
                    if !(factor.is_finite() && factor > 0.0) {
                        return Err(format!("fault `{spec}`: factor must be finite and > 0"));
                    }
                }
                FaultEvent::DeviceDown { .. } | FaultEvent::DeviceRecover { .. } => {}
            }
        }
        Ok(FaultTimeline { events, n_devices })
    }

    /// Parse one spec line per entry (see [`FaultEvent::parse`]).
    pub fn parse_specs<S: AsRef<str>>(specs: &[S], n_devices: usize) -> Result<Self, String> {
        let events = specs
            .iter()
            .map(|s| FaultEvent::parse(s.as_ref()))
            .collect::<Result<Vec<_>, _>>()?;
        Self::new(events, n_devices)
    }

    /// Parse a fault file: one spec per line, `#` comments and blank
    /// lines skipped.
    pub fn parse_text(text: &str, n_devices: usize) -> Result<Self, String> {
        let specs: Vec<&str> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .collect();
        Self::parse_specs(&specs, n_devices)
    }

    /// Derive a small random-but-reproducible timeline from a seed.
    /// Device 0 is never taken down (a seeded timeline always leaves at
    /// least one live device) and every generated event validates.
    pub fn generate(seed: u64, n_devices: usize, horizon: usize) -> Self {
        assert!(n_devices >= 1, "generate needs at least one device");
        let h = horizon.max(2);
        let mut rng = Rng::new(seed);
        let n_events = 1 + rng.below(3);
        let mut events = Vec::new();
        for _ in 0..n_events {
            let device = rng.below(n_devices);
            let start_iter = rng.below(h);
            match rng.below(4) {
                0 | 1 => {
                    let factor = 1.5 + 2.0 * rng.f64();
                    let duration = 1 + rng.below((h / 2).max(1));
                    events.push(FaultEvent::TransientSlowdown { device, factor, start_iter, duration });
                }
                2 => {
                    let factor = 1.25 + rng.f64();
                    events.push(FaultEvent::PersistentDegrade { device, factor, start_iter });
                }
                _ if n_devices >= 2 => {
                    let device = 1 + rng.below(n_devices - 1);
                    events.push(FaultEvent::DeviceDown { device, start_iter });
                    let recover_at = start_iter + 1 + rng.below((h / 2).max(1));
                    events.push(FaultEvent::DeviceRecover { device, start_iter: recover_at });
                }
                _ => {
                    let factor = 1.5 + 2.0 * rng.f64();
                    events.push(FaultEvent::TransientSlowdown { device, factor, start_iter, duration: 1 });
                }
            }
        }
        Self::new(events, n_devices).expect("generated timeline validates by construction")
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Canonical spec lines (checkpoint embedding / compat checks).
    pub fn specs(&self) -> Vec<String> {
        self.events.iter().map(FaultEvent::to_spec).collect()
    }

    /// Down-device mask at `iter`: for each device, the latest
    /// `down`/`recover` event at or before `iter` wins; a same-start
    /// tie resolves to recovered.
    pub fn down_at(&self, iter: usize) -> Vec<bool> {
        let mut stamp: Vec<Option<(usize, bool)>> = vec![None; self.n_devices];
        for e in &self.events {
            let (d, s, is_down) = match *e {
                FaultEvent::DeviceDown { device, start_iter } => (device, start_iter, true),
                FaultEvent::DeviceRecover { device, start_iter } => (device, start_iter, false),
                _ => continue,
            };
            if s > iter {
                continue;
            }
            let take = match stamp[d] {
                None => true,
                // Later start wins; on a tie, prefer recovered (replace
                // an equal-start down, never an equal-start recover).
                Some((prev_s, prev_down)) => s > prev_s || (s == prev_s && prev_down),
            };
            if take {
                stamp[d] = Some((s, is_down));
            }
        }
        stamp.iter().map(|s| matches!(s, Some((_, true)))).collect()
    }

    /// The effective cluster view at `iter`, or `None` when no fault is
    /// active — callers MUST treat `None` as "take the ordinary static
    /// path" so fault-free iterations stay bit-identical.
    pub fn effective(&self, iter: usize, base: &ClusterSpec) -> Option<FaultView> {
        if self.events.is_empty() {
            return None;
        }
        debug_assert_eq!(base.n_devices(), self.n_devices, "timeline/cluster fleet mismatch");
        let down = self.down_at(iter);
        let mut any = down.iter().any(|&d| d);
        let mut slowdown: Vec<f64> = (0..self.n_devices).map(|d| base.slowdown(d)).collect();
        for e in &self.events {
            if slowdown_active(e, iter) {
                any = true;
                if let FaultEvent::TransientSlowdown { device, factor, .. }
                | FaultEvent::PersistentDegrade { device, factor, .. } = *e
                {
                    slowdown[device] *= factor;
                }
            }
        }
        if !any {
            return None;
        }
        for (d, &dn) in down.iter().enumerate() {
            if dn {
                slowdown[d] = DOWN_SLOWDOWN;
            }
        }
        Some(FaultView { slowdown, down })
    }

    /// (activations, recoveries) crossing the `iter-1 → iter` boundary:
    /// slowdown events entering/leaving their active window plus
    /// devices going down / coming back.
    pub fn transitions(&self, iter: usize) -> (usize, usize) {
        let mut act = 0;
        let mut rec = 0;
        for e in &self.events {
            let now = slowdown_active(e, iter);
            let was = iter > 0 && slowdown_active(e, iter - 1);
            if now && !was {
                act += 1;
            }
            if !now && was {
                rec += 1;
            }
        }
        let now = self.down_at(iter);
        let was = if iter == 0 { vec![false; self.n_devices] } else { self.down_at(iter - 1) };
        for d in 0..self.n_devices {
            if now[d] && !was[d] {
                act += 1;
            }
            if !now[d] && was[d] {
                rec += 1;
            }
        }
        (act, rec)
    }

    /// Human-readable description of everything active at `iter`
    /// (Chrome-trace instant events, logs).
    pub fn active_specs(&self, iter: usize) -> Vec<String> {
        let mut out: Vec<String> = self
            .events
            .iter()
            .filter(|e| slowdown_active(e, iter))
            .map(FaultEvent::to_spec)
            .collect();
        for (d, dn) in self.down_at(iter).into_iter().enumerate() {
            if dn {
                out.push(format!("down dev={d}"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterSpec {
        ClusterSpec::hpwnv(1) // 4 devices
    }

    #[test]
    fn parse_round_trips() {
        let specs = [
            "transient dev=2 factor=2.5 start=10 dur=5",
            "degrade dev=1 factor=1.5 start=20",
            "down dev=3 start=30",
            "recover dev=3 start=40",
        ];
        for s in specs {
            let e = FaultEvent::parse(s).unwrap();
            assert_eq!(e.to_spec(), s);
            assert_eq!(FaultEvent::parse(&e.to_spec()).unwrap(), e);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for (spec, needle) in [
            ("meteor dev=0 start=1", "unknown event kind"),
            ("transient dev=0 factor=2.0 start=1", "missing `dur="),
            ("down start=1", "missing `dev="),
            ("down dev=0 start=1 blah", "key=value"),
            ("transient dev=x factor=2.0 start=1 dur=1", "bad device id"),
            ("degrade dev=0 factor=fast start=1", "bad factor"),
            ("down dev=0 start=1 color=red", "unknown key"),
        ] {
            let err = FaultEvent::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn validation_rejects_bad_events() {
        let mk = |spec: &str| {
            FaultTimeline::parse_specs(&[spec], 4).unwrap_err()
        };
        assert!(mk("down dev=4 start=0").contains("out of range"));
        assert!(mk("transient dev=0 factor=0 start=0 dur=1").contains("finite and > 0"));
        assert!(mk("transient dev=0 factor=-2 start=0 dur=1").contains("finite and > 0"));
        assert!(mk("transient dev=0 factor=2 start=0 dur=0").contains("duration"));
        assert!(mk("degrade dev=0 factor=inf start=0").contains("finite and > 0"));
    }

    #[test]
    fn empty_timeline_is_always_inactive() {
        let t = FaultTimeline::empty();
        let c = cluster();
        assert!(t.is_empty());
        for iter in 0..64 {
            assert_eq!(t.effective(iter, &c), None);
            assert_eq!(t.transitions(iter), (0, 0));
        }
    }

    #[test]
    fn transient_window_is_half_open() {
        let t = FaultTimeline::parse_specs(&["transient dev=2 factor=3 start=4 dur=2"], 4).unwrap();
        let c = cluster();
        assert!(t.effective(3, &c).is_none());
        let v4 = t.effective(4, &c).unwrap();
        assert_eq!(v4.slowdown, vec![1.0, 1.0, 3.0, 1.0]);
        assert!(!v4.down.iter().any(|&d| d));
        assert!(t.effective(5, &c).is_some());
        assert!(t.effective(6, &c).is_none());
        assert_eq!(t.transitions(4), (1, 0));
        assert_eq!(t.transitions(5), (0, 0));
        assert_eq!(t.transitions(6), (0, 1));
    }

    #[test]
    fn degrade_is_permanent_and_composes() {
        // Two degrades on the same device multiply, on top of the
        // cluster's static straggler factor.
        let t = FaultTimeline::parse_specs(
            &["degrade dev=1 factor=2 start=1", "degrade dev=1 factor=1.5 start=3"],
            4,
        )
        .unwrap();
        let c = cluster().with_slowdown(1, 2.0);
        assert!(t.effective(0, &c).is_none());
        assert_eq!(t.effective(1, &c).unwrap().slowdown[1], 4.0);
        assert_eq!(t.effective(100, &c).unwrap().slowdown[1], 6.0);
        // Static factors on OTHER devices pass through untouched.
        assert_eq!(t.effective(100, &c).unwrap().slowdown[0], 1.0);
    }

    #[test]
    fn down_recover_state_machine() {
        let t = FaultTimeline::parse_specs(&["down dev=3 start=2", "recover dev=3 start=5"], 4)
            .unwrap();
        let c = cluster();
        assert!(t.effective(1, &c).is_none());
        for iter in 2..5 {
            let v = t.effective(iter, &c).unwrap();
            assert!(v.down[3], "iter {iter}");
            assert_eq!(v.slowdown[3], DOWN_SLOWDOWN);
            assert_eq!(v.n_down(), 1);
            assert!(!v.all_down());
        }
        // Recovered: back to the base vector, so no view at all.
        assert!(t.effective(5, &c).is_none());
        assert_eq!(t.transitions(2), (1, 0));
        assert_eq!(t.transitions(5), (0, 1));
    }

    #[test]
    fn same_start_recover_wins_tie() {
        let t = FaultTimeline::parse_specs(&["down dev=0 start=3", "recover dev=0 start=3"], 4)
            .unwrap();
        assert_eq!(t.down_at(3), vec![false, false, false, false]);
    }

    #[test]
    fn effective_cluster_and_pm_swap_only_slowdowns() {
        let t = FaultTimeline::parse_specs(&["down dev=1 start=0"], 4).unwrap();
        let c = cluster();
        let v = t.effective(0, &c).unwrap();
        let ec = v.effective_cluster(&c);
        assert_eq!(ec.device_slowdown, vec![1.0, 0.0, 1.0, 1.0]);
        assert!(ec.is_heterogeneous());
        assert_eq!(ec.n_devices(), c.n_devices());
        assert_eq!(ec.avg_bandwidth(), c.avg_bandwidth());
        let pm = PerfModel::new(&crate::config::ModelSpec::moe_gpt_s(8, 1, 8192), &c);
        let epm = v.effective_perf_model(&pm);
        assert_eq!(epm.device_slowdown, ec.device_slowdown);
        assert_eq!(epm.tokens_per_s, pm.tokens_per_s);
    }

    #[test]
    fn generate_is_deterministic_and_valid() {
        let a = FaultTimeline::generate(42, 8, 16);
        let b = FaultTimeline::generate(42, 8, 16);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // Round-trip through specs reproduces the timeline bit-exactly.
        let back = FaultTimeline::parse_specs(&a.specs(), 8).unwrap();
        assert_eq!(back, a);
        assert_ne!(FaultTimeline::generate(43, 8, 16), a);
        // Seeded timelines never down device 0.
        for seed in 0..32 {
            let t = FaultTimeline::generate(seed, 4, 12);
            for iter in 0..24 {
                assert!(!t.down_at(iter)[0], "seed {seed} iter {iter}");
            }
        }
    }

    #[test]
    fn active_specs_lists_whats_live() {
        let t = FaultTimeline::parse_specs(
            &["transient dev=2 factor=2 start=1 dur=2", "down dev=3 start=1"],
            4,
        )
        .unwrap();
        let live = t.active_specs(1);
        assert_eq!(live.len(), 2);
        assert!(live.iter().any(|s| s.starts_with("transient dev=2")));
        assert!(live.iter().any(|s| s == "down dev=3"));
        assert!(t.active_specs(0).is_empty());
    }
}
