//! Read side of the metrics contract: parse a metrics JSONL back into a
//! [`MetricsDoc`] and render it with the repo's `TableReport`, including
//! A/B deltas between two runs (`report --metrics A --baseline B`).

use super::SCHEMA;
use crate::metrics::TableReport;
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, BTreeSet};

/// Span aggregate as read from a metrics file (all fields in seconds
/// except `count`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanAgg {
    pub count: f64,
    pub total_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Gauge aggregate as read from a metrics file.
#[derive(Clone, Copy, Debug, Default)]
pub struct GaugeAgg {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
}

/// A parsed metrics JSONL: run header fields plus whole-run aggregates.
/// Aggregates come from the trailing summary line when present, and are
/// re-folded from the iteration records otherwise (truncated files from
/// killed runs still render).
#[derive(Clone, Debug, Default)]
pub struct MetricsDoc {
    pub schema: String,
    pub meta: BTreeMap<String, Json>,
    /// Iterations the producer saw (including dropped records).
    pub iterations: usize,
    /// Iteration records present in the file.
    pub recorded: usize,
    pub dropped: usize,
    pub counters: BTreeMap<String, f64>,
    pub gauges: BTreeMap<String, GaugeAgg>,
    pub spans: BTreeMap<String, SpanAgg>,
}

impl MetricsDoc {
    /// Every metric name in the document (for unknown-metric errors).
    pub fn metric_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        names.extend(self.spans.keys().cloned());
        names.extend(self.counters.keys().cloned());
        names.extend(self.gauges.keys().cloned());
        names
    }
}

fn f(v: Option<&Json>) -> f64 {
    v.and_then(Json::as_f64).unwrap_or(0.0)
}

fn read_span_agg(v: &Json) -> SpanAgg {
    SpanAgg {
        count: f(v.get("count")),
        total_s: f(v.get("total_s")),
        mean_s: f(v.get("mean_s")),
        min_s: f(v.get("min_s")),
        max_s: f(v.get("max_s")),
    }
}

fn read_gauge_agg(v: &Json) -> GaugeAgg {
    GaugeAgg {
        mean: f(v.get("mean")),
        min: f(v.get("min")),
        max: f(v.get("max")),
        last: f(v.get("last")),
    }
}

/// Parse one metrics JSONL document. Errors carry 1-based line numbers;
/// a schema mismatch is an error, not a warning — mis-rendering a file
/// from a different build is worse than refusing it.
pub fn parse_jsonl(text: &str) -> Result<MetricsDoc, String> {
    let mut doc = MetricsDoc { schema: SCHEMA.to_string(), ..Default::default() };
    let mut saw_header = false;
    let mut saw_summary = false;
    // Kept for re-folding when the summary line is missing.
    let mut iter_records: Vec<Json> = Vec::new();

    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let ln = i + 1;
        let v = json::parse(line).map_err(|e| format!("line {ln}: not valid JSON ({e})"))?;
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("line {ln}: missing \"schema\" field"))?;
        if schema != SCHEMA {
            return Err(format!(
                "line {ln}: unsupported schema {schema:?} (this build reads {SCHEMA:?})"
            ));
        }
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("line {ln}: missing \"kind\" field"))?;
        match kind {
            "run" => {
                saw_header = true;
                if let Some(obj) = v.as_obj() {
                    for (k, val) in obj {
                        if k != "schema" && k != "kind" {
                            doc.meta.insert(k.clone(), val.clone());
                        }
                    }
                }
            }
            "iteration" => {
                doc.recorded += 1;
                iter_records.push(v);
            }
            "summary" => {
                saw_summary = true;
                doc.iterations = f(v.get("iterations")) as usize;
                doc.dropped = f(v.get("dropped")) as usize;
                if let Some(obj) = v.get("counters").and_then(Json::as_obj) {
                    for (k, val) in obj {
                        doc.counters.insert(k.clone(), f(Some(val)));
                    }
                }
                if let Some(obj) = v.get("gauges").and_then(Json::as_obj) {
                    for (k, val) in obj {
                        doc.gauges.insert(k.clone(), read_gauge_agg(val));
                    }
                }
                if let Some(obj) = v.get("spans").and_then(Json::as_obj) {
                    for (k, val) in obj {
                        doc.spans.insert(k.clone(), read_span_agg(val));
                    }
                }
            }
            // Unknown kinds from a newer minor revision are skipped.
            _ => {}
        }
    }

    if !saw_header {
        return Err(format!(
            "no run header line (kind=\"run\", schema {SCHEMA:?}) — not a pro-prophet metrics JSONL"
        ));
    }
    if !saw_summary {
        doc.iterations = doc.recorded;
        fold_iterations(&mut doc, &iter_records);
    }
    Ok(doc)
}

/// Rebuild whole-run aggregates from per-iteration records (summary
/// line missing, e.g. a run killed mid-flight).
fn fold_iterations(doc: &mut MetricsDoc, records: &[Json]) {
    for rec in records {
        if let Some(obj) = rec.get("counters").and_then(Json::as_obj) {
            for (k, v) in obj {
                *doc.counters.entry(k.clone()).or_insert(0.0) += f(Some(v));
            }
        }
        if let Some(obj) = rec.get("gauges").and_then(Json::as_obj) {
            for (k, v) in obj {
                let x = f(Some(v));
                let g = doc.gauges.entry(k.clone()).or_insert(GaugeAgg {
                    mean: 0.0,
                    min: x,
                    max: x,
                    last: x,
                });
                g.min = g.min.min(x);
                g.max = g.max.max(x);
                g.last = x;
                // mean field abused as a running sum; normalized below.
                g.mean += x;
            }
        }
        if let Some(obj) = rec.get("spans").and_then(Json::as_obj) {
            for (k, v) in obj {
                let s = read_span_agg(v);
                let agg = doc.spans.entry(k.clone()).or_insert(SpanAgg {
                    count: 0.0,
                    total_s: 0.0,
                    mean_s: 0.0,
                    min_s: s.min_s,
                    max_s: s.max_s,
                });
                agg.count += s.count;
                agg.total_s += s.total_s;
                agg.min_s = agg.min_s.min(s.min_s);
                agg.max_s = agg.max_s.max(s.max_s);
            }
        }
    }
    let n = doc.recorded.max(1) as f64;
    for g in doc.gauges.values_mut() {
        g.mean /= n;
    }
    for s in doc.spans.values_mut() {
        if s.count > 0.0 {
            s.mean_s = s.total_s / s.count;
        }
    }
}

/// Substring-filter all three metric families; an empty intersection is
/// the unknown-metric error (which lists what the file does contain).
#[allow(clippy::type_complexity)]
fn filtered(
    doc: &MetricsDoc,
    filter: Option<&str>,
) -> Result<
    (BTreeMap<String, SpanAgg>, BTreeMap<String, f64>, BTreeMap<String, GaugeAgg>),
    String,
> {
    let keep = |k: &str| filter.map(|q| k.contains(q)).unwrap_or(true);
    let spans: BTreeMap<String, SpanAgg> =
        doc.spans.iter().filter(|(k, _)| keep(k)).map(|(k, v)| (k.clone(), *v)).collect();
    let counters: BTreeMap<String, f64> =
        doc.counters.iter().filter(|(k, _)| keep(k)).map(|(k, v)| (k.clone(), *v)).collect();
    let gauges: BTreeMap<String, GaugeAgg> =
        doc.gauges.iter().filter(|(k, _)| keep(k)).map(|(k, v)| (k.clone(), *v)).collect();
    if let Some(q) = filter {
        if spans.is_empty() && counters.is_empty() && gauges.is_empty() {
            return Err(unknown_metric(q, doc));
        }
    }
    Ok((spans, counters, gauges))
}

fn unknown_metric(q: &str, doc: &MetricsDoc) -> String {
    let names = doc.metric_names();
    if names.is_empty() {
        format!("unknown metric {q:?}: the file records no metrics")
    } else {
        format!("unknown metric {q:?} (file has: {})", names.join(", "))
    }
}

fn header_line(doc: &MetricsDoc) -> String {
    let mut line = format!(
        "metrics: schema {}, {} iterations ({} recorded, {} dropped)",
        doc.schema, doc.iterations, doc.recorded, doc.dropped
    );
    for (k, v) in &doc.meta {
        let val = match v {
            Json::Str(s) => s.clone(),
            other => other.to_string(),
        };
        line.push_str(&format!("  {k}={val}"));
    }
    line.push('\n');
    line
}

/// Render one metrics document as `TableReport` tables (span timings,
/// counters, gauges), optionally filtered to metrics containing
/// `filter`. Span columns are scaled to milliseconds so the table's
/// three decimals keep microsecond resolution; the JSONL itself always
/// carries seconds.
pub fn render(doc: &MetricsDoc, filter: Option<&str>) -> Result<String, String> {
    let (spans, counters, gauges) = filtered(doc, filter)?;
    let mut out = header_line(doc);

    if !spans.is_empty() {
        let mut t = TableReport::new(
            "span timings (milliseconds)",
            &["count", "total_ms", "mean_ms", "min_ms", "max_ms"],
        );
        for (name, s) in &spans {
            t.row(
                name,
                vec![s.count, s.total_s * 1e3, s.mean_s * 1e3, s.min_s * 1e3, s.max_s * 1e3],
            );
        }
        out.push_str(&t.render());
    }
    if !counters.is_empty() {
        let mut t = TableReport::new("counters", &["total", "per_iter"]);
        let n = doc.iterations.max(1) as f64;
        for (name, total) in &counters {
            t.row(name, vec![*total, total / n]);
        }
        out.push_str(&t.render());
    }
    if !gauges.is_empty() {
        let mut t = TableReport::new("gauges", &["mean", "min", "max", "last"]);
        for (name, g) in &gauges {
            t.row(name, vec![g.mean, g.min, g.max, g.last]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

/// One comparable scalar per metric: spans compare total milliseconds,
/// counters their totals, gauges their means.
fn scalar_view(doc: &MetricsDoc) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    for (k, s) in &doc.spans {
        m.insert(format!("{k}.total_ms"), s.total_s * 1e3);
    }
    for (k, v) in &doc.counters {
        m.insert(k.clone(), *v);
    }
    for (k, g) in &doc.gauges {
        m.insert(format!("{k}.mean"), g.mean);
    }
    m
}

/// A/B diff: one row per metric present in either run, with delta
/// (a - b) and ratio (a / b) columns.
pub fn render_diff(a: &MetricsDoc, b: &MetricsDoc, filter: Option<&str>) -> Result<String, String> {
    let va = scalar_view(a);
    let vb = scalar_view(b);
    let keys: BTreeSet<String> = va
        .keys()
        .chain(vb.keys())
        .filter(|k| filter.map(|q| k.contains(q)).unwrap_or(true))
        .cloned()
        .collect();
    if keys.is_empty() {
        if let Some(q) = filter {
            return Err(unknown_metric(q, if va.len() >= vb.len() { a } else { b }));
        }
        return Err("neither run records any metrics".to_string());
    }
    let mut out = String::from("A = --metrics run, B = --baseline run\n");
    let mut t = TableReport::new("A/B metric deltas", &["a", "b", "delta", "ratio"]);
    for k in &keys {
        let x = va.get(k).copied().unwrap_or(f64::NAN);
        let y = vb.get(k).copied().unwrap_or(f64::NAN);
        let ratio = if y == 0.0 { f64::NAN } else { x / y };
        t.row(k, vec![x, y, x - y, ratio]);
    }
    out.push_str(&t.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Labels, Recorder, TelemetryHub};

    fn sample_hub() -> TelemetryHub {
        let hub = TelemetryHub::new();
        hub.set_meta("mode", json::s("test"));
        for i in 0..3 {
            hub.iteration_start(i);
            hub.counter("des.events", Labels::None, 100);
            hub.gauge("balance", Labels::None, 0.5 + i as f64 * 0.1);
            hub.observe("des.execute", Labels::None, 0.002);
            hub.iteration_end();
        }
        hub
    }

    #[test]
    fn round_trip_matches_hub_aggregates() {
        let hub = sample_hub();
        let doc = parse_jsonl(&hub.to_jsonl()).unwrap();
        assert_eq!(doc.iterations, 3);
        assert_eq!(doc.recorded, 3);
        assert_eq!(doc.dropped, 0);
        assert_eq!(doc.counters.get("des.events"), Some(&300.0));
        let s = doc.spans.get("des.execute").unwrap();
        assert_eq!(s.count, 3.0);
        assert!((s.total_s - 0.006).abs() < 1e-9);
        let g = doc.gauges.get("balance").unwrap();
        assert!((g.mean - 0.6).abs() < 1e-9);
        assert_eq!(g.last, 0.7);
    }

    #[test]
    fn truncated_file_refolds_from_iteration_records() {
        let hub = sample_hub();
        let full = hub.to_jsonl();
        // Drop the trailing summary line, as a killed run would.
        let truncated: String =
            full.lines().take(full.lines().count() - 1).map(|l| format!("{l}\n")).collect();
        let doc = parse_jsonl(&truncated).unwrap();
        assert_eq!(doc.iterations, 3);
        assert_eq!(doc.counters.get("des.events"), Some(&300.0));
        let s = doc.spans.get("des.execute").unwrap();
        assert_eq!(s.count, 3.0);
        assert!((s.mean_s - 0.002).abs() < 1e-9);
        let g = doc.gauges.get("balance").unwrap();
        assert!((g.mean - 0.6).abs() < 1e-9);
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = parse_jsonl("not json\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let hub = sample_hub();
        let mut text = hub.to_jsonl();
        text.push_str("{\"schema\":\"other/v9\",\"kind\":\"run\"}\n");
        let err = parse_jsonl(&text).unwrap_err();
        assert!(err.contains("unsupported schema") && err.contains("other/v9"), "{err}");
        let err = parse_jsonl("").unwrap_err();
        assert!(err.contains("no run header"), "{err}");
    }

    #[test]
    fn render_filters_and_rejects_unknown_metrics() {
        let doc = parse_jsonl(&sample_hub().to_jsonl()).unwrap();
        let all = render(&doc, None).unwrap();
        assert!(all.contains("des.execute") && all.contains("des.events"), "{all}");
        assert!(all.contains("span timings"), "{all}");
        let only = render(&doc, Some("des.")).unwrap();
        assert!(only.contains("des.execute") && !only.contains("gauges"), "{only}");
        let err = render(&doc, Some("warpdrive")).unwrap_err();
        assert!(err.contains("unknown metric") && err.contains("des.events"), "{err}");
    }

    #[test]
    fn diff_reports_deltas_per_metric() {
        let a = parse_jsonl(&sample_hub().to_jsonl()).unwrap();
        let hub_b = TelemetryHub::new();
        hub_b.iteration_start(0);
        hub_b.counter("des.events", Labels::None, 100);
        hub_b.iteration_end();
        let b = parse_jsonl(&hub_b.to_jsonl()).unwrap();
        let out = render_diff(&a, &b, None).unwrap();
        assert!(out.contains("des.events"), "{out}");
        assert!(out.contains("delta"), "{out}");
        // a-only metric still shows up.
        assert!(out.contains("balance.mean"), "{out}");
        let err = render_diff(&a, &b, Some("nope")).unwrap_err();
        assert!(err.contains("unknown metric"), "{err}");
    }
}
