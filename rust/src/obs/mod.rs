//! Telemetry: structured per-iteration metrics for the host-side phases.
//!
//! Pro-Prophet's premise is that *recorded* statistics drive planning, so
//! the simulator/trainer record their own runtime statistics the same way:
//! a dependency-free [`Recorder`] trait (counters / gauges / span samples),
//! a [`TelemetryHub`] implementation that aggregates per iteration and
//! whole-run, and a schema-versioned JSONL sink rendered by the `report`
//! CLI subcommand.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when off.** The default recorder is [`NoopRecorder`];
//!    [`Span::enter`] never reads the clock unless `enabled()` is true, no
//!    method allocates, and instrumented hot paths stay bit-identical to
//!    the uninstrumented ones (pinned by `integration_obs.rs` against the
//!    frozen oracles).
//! 2. **Static metric identity.** Metric names are `&'static str` and
//!    labels are the alloc-free [`Labels`] enum, so recording a sample is
//!    a mutex lock plus a `BTreeMap` update — no formatting on the hot
//!    path. Names are only rendered (`name{k=v}`) when the sink is
//!    written.
//! 3. **Bounded sinks, no silent caps.** The hub keeps at most
//!    `max_events` per-iteration records; anything beyond is counted and
//!    reported (dropped count + iteration range) in both the JSONL
//!    summary line and [`SinkStats::drop_message`].
//!
//! The JSONL contract (schema [`SCHEMA`]): line 1 is a `kind = "run"`
//! header, then one `kind = "iteration"` record per retained iteration,
//! then a final `kind = "summary"` line with whole-run aggregates. See
//! EXPERIMENTS.md §Observability for the metric catalog.

mod hub;
pub mod report;

pub use hub::{Agg, SinkStats, TelemetryHub};

use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Schema identifier stamped on every JSONL line. Bump the `/vN` suffix
/// on any breaking change to the record shapes; `report` refuses files
/// it cannot read rather than mis-rendering them.
pub const SCHEMA: &str = "pro-prophet-metrics/v1";

/// Default cap on retained per-iteration records (and Chrome-trace op
/// events) — large enough for any current experiment, small enough that
/// a runaway loop cannot fill a disk.
pub const DEFAULT_MAX_EVENTS: usize = 100_000;

/// `[obs]` table of an experiment config: where the metrics JSONL goes
/// and how many per-iteration records the sink retains.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Metrics JSONL path; `None` leaves telemetry off (the default).
    pub metrics_path: Option<String>,
    /// Sink retention cap (see [`DEFAULT_MAX_EVENTS`]).
    pub max_events: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { metrics_path: None, max_events: DEFAULT_MAX_EVENTS }
    }
}

/// Alloc-free metric labels. At most two key/value pairs — enough for
/// `{dev=5}` / `{layer=3,dev=5}` style dimensions without touching the
/// heap on the recording path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Labels {
    None,
    One(&'static str, i64),
    Two((&'static str, i64), (&'static str, i64)),
}

impl Labels {
    pub fn one(key: &'static str, value: i64) -> Labels {
        Labels::One(key, value)
    }

    /// Rendered suffix for sink keys: `""`, `"{k=v}"`, or `"{a=1,b=2}"`.
    pub fn suffix(&self) -> String {
        match self {
            Labels::None => String::new(),
            Labels::One(k, v) => format!("{{{k}={v}}}"),
            Labels::Two((k1, v1), (k2, v2)) => format!("{{{k1}={v1},{k2}={v2}}}"),
        }
    }
}

/// Metric sink interface. All methods default to no-ops so `dyn
/// Recorder` call sites cost one virtual call when telemetry is off;
/// implementations must be `Send + Sync` because `BalancerSession`
/// fans `decide` out over scoped threads.
pub trait Recorder: Send + Sync {
    /// `false` (the default) lets callers skip sample *construction* —
    /// most importantly the `Instant::now()` pair inside [`Span`].
    fn enabled(&self) -> bool {
        false
    }

    /// Monotonic count (events processed, plans run, tokens seen).
    fn counter(&self, _name: &'static str, _labels: Labels, _delta: u64) {}

    /// Point-in-time value (balance degree, loss, makespan seconds).
    fn gauge(&self, _name: &'static str, _labels: Labels, _value: f64) {}

    /// One duration/histogram sample in seconds ([`Span`] calls this).
    fn observe(&self, _name: &'static str, _labels: Labels, _seconds: f64) {}

    /// Open the per-iteration scope `index` (0-based sim iteration or
    /// 1-based train step — the producer picks the numbering).
    fn iteration_start(&self, _index: usize) {}

    /// Close the current per-iteration scope and flush it to the sink.
    fn iteration_end(&self) {}
}

/// RAII span: times a region and records it via [`Recorder::observe`]
/// on drop. When the recorder is disabled the guard holds nothing and
/// never reads the clock.
#[must_use = "a span measures until it is dropped; binding to _ drops immediately"]
pub struct Span<'a> {
    armed: Option<(&'a dyn Recorder, &'static str, Labels, Instant)>,
}

impl<'a> Span<'a> {
    pub fn enter(rec: &'a dyn Recorder, name: &'static str, labels: Labels) -> Span<'a> {
        let armed =
            if rec.enabled() { Some((rec, name, labels, Instant::now())) } else { None };
        Span { armed }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some((rec, name, labels, t0)) = self.armed.take() {
            rec.observe(name, labels, t0.elapsed().as_secs_f64());
        }
    }
}

/// The disabled recorder: every method is the trait default no-op.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {}

static NOOP: NoopRecorder = NoopRecorder;

/// Borrowed disabled recorder for `DecideCtx`-style plumbing.
pub fn noop() -> &'static dyn Recorder {
    &NOOP
}

/// Shared disabled recorder for owner structs (`BalancerSession`,
/// `Trainer`); allocated once per process.
pub fn noop_arc() -> Arc<dyn Recorder> {
    static CELL: OnceLock<Arc<NoopRecorder>> = OnceLock::new();
    CELL.get_or_init(|| Arc::new(NoopRecorder)).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_render_stable_suffixes() {
        assert_eq!(Labels::None.suffix(), "");
        assert_eq!(Labels::one("dev", 5).suffix(), "{dev=5}");
        assert_eq!(Labels::Two(("layer", 3), ("dev", 5)).suffix(), "{layer=3,dev=5}");
    }

    #[test]
    fn noop_recorder_is_disabled_and_inert() {
        let rec = noop();
        assert!(!rec.enabled());
        rec.counter("x", Labels::None, 1);
        rec.gauge("x", Labels::None, 1.0);
        rec.observe("x", Labels::None, 1.0);
        rec.iteration_start(0);
        rec.iteration_end();
        // A span over a disabled recorder never arms.
        let sp = Span::enter(rec, "x", Labels::None);
        assert!(sp.armed.is_none());
    }

    #[test]
    fn span_records_into_an_enabled_recorder() {
        let hub = TelemetryHub::new();
        {
            let _sp = Span::enter(&hub, "unit.span", Labels::None);
        }
        let agg = hub.span_agg("unit.span", Labels::None).expect("span recorded");
        assert_eq!(agg.count, 1);
        assert!(agg.total >= 0.0);
    }

    #[test]
    fn noop_arc_is_shared() {
        let a = noop_arc();
        let b = noop_arc();
        assert!(!a.enabled() && !b.enabled());
        assert!(Arc::ptr_eq(&a, &b));
    }
}
