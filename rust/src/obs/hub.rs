//! `TelemetryHub`: the enabled [`Recorder`] — per-iteration scopes,
//! whole-run aggregates, and the bounded schema-versioned JSONL sink.

use super::{Labels, Recorder, DEFAULT_MAX_EVENTS, SCHEMA};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::Mutex;

/// Metric identity on the recording path: static name + packed labels.
/// Rendering to `name{k=v}` strings happens only at sink time.
type Key = (&'static str, Labels);

/// Streaming aggregate for span samples and gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Agg {
    pub count: u64,
    pub total: f64,
    pub min: f64,
    pub max: f64,
    pub last: f64,
}

impl Agg {
    fn push(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
        self.count += 1;
        self.total += v;
        self.last = v;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }
}

/// One metric scope (whole-run or a single iteration).
#[derive(Default)]
struct Scope {
    counters: BTreeMap<Key, u64>,
    gauges: BTreeMap<Key, Agg>,
    spans: BTreeMap<Key, Agg>,
}

struct HubState {
    /// Run-header fields (`set_meta`), written once on the "run" line.
    meta: BTreeMap<String, Json>,
    /// Fields stamped into every subsequent iteration record
    /// (`set_context`) — e.g. which policy a multi-policy run is on.
    context: BTreeMap<String, Json>,
    run: Scope,
    iter: Scope,
    iter_index: Option<usize>,
    records: Vec<Json>,
    iterations_seen: usize,
    dropped: usize,
    dropped_first: Option<usize>,
    dropped_last: Option<usize>,
}

/// What the sink kept and what it shed; `drop_message` is the exact
/// line producers print so caps are never silent.
#[derive(Clone, Debug)]
pub struct SinkStats {
    pub lines: usize,
    pub iterations: usize,
    pub recorded: usize,
    pub dropped: usize,
    pub dropped_first: Option<usize>,
    pub dropped_last: Option<usize>,
    pub max_events: usize,
}

impl SinkStats {
    pub fn drop_message(&self) -> Option<String> {
        if self.dropped == 0 {
            return None;
        }
        Some(format!(
            "metrics sink: dropped {} of {} iteration records (iterations {}..={}) over the max-events cap {}",
            self.dropped,
            self.iterations,
            self.dropped_first.unwrap_or(0),
            self.dropped_last.unwrap_or(0),
            self.max_events
        ))
    }
}

/// The enabled recorder. Interior mutability via one `Mutex` — every
/// instrumented phase is host-side and coarse enough that contention is
/// negligible, and `&self` methods keep the `Recorder` trait object
/// shareable across the decide fan-out threads.
pub struct TelemetryHub {
    state: Mutex<HubState>,
    max_events: usize,
}

impl Default for TelemetryHub {
    fn default() -> Self {
        Self::new()
    }
}

impl TelemetryHub {
    pub fn new() -> Self {
        Self::with_max_events(DEFAULT_MAX_EVENTS)
    }

    /// `max_events` bounds retained per-iteration records (>= 1).
    pub fn with_max_events(max_events: usize) -> Self {
        TelemetryHub {
            state: Mutex::new(HubState {
                meta: BTreeMap::new(),
                context: BTreeMap::new(),
                run: Scope::default(),
                iter: Scope::default(),
                iter_index: None,
                records: Vec::new(),
                iterations_seen: 0,
                dropped: 0,
                dropped_first: None,
                dropped_last: None,
            }),
            max_events: max_events.max(1),
        }
    }

    pub fn max_events(&self) -> usize {
        self.max_events
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HubState> {
        self.state.lock().expect("telemetry hub lock poisoned")
    }

    /// Set a run-header field (model, cluster, seed, ...).
    pub fn set_meta(&self, key: &str, value: Json) {
        self.lock().meta.insert(key.to_string(), value);
    }

    /// Set a field stamped into every iteration record from now on.
    pub fn set_context(&self, key: &str, value: Json) {
        self.lock().context.insert(key.to_string(), value);
    }

    pub fn counter_total(&self, name: &'static str, labels: Labels) -> u64 {
        self.lock().run.counters.get(&(name, labels)).copied().unwrap_or(0)
    }

    pub fn span_agg(&self, name: &'static str, labels: Labels) -> Option<Agg> {
        self.lock().run.spans.get(&(name, labels)).copied()
    }

    pub fn gauge_agg(&self, name: &'static str, labels: Labels) -> Option<Agg> {
        self.lock().run.gauges.get(&(name, labels)).copied()
    }

    pub fn iterations_seen(&self) -> usize {
        self.lock().iterations_seen
    }

    pub fn iterations_recorded(&self) -> usize {
        self.lock().records.len()
    }

    pub fn dropped(&self) -> usize {
        self.lock().dropped
    }

    pub fn stats(&self) -> SinkStats {
        let st = self.lock();
        SinkStats {
            // run header + iteration records + summary
            lines: st.records.len() + 2,
            iterations: st.iterations_seen,
            recorded: st.records.len(),
            dropped: st.dropped,
            dropped_first: st.dropped_first,
            dropped_last: st.dropped_last,
            max_events: self.max_events,
        }
    }

    /// Render the whole sink: header line, iteration records, summary.
    pub fn to_jsonl(&self) -> String {
        let st = self.lock();
        let mut out = String::new();

        let mut header: BTreeMap<String, Json> = BTreeMap::new();
        header.insert("schema".into(), json::s(SCHEMA));
        header.insert("kind".into(), json::s("run"));
        header.insert("version".into(), json::s(crate::VERSION));
        for (k, v) in &st.meta {
            header.insert(k.clone(), v.clone());
        }
        out.push_str(&Json::Obj(header).to_string());
        out.push('\n');

        for rec in &st.records {
            out.push_str(&rec.to_string());
            out.push('\n');
        }

        let mut summary: BTreeMap<String, Json> = BTreeMap::new();
        summary.insert("schema".into(), json::s(SCHEMA));
        summary.insert("kind".into(), json::s("summary"));
        summary.insert("iterations".into(), json::num(st.iterations_seen as f64));
        summary.insert("recorded".into(), json::num(st.records.len() as f64));
        summary.insert("dropped".into(), json::num(st.dropped as f64));
        if let (Some(a), Some(b)) = (st.dropped_first, st.dropped_last) {
            summary.insert("dropped_first".into(), json::num(a as f64));
            summary.insert("dropped_last".into(), json::num(b as f64));
        }
        if !st.run.counters.is_empty() {
            summary.insert("counters".into(), counters_json(&st.run.counters));
        }
        if !st.run.gauges.is_empty() {
            summary.insert("gauges".into(), aggs_json(&st.run.gauges, false));
        }
        if !st.run.spans.is_empty() {
            summary.insert("spans".into(), aggs_json(&st.run.spans, true));
        }
        out.push_str(&Json::Obj(summary).to_string());
        out.push('\n');
        out
    }

    pub fn write_jsonl(&self, path: &Path) -> io::Result<SinkStats> {
        std::fs::write(path, self.to_jsonl())?;
        Ok(self.stats())
    }
}

fn key_name(key: &Key) -> String {
    format!("{}{}", key.0, key.1.suffix())
}

fn counters_json(m: &BTreeMap<Key, u64>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (key_name(k), json::num(*v as f64))).collect())
}

/// Gauges in iteration records are scalars (the last value set); in
/// aggregate position both gauges and spans render their full `Agg`.
/// Span fields carry an `_s` suffix: the unit is always seconds.
fn aggs_json(m: &BTreeMap<Key, Agg>, spans: bool) -> Json {
    let (total, mean, min, max) = if spans {
        ("total_s", "mean_s", "min_s", "max_s")
    } else {
        ("total", "mean", "min", "max")
    };
    Json::Obj(
        m.iter()
            .map(|(k, a)| {
                let mut o: BTreeMap<String, Json> = BTreeMap::new();
                o.insert("count".into(), json::num(a.count as f64));
                o.insert(total.into(), json::num(a.total));
                o.insert(mean.into(), json::num(a.mean()));
                o.insert(min.into(), json::num(a.min));
                o.insert(max.into(), json::num(a.max));
                if !spans {
                    o.insert("last".into(), json::num(a.last));
                }
                (key_name(k), Json::Obj(o))
            })
            .collect(),
    )
}

fn gauges_scalar_json(m: &BTreeMap<Key, Agg>) -> Json {
    Json::Obj(m.iter().map(|(k, a)| (key_name(k), json::num(a.last))).collect())
}

impl Recorder for TelemetryHub {
    fn enabled(&self) -> bool {
        true
    }

    fn counter(&self, name: &'static str, labels: Labels, delta: u64) {
        let mut st = self.lock();
        *st.run.counters.entry((name, labels)).or_insert(0) += delta;
        if st.iter_index.is_some() {
            *st.iter.counters.entry((name, labels)).or_insert(0) += delta;
        }
    }

    fn gauge(&self, name: &'static str, labels: Labels, value: f64) {
        let mut st = self.lock();
        st.run.gauges.entry((name, labels)).or_default().push(value);
        if st.iter_index.is_some() {
            st.iter.gauges.entry((name, labels)).or_default().push(value);
        }
    }

    fn observe(&self, name: &'static str, labels: Labels, seconds: f64) {
        let mut st = self.lock();
        st.run.spans.entry((name, labels)).or_default().push(seconds);
        if st.iter_index.is_some() {
            st.iter.spans.entry((name, labels)).or_default().push(seconds);
        }
    }

    fn iteration_start(&self, index: usize) {
        let mut st = self.lock();
        st.iter = Scope::default();
        st.iter_index = Some(index);
    }

    fn iteration_end(&self) {
        let mut st = self.lock();
        let Some(idx) = st.iter_index.take() else { return };
        st.iterations_seen += 1;
        let scope = std::mem::take(&mut st.iter);
        if st.records.len() >= self.max_events {
            st.dropped += 1;
            if st.dropped_first.is_none() {
                st.dropped_first = Some(idx);
            }
            st.dropped_last = Some(idx);
            return;
        }
        let mut rec: BTreeMap<String, Json> = BTreeMap::new();
        rec.insert("schema".into(), json::s(SCHEMA));
        rec.insert("kind".into(), json::s("iteration"));
        rec.insert("iter".into(), json::num(idx as f64));
        for (k, v) in &st.context {
            rec.insert(k.clone(), v.clone());
        }
        if !scope.counters.is_empty() {
            rec.insert("counters".into(), counters_json(&scope.counters));
        }
        if !scope.gauges.is_empty() {
            rec.insert("gauges".into(), gauges_scalar_json(&scope.gauges));
        }
        if !scope.spans.is_empty() {
            rec.insert("spans".into(), aggs_json(&scope.spans, true));
        }
        st.records.push(Json::Obj(rec));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agg_tracks_min_max_mean_last() {
        let mut a = Agg::default();
        for v in [3.0, 1.0, 2.0] {
            a.push(v);
        }
        assert_eq!(a.count, 3);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert_eq!(a.last, 2.0);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate_per_run_and_per_iteration() {
        let hub = TelemetryHub::new();
        hub.counter("x", Labels::None, 2); // outside any iteration
        hub.iteration_start(0);
        hub.counter("x", Labels::None, 3);
        hub.iteration_end();
        assert_eq!(hub.counter_total("x", Labels::None), 5);
        let text = hub.to_jsonl();
        let iter_line = text.lines().nth(1).unwrap();
        let v = json::parse(iter_line).unwrap();
        // Only the in-iteration delta lands in the iteration record.
        assert_eq!(v.get("counters").unwrap().get("x").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn labels_split_metric_identity() {
        let hub = TelemetryHub::new();
        hub.gauge("idle", Labels::one("dev", 0), 1.0);
        hub.gauge("idle", Labels::one("dev", 1), 9.0);
        assert_eq!(hub.gauge_agg("idle", Labels::one("dev", 1)).unwrap().last, 9.0);
        let text = hub.to_jsonl();
        assert!(text.contains("idle{dev=0}") && text.contains("idle{dev=1}"), "{text}");
    }

    #[test]
    fn sink_caps_and_accounts_for_drops() {
        let hub = TelemetryHub::with_max_events(2);
        for i in 0..5 {
            hub.iteration_start(i);
            hub.counter("n", Labels::None, 1);
            hub.iteration_end();
        }
        let stats = hub.stats();
        assert_eq!(stats.iterations, 5);
        assert_eq!(stats.recorded, 2);
        assert_eq!(stats.dropped, 3);
        assert_eq!(stats.dropped_first, Some(2));
        assert_eq!(stats.dropped_last, Some(4));
        let msg = stats.drop_message().expect("drops must be reported");
        assert!(msg.contains("3 of 5") && msg.contains("2..=4"), "{msg}");
        // Aggregates still see every iteration.
        assert_eq!(hub.counter_total("n", Labels::None), 5);
        // Lines: header + 2 records + summary.
        assert_eq!(hub.to_jsonl().lines().count(), 4);
    }

    #[test]
    fn every_line_is_schema_stamped_json() {
        let hub = TelemetryHub::new();
        hub.set_meta("mode", json::s("test"));
        hub.set_context("policy", json::s("pro-prophet"));
        hub.iteration_start(0);
        hub.observe("phase", Labels::None, 0.25);
        hub.iteration_end();
        for line in hub.to_jsonl().lines() {
            let v = json::parse(line).expect("valid JSON");
            assert_eq!(v.get("schema").and_then(Json::as_str), Some(SCHEMA));
        }
        let text = hub.to_jsonl();
        assert!(text.contains("\"policy\":\"pro-prophet\""), "{text}");
        assert!(text.contains("\"mode\":\"test\""), "{text}");
    }
}
