//! Workload generation: synthetic gate-routing traces with the skew and
//! iteration-to-iteration locality the paper profiles (Fig 3, Fig 4), plus
//! trace record/replay and the synthetic token corpus for the end-to-end
//! trainer.

pub mod arrivals;
pub mod corpus;
pub mod trace;

pub use trace::Trace;

use crate::moe::LoadMatrix;
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub n_layers: usize,
    pub n_experts: usize,
    pub n_devices: usize,
    /// Tokens per iteration across the cluster (k-weighted routing slots:
    /// pass tokens * k to model a top-k gate).
    pub tokens_per_iter: u64,
    /// Zipf exponent of the base expert popularity (1.2 reproduces the
    /// paper's Fig 3: top-3 of 16 experts hold >50% of tokens).
    pub zipf_s: f64,
    /// Per-iteration drift of the popularity vector in [0, 1]:
    /// 0 = frozen distribution, 1 = fully resampled each iteration.
    /// 0.05 reproduces Fig 4's near-constant adjacent iterations.
    pub drift: f64,
    /// Device-level sampling noise (Dirichlet concentration multiplier;
    /// larger = device shards look more alike).
    pub device_concentration: f64,
    pub seed: u64,
}

impl WorkloadConfig {
    pub fn paper_default(n_layers: usize, n_experts: usize, n_devices: usize, tokens: u64) -> Self {
        WorkloadConfig {
            n_layers,
            n_experts,
            n_devices,
            tokens_per_iter: tokens,
            zipf_s: 1.2,
            drift: 0.05,
            device_concentration: 60.0,
            seed: 42,
        }
    }
}

/// Stateful generator: evolves a latent per-layer expert popularity vector
/// and samples per-device load matrices from it.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: Rng,
    /// Latent popularity per layer (simplex vectors).
    popularity: Vec<Vec<f64>>,
    iteration: usize,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let popularity = (0..cfg.n_layers)
            .map(|l| {
                let mut layer_rng = rng.split(l as u64 + 1);
                base_popularity(&mut layer_rng, cfg.n_experts, cfg.zipf_s)
            })
            .collect();
        WorkloadGen { cfg, rng, popularity, iteration: 0 }
    }

    pub fn cfg(&self) -> &WorkloadConfig {
        &self.cfg
    }

    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Generate the next iteration: one LoadMatrix per MoE layer.
    pub fn next_iteration(&mut self) -> Vec<LoadMatrix> {
        let out = (0..self.cfg.n_layers)
            .map(|l| self.sample_layer(l))
            .collect();
        self.evolve();
        self.iteration += 1;
        out
    }

    fn sample_layer(&mut self, layer: usize) -> LoadMatrix {
        let cfg = &self.cfg;
        let p = self.popularity[layer].clone();
        let per_device = cfg.tokens_per_iter / cfg.n_devices as u64;
        let conc = cfg.device_concentration;
        let n_devices = cfg.n_devices;
        let n_experts = cfg.n_experts;
        let mut w = LoadMatrix::zeros(n_devices, n_experts);
        for d in 0..n_devices {
            // Device shard draws a jittered copy of the layer popularity
            // (data parallel shards see similar but not identical data).
            let alpha: Vec<f64> = p.iter().map(|&x| (x * conc).max(1e-3)).collect();
            let device_p = self.rng.dirichlet(&alpha);
            let counts = self.rng.multinomial(per_device, &device_p);
            for (e, &c) in counts.iter().enumerate() {
                w.set(d, e, c);
            }
        }
        w
    }

    /// Random-walk the latent popularity (the paper's slowly varying
    /// imbalance: heavy experts change identity over tens of iterations).
    fn evolve(&mut self) {
        let drift = self.cfg.drift;
        if drift <= 0.0 {
            return;
        }
        for l in 0..self.popularity.len() {
            let fresh = {
                let mut r = self.rng.split(0xD1F7 + l as u64);
                base_popularity(&mut r, self.cfg.n_experts, self.cfg.zipf_s)
            };
            let p = &mut self.popularity[l];
            let mut sum = 0.0;
            for (pi, fi) in p.iter_mut().zip(&fresh) {
                *pi = (1.0 - drift) * *pi + drift * fi;
                sum += *pi;
            }
            for pi in p.iter_mut() {
                *pi /= sum;
            }
        }
    }
}

/// Zipf-shaped popularity with a random expert permutation (so the heavy
/// experts differ per layer, as in the paper's Fig 3 heat map).
fn base_popularity(rng: &mut Rng, n_experts: usize, zipf_s: f64) -> Vec<f64> {
    let mut ranks: Vec<usize> = (0..n_experts).collect();
    rng.shuffle(&mut ranks);
    let h: f64 = (1..=n_experts).map(|k| (k as f64).powf(-zipf_s)).sum();
    let mut p = vec![0.0; n_experts];
    for (rank_pos, &e) in ranks.iter().enumerate() {
        p[e] = ((rank_pos + 1) as f64).powf(-zipf_s) / h;
    }
    p
}

/// Share of tokens held by the `k` heaviest experts of a distribution.
pub fn top_share(dist: &[u64], k: usize) -> f64 {
    let total: u64 = dist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut v: Vec<u64> = dist.to_vec();
    v.sort_by_key(|&x| std::cmp::Reverse(x));
    v.iter().take(k).sum::<u64>() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::locality::similarity;

    fn gen16() -> WorkloadGen {
        WorkloadGen::new(WorkloadConfig::paper_default(12, 16, 16, 16384))
    }

    #[test]
    fn token_conservation() {
        let mut g = gen16();
        let layers = g.next_iteration();
        assert_eq!(layers.len(), 12);
        for w in &layers {
            assert_eq!(w.total_tokens(), 16384);
            assert_eq!(w.n_devices(), 16);
            assert_eq!(w.n_experts(), 16);
        }
    }

    #[test]
    fn fig3_skew_top3_over_half() {
        // Paper Fig 3: in most layers the 3 heaviest experts hold >50%.
        let mut g = gen16();
        let layers = g.next_iteration();
        let heavy_layers = layers
            .iter()
            .filter(|w| top_share(&w.distribution(), 3) > 0.5)
            .count();
        assert!(
            heavy_layers >= 9,
            "only {heavy_layers}/12 layers show the paper's skew"
        );
    }

    #[test]
    fn fig3_bottom3_under_5_percent() {
        let mut g = gen16();
        let layers = g.next_iteration();
        for w in &layers {
            let mut d = w.distribution();
            d.sort();
            let total: u64 = d.iter().sum();
            let bottom3: u64 = d.iter().take(3).sum();
            assert!(
                (bottom3 as f64 / total as f64) < 0.08,
                "bottom-3 share too large: {bottom3}/{total}"
            );
        }
    }

    #[test]
    fn fig4_locality_between_adjacent_iterations() {
        let mut g = gen16();
        let mut prev = g.next_iteration();
        for _ in 0..5 {
            let cur = g.next_iteration();
            for (a, b) in prev.iter().zip(&cur) {
                let sim = similarity(&a.distribution(), &b.distribution());
                assert!(sim > 0.85, "adjacent-iteration similarity {sim} too low");
            }
            prev = cur;
        }
    }

    #[test]
    fn distribution_drifts_over_many_iterations() {
        let mut cfg = WorkloadConfig::paper_default(1, 16, 16, 16384);
        cfg.drift = 0.15;
        let mut g = WorkloadGen::new(cfg);
        let first = g.next_iteration()[0].distribution();
        for _ in 0..60 {
            g.next_iteration();
        }
        let late = g.next_iteration()[0].distribution();
        let sim = similarity(&first, &late);
        assert!(sim < 0.9, "distribution should drift over 60 iters: {sim}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadGen::new(WorkloadConfig::paper_default(2, 8, 8, 4096))
            .next_iteration();
        let b = WorkloadGen::new(WorkloadConfig::paper_default(2, 8, 8, 4096))
            .next_iteration();
        assert_eq!(a, b);
    }

    #[test]
    fn zero_drift_freezes_popularity() {
        let mut cfg = WorkloadConfig::paper_default(1, 8, 8, 100_000);
        cfg.drift = 0.0;
        let mut g = WorkloadGen::new(cfg);
        let d1 = g.next_iteration()[0].distribution();
        for _ in 0..20 {
            g.next_iteration();
        }
        let d2 = g.next_iteration()[0].distribution();
        // Frozen popularity: only multinomial + device-jitter noise remains.
        assert!(similarity(&d1, &d2) > 0.93, "{}", similarity(&d1, &d2));
    }

    #[test]
    fn top_share_edges() {
        assert_eq!(top_share(&[0, 0], 1), 0.0);
        assert!((top_share(&[10, 10], 2) - 1.0).abs() < 1e-12);
        assert!((top_share(&[30, 10], 1) - 0.75).abs() < 1e-12);
    }
}
