//! Trace record / replay: a training run's per-iteration, per-layer load
//! matrices, serializable to a compact text format so real traces captured
//! by the trainer can drive the simulator and benches.
//!
//! Format (line-oriented, `#` comments):
//! ```text
//! trace v1 layers=12 devices=16 experts=16
//! iter 0 layer 0
//! 12 3 0 7 ...            # one row per device: tokens per expert
//! ```

use crate::moe::LoadMatrix;
use std::path::Path;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub n_layers: usize,
    pub n_devices: usize,
    pub n_experts: usize,
    /// iterations[i][l] = load matrix of layer l at iteration i.
    pub iterations: Vec<Vec<LoadMatrix>>,
}

impl Trace {
    pub fn new(n_layers: usize, n_devices: usize, n_experts: usize) -> Self {
        Trace { n_layers, n_devices, n_experts, iterations: vec![] }
    }

    /// Record one iteration (must contain n_layers matrices).
    pub fn push(&mut self, layers: Vec<LoadMatrix>) {
        assert_eq!(layers.len(), self.n_layers);
        for w in &layers {
            assert_eq!(w.n_devices(), self.n_devices);
            assert_eq!(w.n_experts(), self.n_experts);
        }
        self.iterations.push(layers);
    }

    pub fn len(&self) -> usize {
        self.iterations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.iterations.is_empty()
    }

    /// Capture `iters` iterations from a generator.
    pub fn capture(gen: &mut super::WorkloadGen, iters: usize) -> Trace {
        let cfg = gen.cfg().clone();
        let mut t = Trace::new(cfg.n_layers, cfg.n_devices, cfg.n_experts);
        for _ in 0..iters {
            t.push(gen.next_iteration());
        }
        t
    }

    pub fn serialize(&self) -> String {
        let mut out = format!(
            "trace v1 layers={} devices={} experts={}\n",
            self.n_layers, self.n_devices, self.n_experts
        );
        for (i, layers) in self.iterations.iter().enumerate() {
            for (l, w) in layers.iter().enumerate() {
                out.push_str(&format!("iter {i} layer {l}\n"));
                for d in 0..self.n_devices {
                    let row: Vec<String> = (0..self.n_experts)
                        .map(|e| w.get(d, e).to_string())
                        .collect();
                    out.push_str(&row.join(" "));
                    out.push('\n');
                }
            }
        }
        out
    }

    pub fn deserialize(text: &str) -> Result<Trace, String> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().ok_or("empty trace")?;
        let mut n_layers = 0;
        let mut n_devices = 0;
        let mut n_experts = 0;
        if !header.starts_with("trace v1") {
            return Err("bad trace header".into());
        }
        for part in header.split_whitespace() {
            if let Some(v) = part.strip_prefix("layers=") {
                n_layers = v.parse().map_err(|_| "bad layers")?;
            } else if let Some(v) = part.strip_prefix("devices=") {
                n_devices = v.parse().map_err(|_| "bad devices")?;
            } else if let Some(v) = part.strip_prefix("experts=") {
                n_experts = v.parse().map_err(|_| "bad experts")?;
            }
        }
        if n_layers == 0 || n_devices == 0 || n_experts == 0 {
            return Err("incomplete trace header".into());
        }
        let mut trace = Trace::new(n_layers, n_devices, n_experts);
        let mut current: Vec<LoadMatrix> = Vec::new();
        let mut lines = lines.peekable();
        while let Some(line) = lines.next() {
            if !line.starts_with("iter ") {
                return Err(format!("expected iter header, got {line:?}"));
            }
            let mut w = LoadMatrix::zeros(n_devices, n_experts);
            for d in 0..n_devices {
                let row = lines.next().ok_or("truncated matrix")?;
                let vals: Result<Vec<u64>, _> =
                    row.split_whitespace().map(str::parse).collect();
                let vals = vals.map_err(|_| format!("bad row {row:?}"))?;
                if vals.len() != n_experts {
                    return Err(format!("row has {} values, want {n_experts}", vals.len()));
                }
                for (e, v) in vals.into_iter().enumerate() {
                    w.set(d, e, v);
                }
            }
            current.push(w);
            if current.len() == n_layers {
                trace.push(std::mem::take(&mut current));
            }
        }
        if !current.is_empty() {
            return Err("trailing partial iteration".into());
        }
        Ok(trace)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.serialize())
    }

    pub fn load(path: &Path) -> Result<Trace, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read trace: {e}"))?;
        Self::deserialize(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadConfig, WorkloadGen};

    fn small_trace() -> Trace {
        let mut gen =
            WorkloadGen::new(WorkloadConfig::paper_default(2, 4, 4, 1024));
        Trace::capture(&mut gen, 3)
    }

    #[test]
    fn capture_shape() {
        let t = small_trace();
        assert_eq!(t.len(), 3);
        assert_eq!(t.iterations[0].len(), 2);
    }

    #[test]
    fn serialize_roundtrip() {
        let t = small_trace();
        let text = t.serialize();
        let back = Trace::deserialize(&text).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn file_roundtrip() {
        let t = small_trace();
        let path = std::env::temp_dir().join("pro_prophet_trace_test.txt");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(Trace::deserialize("").is_err());
        assert!(Trace::deserialize("trace v2 layers=1 devices=1 experts=1").is_err());
        assert!(Trace::deserialize("not a trace").is_err());
        // Truncated body.
        let t = small_trace();
        let text = t.serialize();
        let cut = &text[..text.len() / 2];
        assert!(Trace::deserialize(cut).is_err());
    }

    #[test]
    #[should_panic]
    fn push_validates_shape() {
        let mut t = Trace::new(2, 4, 4);
        t.push(vec![LoadMatrix::zeros(4, 4)]); // one layer missing
    }
}
