//! Synthetic training corpus for the end-to-end trainer: a seeded Markov
//! chain over the vocabulary with Zipf-distributed transitions.
//!
//! The chain gives the LM real structure to learn (unlike i.i.d. uniform
//! tokens, whose loss floor is log V), so the e2e loss curve in
//! EXPERIMENTS.md demonstrably decreases; and because next-token statistics
//! are position-independent, different batches stress the same experts,
//! producing the routing locality the paper relies on.

use crate::util::rng::Rng;

pub struct Corpus {
    vocab: usize,
    /// transitions[v] = list of (next_token, cum_prob) pairs.
    transitions: Vec<Vec<(u32, f64)>>,
    state: u32,
    rng: Rng,
}

impl Corpus {
    /// `branching`: candidate successors per token (smaller = more
    /// predictable = faster-dropping loss).
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Self {
        assert!(vocab >= 2);
        let branching = branching.clamp(1, vocab);
        let mut rng = Rng::new(seed);
        let mut transitions = Vec::with_capacity(vocab);
        for v in 0..vocab {
            let mut tr = rng.split(v as u64 + 0x5EED);
            // Zipf-weighted choice among `branching` random successors.
            let mut succ: Vec<u32> = (0..branching)
                .map(|_| tr.below(vocab) as u32)
                .collect();
            succ.dedup();
            let h: f64 = (1..=succ.len()).map(|k| 1.0 / k as f64).sum();
            let mut cum = 0.0;
            let pairs: Vec<(u32, f64)> = succ
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    cum += (1.0 / (i + 1) as f64) / h;
                    (s, cum)
                })
                .collect();
            transitions.push(pairs);
        }
        Corpus { vocab, transitions, state: 0, rng }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_token(&mut self) -> u32 {
        let u = self.rng.f64();
        let row = &self.transitions[self.state as usize];
        let next = row
            .iter()
            .find(|&&(_, c)| u <= c)
            .map(|&(t, _)| t)
            .unwrap_or(row.last().map(|&(t, _)| t).unwrap_or(0));
        self.state = next;
        next
    }

    /// Sample a (batch, seq_len) token matrix, flattened row-major i32
    /// (the dtype the train_step artifact expects).
    pub fn batch(&mut self, batch: usize, seq_len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            // Random restart per sequence to decorrelate rows.
            self.state = self.rng.below(self.vocab) as u32;
            for _ in 0..seq_len {
                out.push(self.next_token() as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = Corpus::new(64, 4, 1);
        let b = c.batch(8, 32);
        assert_eq!(b.len(), 256);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::new(128, 4, 7).batch(2, 16);
        let b = Corpus::new(128, 4, 7).batch(2, 16);
        assert_eq!(a, b);
        let c = Corpus::new(128, 4, 8).batch(2, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn chain_is_predictable() {
        // With branching 2 the bigram entropy is far below log2(V):
        // successors must repeat.
        let mut c = Corpus::new(256, 2, 3);
        let toks = c.batch(1, 4096);
        let mut bigrams = std::collections::HashSet::new();
        for w in toks.windows(2) {
            bigrams.insert((w[0], w[1]));
        }
        // Random tokens would give ~4095 distinct bigrams; a 2-branching
        // chain over <=256 states gives at most ~512.
        assert!(bigrams.len() < 600, "bigrams: {}", bigrams.len());
    }

    #[test]
    fn zipf_biases_first_successor() {
        let mut c = Corpus::new(32, 4, 5);
        let toks = c.batch(1, 8192);
        // The most common successor of each token should dominate.
        let mut follow: std::collections::HashMap<i32, std::collections::HashMap<i32, usize>> =
            Default::default();
        for w in toks.windows(2) {
            *follow.entry(w[0]).or_default().entry(w[1]).or_default() += 1;
        }
        let mut dominant = 0;
        let mut total = 0;
        for (_, succ) in follow {
            let sum: usize = succ.values().sum();
            if sum < 20 {
                continue;
            }
            let max = succ.values().max().copied().unwrap_or(0);
            dominant += max;
            total += sum;
        }
        assert!(dominant as f64 / total as f64 > 0.4);
    }
}
