//! Seeded arrival processes for inference traffic (fleet tenants).
//!
//! Two request-arrival shapes drive [`crate::fleet`]'s `InferenceJob`s:
//!
//! * [`ArrivalProcess::Poisson`] — a memoryless stream at a constant
//!   mean rate (requests per fleet tick), the classic open-loop serving
//!   load.
//! * [`ArrivalProcess::OnOffBursty`] — a deterministic ON/OFF phase
//!   cycle modulating a Poisson stream: `burst_factor`× the base rate
//!   while ON, the bare base rate while OFF.  This is the bursty
//!   diurnal/batch-upload traffic shape that makes lease rebalancing
//!   worth having — sustained ON phases push queue depth (and the
//!   replica-demand signal) up, OFF phases let it drain.
//!
//! Determinism contract: an [`ArrivalGen`] is a pure function of
//! `(process, seed)` — same seed, same per-tick arrival counts, on every
//! machine and every run (the repo's portable xoshiro PRNG underneath).
//! The phase clock is the generator's own tick counter, so interleaving
//! with other jobs cannot shift a job's burst windows.

use crate::util::rng::Rng;

/// The arrival-count distribution of one job's request stream.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate` requests per tick.
    Poisson { rate: f64 },
    /// Poisson arrivals whose rate cycles deterministically between
    /// `rate * burst_factor` (for `on_ticks`) and `rate` (for
    /// `off_ticks`), starting in the ON phase.
    OnOffBursty { rate: f64, on_ticks: usize, off_ticks: usize, burst_factor: f64 },
}

impl ArrivalProcess {
    /// Mean rate at phase-clock position `tick`.
    pub fn rate_at(&self, tick: usize) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::OnOffBursty { rate, on_ticks, off_ticks, burst_factor } => {
                let period = (on_ticks + off_ticks).max(1);
                if tick % period < on_ticks {
                    rate * burst_factor
                } else {
                    rate
                }
            }
        }
    }

    /// Whether `tick` falls in an ON window (always true for Poisson —
    /// a constant-rate stream is "always on").
    pub fn is_on(&self, tick: usize) -> bool {
        match *self {
            ArrivalProcess::Poisson { .. } => true,
            ArrivalProcess::OnOffBursty { on_ticks, off_ticks, .. } => {
                tick % (on_ticks + off_ticks).max(1) < on_ticks
            }
        }
    }

    /// Long-run mean requests per tick (admission sizing, reports).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::OnOffBursty { rate, on_ticks, off_ticks, burst_factor } => {
                let period = (on_ticks + off_ticks).max(1) as f64;
                rate * (on_ticks as f64 * burst_factor + off_ticks as f64) / period
            }
        }
    }

    /// Validate the knobs (rates finite and >= 0, a non-degenerate
    /// phase cycle, burst_factor >= 1 so ON means MORE traffic).
    pub fn validate(&self) -> Result<(), String> {
        let rate = match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::OnOffBursty { rate, on_ticks, off_ticks, burst_factor } => {
                if on_ticks == 0 && off_ticks == 0 {
                    return Err("on/off cycle needs at least one tick".into());
                }
                if !(burst_factor.is_finite() && burst_factor >= 1.0) {
                    return Err(format!("burst factor must be >= 1, got {burst_factor}"));
                }
                rate
            }
        };
        if !(rate.is_finite() && rate >= 0.0) {
            return Err(format!("arrival rate must be finite and >= 0, got {rate}"));
        }
        Ok(())
    }
}

/// Stateful, seeded arrival generator: one [`ArrivalProcess`] plus its
/// own phase clock and PRNG stream.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    tick: usize,
}

impl ArrivalGen {
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        ArrivalGen { process, rng: Rng::new(seed), tick: 0 }
    }

    pub fn process(&self) -> &ArrivalProcess {
        &self.process
    }

    /// Ticks generated so far (the phase-clock position).
    pub fn tick(&self) -> usize {
        self.tick
    }

    /// Number of requests arriving in the next tick.
    pub fn next_tick(&mut self) -> u64 {
        let lambda = self.process.rate_at(self.tick);
        self.tick += 1;
        poisson(&mut self.rng, lambda)
    }
}

/// One Poisson draw.  Knuth's product-of-uniforms for small λ; for large
/// λ (where that loop degrades and floating-point underflows), the
/// normal approximation N(λ, λ) clamped at zero — both deterministic
/// per RNG state.
fn poisson(rng: &mut Rng, lambda: f64) -> u64 {
    if !(lambda > 0.0) {
        return 0;
    }
    if lambda < 30.0 {
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }
    let draw = lambda + lambda.sqrt() * rng.normal();
    if draw <= 0.0 {
        0
    } else {
        draw.round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let mut a = ArrivalGen::new(ArrivalProcess::Poisson { rate: 3.5 }, 7);
        let mut b = ArrivalGen::new(ArrivalProcess::Poisson { rate: 3.5 }, 7);
        let xs: Vec<u64> = (0..64).map(|_| a.next_tick()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_tick()).collect();
        assert_eq!(xs, ys);
        let mut c = ArrivalGen::new(ArrivalProcess::Poisson { rate: 3.5 }, 8);
        let zs: Vec<u64> = (0..64).map(|_| c.next_tick()).collect();
        assert_ne!(xs, zs, "different seeds should differ somewhere");
    }

    #[test]
    fn poisson_mean_tracks_rate() {
        for rate in [0.5, 4.0, 80.0] {
            let mut g = ArrivalGen::new(ArrivalProcess::Poisson { rate }, 42);
            let n = 4000;
            let total: u64 = (0..n).map(|_| g.next_tick()).collect::<Vec<_>>().iter().sum();
            let mean = total as f64 / n as f64;
            // Loose 3σ-ish bound: σ/√n = sqrt(rate/n).
            let tol = 4.0 * (rate / n as f64).sqrt() + 0.02;
            assert!(
                (mean - rate).abs() < tol,
                "rate {rate}: sample mean {mean} off by more than {tol}"
            );
        }
    }

    #[test]
    fn zero_rate_never_arrives() {
        let mut g = ArrivalGen::new(ArrivalProcess::Poisson { rate: 0.0 }, 1);
        assert!((0..32).all(|_| g.next_tick() == 0));
    }

    #[test]
    fn bursty_phases_cycle_deterministically() {
        let p = ArrivalProcess::OnOffBursty {
            rate: 2.0,
            on_ticks: 3,
            off_ticks: 5,
            burst_factor: 4.0,
        };
        assert!(p.validate().is_ok());
        for t in 0..16 {
            assert_eq!(p.is_on(t), t % 8 < 3, "tick {t}");
            assert_eq!(p.rate_at(t), if t % 8 < 3 { 8.0 } else { 2.0 });
        }
        let period_mean = (3.0 * 8.0 + 5.0 * 2.0) / 8.0;
        assert!((p.mean_rate() - period_mean).abs() < 1e-12);
    }

    #[test]
    fn bursty_on_phase_actually_bursts() {
        let p = ArrivalProcess::OnOffBursty {
            rate: 2.0,
            on_ticks: 4,
            off_ticks: 4,
            burst_factor: 6.0,
        };
        let mut g = ArrivalGen::new(p, 9);
        let (mut on_total, mut on_n, mut off_total, mut off_n) = (0u64, 0u64, 0u64, 0u64);
        for t in 0..4096 {
            let x = g.next_tick();
            if t % 8 < 4 {
                on_total += x;
                on_n += 1;
            } else {
                off_total += x;
                off_n += 1;
            }
        }
        let on_mean = on_total as f64 / on_n as f64;
        let off_mean = off_total as f64 / off_n as f64;
        assert!(
            on_mean > 3.0 * off_mean,
            "ON mean {on_mean} should dwarf OFF mean {off_mean}"
        );
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(ArrivalProcess::Poisson { rate: -1.0 }.validate().is_err());
        assert!(ArrivalProcess::Poisson { rate: f64::NAN }.validate().is_err());
        let bad_cycle = ArrivalProcess::OnOffBursty {
            rate: 1.0,
            on_ticks: 0,
            off_ticks: 0,
            burst_factor: 2.0,
        };
        assert!(bad_cycle.validate().is_err());
        let weak_burst = ArrivalProcess::OnOffBursty {
            rate: 1.0,
            on_ticks: 1,
            off_ticks: 1,
            burst_factor: 0.5,
        };
        assert!(weak_burst.validate().is_err());
    }
}
