//! Artifact manifest: the JSON inventory aot.py writes next to the HLO
//! files, describing the model config, the flat tensor layout of the
//! train-step interface, and which artifacts exist.

use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `{preset}_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    // Model config (mirrors python ModelConfig).
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_experts: usize,
    pub k: usize,
    pub capacity: usize,
    pub batch: usize,
    pub tokens_per_step: usize,
    pub num_tensors: usize,
    pub num_params: usize,
    pub tensors: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path, preset: &str) -> Result<Manifest> {
        let path = dir.join(format!("{preset}_manifest.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let cfg = v.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let get = |key: &str| -> Result<usize> {
            cfg.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest config missing {key}"))
        };
        let tensors = v
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing tensors"))?
            .iter()
            .map(|t| -> Result<TensorSpec> {
                Ok(TensorSpec {
                    name: t
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("tensor missing name"))?
                        .to_string(),
                    shape: t
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("tensor missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|(k, val)| {
                Ok((
                    k.clone(),
                    val.as_str()
                        .ok_or_else(|| anyhow!("artifact path not a string"))?
                        .to_string(),
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        let m = Manifest {
            preset: v
                .get("preset")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            dir: dir.to_path_buf(),
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            d_model: get("d_model")?,
            d_ff: get("d_ff")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_experts: get("n_experts")?,
            k: get("k")?,
            capacity: get("capacity")?,
            batch: get("batch")?,
            tokens_per_step: get("tokens_per_step")?,
            num_tensors: get("num_tensors")?,
            num_params: get("num_params")?,
            tensors,
            artifacts,
        };
        if m.tensors.len() != m.num_tensors {
            return Err(anyhow!(
                "manifest inconsistent: {} tensor specs, num_tensors={}",
                m.tensors.len(),
                m.num_tensors
            ));
        }
        Ok(m)
    }

    pub fn artifact_path(&self, tag: &str) -> Result<PathBuf> {
        let fname = self
            .artifacts
            .get(tag)
            .ok_or_else(|| anyhow!("manifest has no artifact {tag:?}"))?;
        Ok(self.dir.join(fname))
    }

    /// Train-step input arity: 3 * num_tensors (params, m, v) + step + tokens.
    pub fn train_step_inputs(&self) -> usize {
        3 * self.num_tensors + 2
    }

    /// Train-step output arity: 3 * num_tensors + loss + loads.
    pub fn train_step_outputs(&self) -> usize {
        3 * self.num_tensors + 2
    }

    /// Flat index of a layer tensor by suffix name, e.g. (0, "gate_w").
    pub fn layer_tensor_index(&self, layer: usize, suffix: &str) -> Option<usize> {
        let want = format!("l{layer}.{suffix}");
        self.tensors.iter().position(|t| t.name == want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "preset": "tiny",
          "config": {"vocab": 64, "seq_len": 16, "d_model": 32, "d_ff": 64,
                     "n_layers": 2, "n_heads": 2, "n_experts": 4, "k": 2,
                     "capacity": 48, "capacity_factor": 1.5, "batch": 4,
                     "lr": 0.001, "tokens_per_step": 64, "num_tensors": 30,
                     "num_params": 12345},
          "tensors": [REPLACED],
          "artifacts": {"train_step": "tiny_train_step.hlo.txt"}
        }"#
        .replace(
            "REPLACED",
            &(0..30)
                .map(|i| {
                    if i == 11 {
                        r#"{"name": "l0.w1", "shape": [4, 32, 64]}"#.to_string()
                    } else {
                        format!(r#"{{"name": "t{i}", "shape": [2, 3]}}"#)
                    }
                })
                .collect::<Vec<_>>()
                .join(","),
        )
    }

    #[test]
    fn parses_sample() {
        let v = json::parse(&sample_json()).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/arts")).unwrap();
        assert_eq!(m.n_experts, 4);
        assert_eq!(m.tensors.len(), 30);
        assert_eq!(m.tensors[11].numel(), 4 * 32 * 64);
        assert_eq!(m.train_step_inputs(), 92);
        assert_eq!(
            m.artifact_path("train_step").unwrap(),
            PathBuf::from("/tmp/arts/tiny_train_step.hlo.txt")
        );
        assert!(m.artifact_path("nope").is_err());
        assert_eq!(m.layer_tensor_index(0, "w1"), Some(11));
        assert_eq!(m.layer_tensor_index(9, "w1"), None);
    }

    #[test]
    fn rejects_inconsistent_tensor_count() {
        let bad = sample_json().replace("\"num_tensors\": 30", "\"num_tensors\": 31");
        let v = json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_missing_config() {
        let v = json::parse(r#"{"tensors": [], "artifacts": {}}"#).unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }
}
