//! PJRT runtime: loads the AOT'd HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes them on the CPU
//! PJRT client.  This is the ONLY bridge between the rust coordinator and
//! the JAX/Pallas layers — python never runs here.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! >= 0.5 emits 64-bit instruction ids in serialized protos which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;

pub use manifest::{Manifest, TensorSpec};

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT client plus artifact loading.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_artifact(&self, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        Ok(Artifact {
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    /// Load an artifact by manifest tag (e.g. "train_step").
    pub fn load_tagged(&self, man: &Manifest, tag: &str) -> Result<Artifact> {
        let path = man.artifact_path(tag)?;
        self.load_artifact(&path)
    }
}

/// One compiled executable.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host literals; the artifact's tuple result is
    /// decomposed into its elements (aot.py lowers with
    /// `return_tuple=True`, so outputs are always a single tuple).
    /// Accepts owned or borrowed literals, so large model state can be
    /// passed by reference without deep-copying.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let outputs = self
            .exe
            .execute(inputs)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let buffer = outputs
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let lit = buffer
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {}: {e}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e}", self.name))
    }
}

// --- literal construction helpers -----------------------------------------

/// f32 literal of the given shape from a flat row-major slice.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {n} values, got {}", dims, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// i32 literal of the given shape.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {n} values, got {}", dims, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape: {e}"))
}

pub fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn i32_scalar(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a literal's data as f32 vec.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

/// Scalar f32 from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e}"))
}

/// Locate the artifacts directory: $PRO_PROPHET_ARTIFACTS, ./artifacts, or
/// parent dirs relative to the cwd (so tests work from any location).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PRO_PROPHET_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// True if the given preset's artifacts have been built.
pub fn artifacts_available(preset: &str) -> bool {
    artifacts_dir().join(format!("{preset}_manifest.json")).is_file()
}

/// Load a manifest from the default artifacts dir.
pub fn load_manifest(preset: &str) -> Result<Manifest> {
    Manifest::load(&artifacts_dir(), preset)
        .with_context(|| format!("run `make artifacts` first (preset {preset})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_validate_shape() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let back = to_f32_vec(&l).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn i32_literal_roundtrip() {
        let l = i32_literal(&[5, -3, 7, 0, 1, 2], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -3, 7, 0, 1, 2]);
        assert!(i32_literal(&[1], &[2]).is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(scalar_f32(&f32_scalar(2.5)).unwrap(), 2.5);
    }
}
