//! The four original policies as [`BalancingPolicy`] impls: Deepspeed-MoE,
//! FasterMoE, static top-k, and Pro-Prophet itself.
//!
//! The placement *algorithms* stay in [`crate::planner`] (the greedy
//! search and the baseline placement constructions of
//! `planner::policies`); this module only adapts them to the
//! [`Decision`]/session contract.  The golden equivalence test pins each
//! impl bit-for-bit to its pre-refactor enum arm (frozen in
//! `sim::reference`).

use super::{
    BalancingPolicy, CommStyle, DecideCtx, Decision, LayerFeedback, PolicyCounters,
    ProphetOptions, ScheduleKind,
};
use crate::moe::{LoadMatrix, Placement};
use crate::obs::{Labels, Span};
use crate::planner::{policies, Planner};
use crate::prophet::ProphetConfig;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Deepspeed-MoE: pure expert parallelism, no load balancing at all.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeepspeedMoe;

impl BalancingPolicy for DeepspeedMoe {
    fn name(&self) -> String {
        "Deepspeed-MoE".into()
    }

    fn bind(&mut self, _n_layers: usize) {}

    fn decide(&self, _layer: usize, w: &LoadMatrix, _ctx: &DecideCtx<'_>) -> Decision {
        Decision {
            placement: Arc::new(Placement::identity(w.n_experts(), w.n_devices())),
            plan_cost: 0.0,
            comm_style: CommStyle::Pipelined,
            schedule_kind: ScheduleKind::NoLoadBalance,
        }
    }
}

/// FasterMoE: dynamic shadowing to ALL devices, decided on the CURRENT
/// iteration's gating (no locality prediction), paying its search and a
/// coarse blocking broadcast every iteration.
#[derive(Debug, Default)]
pub struct FasterMoe {
    plans: AtomicUsize,
}

impl FasterMoe {
    pub fn new() -> Self {
        FasterMoe::default()
    }
}

impl BalancingPolicy for FasterMoe {
    fn name(&self) -> String {
        "FasterMoE".into()
    }

    fn bind(&mut self, _n_layers: usize) {}

    fn decide(&self, _layer: usize, w: &LoadMatrix, ctx: &DecideCtx<'_>) -> Decision {
        self.plans.fetch_add(1, Ordering::Relaxed);
        Decision {
            placement: Arc::new(policies::fastermoe_shadowing(w, ctx.pm)),
            plan_cost: ctx.pm.t_plan,
            comm_style: CommStyle::Coarse,
            schedule_kind: ScheduleKind::Blocking,
        }
    }

    fn counters(&self) -> PolicyCounters {
        PolicyCounters {
            plans_run: self.plans.load(Ordering::Relaxed),
            ..Default::default()
        }
    }
}

/// Replicate the k heaviest experts to all devices (Fig 15 top2/top3):
/// a topk() on the load vector, negligible decision cost, coarse
/// broadcast transfer.
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k }
    }
}

impl BalancingPolicy for TopK {
    fn name(&self) -> String {
        format!("top{}", self.k)
    }

    fn bind(&mut self, _n_layers: usize) {}

    fn decide(&self, _layer: usize, w: &LoadMatrix, _ctx: &DecideCtx<'_>) -> Decision {
        Decision {
            placement: Arc::new(policies::top_k_to_all(w, self.k)),
            plan_cost: 0.0,
            comm_style: CommStyle::Coarse,
            schedule_kind: ScheduleKind::Blocking,
        }
    }
}

/// Pro-Prophet: per-layer locality-aware planners fed by the session's
/// shared prophet — plan on the forecast of THIS iteration when one is
/// outstanding (§V-A: the Plan primitive runs one iteration early on
/// predicted statistics), warm up on the observed matrix, and let drift
/// detection invalidate stale cached placements.
#[derive(Debug)]
pub struct ProProphet {
    pub opts: ProphetOptions,
    /// One planner per MoE layer, behind a per-layer lock so `decide`
    /// can fan out across layers with `&self` (each lock is only ever
    /// taken by its own layer's thread — uncontended).
    planners: Vec<Mutex<Planner>>,
    drift_replans: usize,
}

impl ProProphet {
    pub fn new(opts: ProphetOptions) -> Self {
        ProProphet { opts, planners: Vec::new(), drift_replans: 0 }
    }
}

impl BalancingPolicy for ProProphet {
    fn name(&self) -> String {
        if self.opts.scheduler_on && self.opts.relaxed_dag {
            "Pro-Prophet(dag)".into()
        } else if self.opts.scheduler_on && self.opts.planner.use_overlap_model {
            "Pro-Prophet".into()
        } else if self.opts.scheduler_on {
            "Pro-Prophet(no-comb)".into()
        } else {
            "Pro-Prophet(planner)".into()
        }
    }

    fn bind(&mut self, n_layers: usize) {
        self.planners =
            (0..n_layers).map(|_| Mutex::new(Planner::new(self.opts.planner.clone()))).collect();
    }

    fn prophet_config(&self) -> Option<ProphetConfig> {
        Some(self.opts.prophet.clone())
    }

    fn decide(&self, layer: usize, w: &LoadMatrix, ctx: &DecideCtx<'_>) -> Decision {
        let mut planner = self
            .planners
            .get(layer)
            .expect("ProProphet::decide before bind()")
            .lock()
            .expect("planner lock poisoned");
        let forecast = {
            let _sp = Span::enter(ctx.rec, "prophet.forecast", Labels::None);
            ctx.prophet.and_then(|p| p.forecast_matrix(layer))
        };
        let w_plan: &LoadMatrix = forecast.as_ref().unwrap_or(w);
        let before = planner.plans_run;
        let candidates_before = planner.candidates_evaluated;
        let search_seconds_before = planner.search_seconds;
        let placement = planner.plan(w_plan, ctx.pm);
        let plan_cost = if planner.plans_run > before { ctx.pm.t_plan } else { 0.0 };
        if ctx.rec.enabled() {
            if planner.plans_run > before {
                // The planner already times its own searches; forward the
                // exact increment as a greedy-search span sample.
                ctx.rec.observe(
                    "plan.greedy_search",
                    Labels::None,
                    planner.search_seconds - search_seconds_before,
                );
                ctx.rec.counter("plan.searches", Labels::None, 1);
                ctx.rec.counter(
                    "plan.candidates",
                    Labels::None,
                    (planner.candidates_evaluated - candidates_before) as u64,
                );
            } else {
                ctx.rec.counter("plan.cache_hits", Labels::None, 1);
            }
        }
        Decision {
            placement,
            plan_cost,
            comm_style: CommStyle::Pipelined,
            schedule_kind: if !self.opts.scheduler_on {
                ScheduleKind::Blocking
            } else if self.opts.relaxed_dag {
                ScheduleKind::DagRelaxed
            } else {
                ScheduleKind::Blockwise
            },
        }
    }

    fn observe(&mut self, layer: usize, _w: &LoadMatrix, fb: &LayerFeedback) {
        if fb.drift {
            self.planners[layer].lock().expect("planner lock poisoned").invalidate();
            self.drift_replans += 1;
        }
    }

    fn counters(&self) -> PolicyCounters {
        let mut c = PolicyCounters { drift_replans: self.drift_replans, ..Default::default() };
        for planner in &self.planners {
            let p = planner.lock().expect("planner lock poisoned");
            c.plans_run += p.plans_run;
            c.plans_reused += p.plans_reused;
        }
        c
    }

    fn set_device_mask(&mut self, down: &[bool]) {
        // Mask future searches off the down devices and drop every cached
        // placement: the next decide replans under the new health state
        // (recovery passes an all-false mask, so placements re-expand).
        let mask = if down.iter().any(|&d| d) { Some(down.to_vec()) } else { None };
        for planner in &self.planners {
            let mut p = planner.lock().expect("planner lock poisoned");
            p.cfg.device_mask = mask.clone();
            p.invalidate();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;
    use crate::perfmodel::PerfModel;

    fn skewed_w() -> LoadMatrix {
        LoadMatrix::from_rows(vec![vec![600, 100, 100, 224]; 4])
    }

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &ClusterSpec::hpwnv(1))
    }

    #[test]
    fn deepspeed_decides_identity_for_free() {
        let mut p = DeepspeedMoe;
        p.bind(1);
        let pm = pm();
        let d = p.decide(0, &skewed_w(), &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert!(d.placement.is_identity());
        assert_eq!(d.plan_cost, 0.0);
        assert_eq!(d.schedule_kind, ScheduleKind::NoLoadBalance);
        assert_eq!(p.counters(), PolicyCounters::default());
    }

    #[test]
    fn fastermoe_pays_search_every_decide() {
        let mut p = FasterMoe::new();
        p.bind(1);
        let pm = pm();
        let w = skewed_w();
        for _ in 0..3 {
            let d = p.decide(0, &w, &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
            assert_eq!(d.plan_cost, pm.t_plan);
            assert_eq!(d.comm_style, CommStyle::Coarse);
        }
        assert_eq!(p.counters().plans_run, 3);
    }

    #[test]
    fn topk_matches_algorithm() {
        let mut p = TopK::new(2);
        p.bind(1);
        let pm = pm();
        let w = skewed_w();
        let d = p.decide(0, &w, &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert_eq!(*d.placement, policies::top_k_to_all(&w, 2));
        assert_eq!(p.name(), "top2");
    }

    #[test]
    fn pro_prophet_dag_variant_decides_dag_relaxed() {
        let mut p = ProProphet::new(ProphetOptions::dag());
        p.bind(1);
        let pm = pm();
        let d = p.decide(0, &skewed_w(), &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert_eq!(d.schedule_kind, ScheduleKind::DagRelaxed);
        assert_eq!(d.comm_style, CommStyle::Pipelined);
        // Ablating the scheduler off wins over the relaxed-DAG flag.
        let mut off = ProProphet::new(ProphetOptions {
            scheduler_on: false,
            ..ProphetOptions::dag()
        });
        off.bind(1);
        let d = off.decide(0, &skewed_w(), &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert_eq!(d.schedule_kind, ScheduleKind::Blocking);
    }

    #[test]
    fn pro_prophet_names_track_ablation() {
        assert_eq!(ProProphet::new(ProphetOptions::full()).name(), "Pro-Prophet");
        assert_eq!(ProProphet::new(ProphetOptions::dag()).name(), "Pro-Prophet(dag)");
        assert_eq!(
            ProProphet::new(ProphetOptions::without_combination()).name(),
            "Pro-Prophet(no-comb)"
        );
        assert_eq!(
            ProProphet::new(ProphetOptions::planner_only()).name(),
            "Pro-Prophet(planner)"
        );
    }

    #[test]
    fn pro_prophet_device_mask_replans_off_down_devices() {
        let mut p = ProProphet::new(ProphetOptions {
            planner: crate::planner::PlannerConfig {
                replan_interval: 100,
                ..Default::default()
            },
            ..Default::default()
        });
        p.bind(1);
        let pm = pm();
        let w = skewed_w();
        let ctx = DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() };
        let d1 = p.decide(0, &w, &ctx);
        assert_eq!(d1.plan_cost, pm.t_plan);
        // Device 2 goes down: the cache is dropped and the replacement
        // search never widens a replica set onto device 2.
        p.set_device_mask(&[false, false, true, false]);
        let d2 = p.decide(0, &w, &ctx);
        assert_eq!(d2.plan_cost, pm.t_plan, "health transition forces a replan");
        for e in 0..4 {
            for dev in d2.placement.replicas(e).iter() {
                assert!(dev != 2 || d2.placement.home(e) == 2);
            }
        }
        // Recovery drops the mask and replans again, identically to a
        // never-faulted planner.
        p.set_device_mask(&[false; 4]);
        let d3 = p.decide(0, &w, &ctx);
        assert_eq!(d3.plan_cost, pm.t_plan);
        assert_eq!(*d3.placement, *d1.placement);
    }

    #[test]
    fn pro_prophet_caches_and_invalidates() {
        let mut p = ProProphet::new(ProphetOptions {
            planner: crate::planner::PlannerConfig {
                replan_interval: 100,
                ..Default::default()
            },
            ..Default::default()
        });
        p.bind(1);
        let pm = pm();
        let w = skewed_w();
        let ctx = DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() };
        let d1 = p.decide(0, &w, &ctx);
        assert_eq!(d1.plan_cost, pm.t_plan, "first decision runs the search");
        let d2 = p.decide(0, &w, &ctx);
        assert_eq!(d2.plan_cost, 0.0, "second decision reuses the cache");
        assert_eq!(p.counters().plans_run, 1);
        assert_eq!(p.counters().plans_reused, 1);
        // Drift feedback invalidates the cached placement.
        p.observe(0, &w, &LayerFeedback { drift: true, forecast_error: Some(0.9) });
        let d3 = p.decide(0, &w, &ctx);
        assert_eq!(d3.plan_cost, pm.t_plan, "drift forces a replan");
        assert_eq!(p.counters().drift_replans, 1);
        assert_eq!(p.counters().plans_run, 2);
    }
}
