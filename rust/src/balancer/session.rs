//! [`BalancerSession`]: one policy bound to one run.
//!
//! The session owns what is shared per run — the layer count and, for
//! forecasting policies, the [`Prophet`] — and centralizes the
//! observe → score → drift → invalidate loop that `sim::simulate` (phase
//! 2) and `Trainer::step` used to each re-implement.  Drivers call
//! [`BalancerSession::decide_layer`] from their per-layer fan-out (or
//! [`BalancerSession::decide_iteration`] to let the session fan out) and
//! [`BalancerSession::observe_iteration`] once per iteration with the
//! actual gating results.

use super::{BalancingPolicy, DecideCtx, Decision, LayerFeedback, PolicyCounters};
use crate::moe::LoadMatrix;
use crate::obs::{self, Labels, Recorder, Span};
use crate::perfmodel::PerfModel;
use crate::prophet::Prophet;
use crate::util::threads;
use std::sync::Arc;

/// What one iteration's observations told the session, aggregated over
/// layers (in layer order).
#[derive(Clone, Debug, Default)]
pub struct IterationFeedback {
    /// Forecast errors of the layers that had an outstanding forecast.
    pub forecast_errors: Vec<f64>,
    /// Layers whose drift detector fired this iteration.
    pub drift_layers: usize,
}

impl IterationFeedback {
    /// Mean forecast error (None when no layer had a forecast — warm-up
    /// iterations and non-forecasting policies).
    pub fn mean_forecast_error(&self) -> Option<f64> {
        if self.forecast_errors.is_empty() {
            None
        } else {
            Some(self.forecast_errors.iter().sum::<f64>() / self.forecast_errors.len() as f64)
        }
    }
}

/// One [`BalancingPolicy`] bound to one run.
pub struct BalancerSession {
    policy: Box<dyn BalancingPolicy>,
    prophet: Option<Prophet>,
    n_layers: usize,
    iterations_observed: usize,
    rec: Arc<dyn Recorder>,
}

impl BalancerSession {
    /// Bind `policy` to a run over `n_layers` MoE layers; builds the
    /// shared prophet when the policy forecasts.  Telemetry stays off
    /// (the zero-cost no-op recorder); see
    /// [`BalancerSession::with_recorder`].
    pub fn new(policy: Box<dyn BalancingPolicy>, n_layers: usize) -> Self {
        Self::with_recorder(policy, n_layers, obs::noop_arc())
    }

    /// Like [`BalancerSession::new`] with a live telemetry sink: decide
    /// and observe phases are span-timed (`balancer.decide`,
    /// `balancer.observe`, `prophet.observe`), drift firings counted,
    /// and forecast error gauged; the same recorder is served to
    /// policies via [`DecideCtx::rec`].
    pub fn with_recorder(
        mut policy: Box<dyn BalancingPolicy>,
        n_layers: usize,
        rec: Arc<dyn Recorder>,
    ) -> Self {
        assert!(n_layers >= 1, "session needs at least one layer");
        policy.bind(n_layers);
        let prophet = policy.prophet_config().map(|cfg| Prophet::new(cfg, n_layers));
        BalancerSession { policy, prophet, n_layers, iterations_observed: 0, rec }
    }

    /// The session's telemetry sink (the no-op recorder when off).
    pub fn recorder(&self) -> &Arc<dyn Recorder> {
        &self.rec
    }

    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Iterations fed through [`BalancerSession::observe_iteration`].
    pub fn iterations_observed(&self) -> usize {
        self.iterations_observed
    }

    /// The shared forecasting subsystem (None for non-forecasting
    /// policies).
    pub fn prophet(&self) -> Option<&Prophet> {
        self.prophet.as_ref()
    }

    /// Whole-run decision counters.
    pub fn counters(&self) -> PolicyCounters {
        self.policy.counters()
    }

    /// Decide one layer's placement.  `&self`: safe to call from a
    /// per-layer thread fan-out (drivers that also price per layer fold
    /// this into their own [`crate::util::threads::par_map`] closure).
    pub fn decide_layer(&self, layer: usize, w: &LoadMatrix, pm: &PerfModel) -> Decision {
        assert!(layer < self.n_layers, "layer {layer} out of range");
        let _sp = Span::enter(&*self.rec, "balancer.decide", Labels::None);
        let ctx = DecideCtx { pm, prophet: self.prophet.as_ref(), rec: &*self.rec };
        self.policy.decide(layer, w, &ctx)
    }

    /// Decide all layers of one iteration, fanned out over scoped threads
    /// (serial below the [`threads`] work threshold — results identical).
    pub fn decide_iteration(&self, layers: &[LoadMatrix], pm: &PerfModel) -> Vec<Decision> {
        assert_eq!(layers.len(), self.n_layers, "layer count mismatch");
        let work = layers.first().map_or(1, |w| w.n_devices() * w.n_experts());
        threads::par_map(layers.len(), work, |l| self.decide_layer(l, &layers[l], pm))
    }

    /// Feed the ACTUAL gating results of one iteration, in layer order:
    /// scores the outstanding forecasts, advances the history, runs drift
    /// detection, and hands each layer's verdict to the policy (which
    /// reacts by invalidating caches, adjusting placements, ...).
    pub fn observe_iteration(&mut self, layers: &[LoadMatrix]) -> IterationFeedback {
        assert_eq!(layers.len(), self.n_layers, "layer count mismatch");
        let _sp = Span::enter(&*self.rec, "balancer.observe", Labels::None);
        let mut fb = IterationFeedback::default();
        for (l, w) in layers.iter().enumerate() {
            let layer_fb = match self.prophet.as_mut() {
                Some(prophet) => {
                    let _psp = Span::enter(&*self.rec, "prophet.observe", Labels::None);
                    let obs = prophet.observe_layer(l, w);
                    LayerFeedback { drift: obs.drift, forecast_error: obs.forecast_error }
                }
                None => LayerFeedback::default(),
            };
            if layer_fb.drift {
                fb.drift_layers += 1;
            }
            if let Some(e) = layer_fb.forecast_error {
                fb.forecast_errors.push(e);
            }
            self.policy.observe(l, w, &layer_fb);
        }
        self.iterations_observed += 1;
        if self.rec.enabled() {
            self.rec.counter("prophet.drift_layers", Labels::None, fb.drift_layers as u64);
            if let Some(e) = fb.mean_forecast_error() {
                self.rec.gauge("prophet.forecast_error_l1", Labels::None, e);
            }
        }
        fb
    }
}

impl std::fmt::Debug for BalancerSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BalancerSession")
            .field("policy", &self.policy.name())
            .field("n_layers", &self.n_layers)
            .field("forecasting", &self.prophet.is_some())
            .field("iterations_observed", &self.iterations_observed)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{builtin, ProphetOptions};
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;
    use crate::workload::{WorkloadConfig, WorkloadGen};

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(8, 1, 8192), &ClusterSpec::hpwnv(2))
    }

    #[test]
    fn non_forecasting_session_has_no_prophet() {
        let s = BalancerSession::new(Box::new(builtin::DeepspeedMoe), 3);
        assert!(s.prophet().is_none());
        assert_eq!(s.policy_name(), "Deepspeed-MoE");
        assert_eq!(s.n_layers(), 3);
    }

    #[test]
    fn forecasting_session_scores_and_feeds_back() {
        let mut s = BalancerSession::new(
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
            3,
        );
        assert!(s.prophet().is_some());
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(3, 8, 8, 8192));
        // Warm-up iteration: no outstanding forecast to score.
        let fb0 = s.observe_iteration(&gen.next_iteration());
        assert!(fb0.mean_forecast_error().is_none());
        // From iteration 1 on, every layer's forecast gets scored.
        let fb1 = s.observe_iteration(&gen.next_iteration());
        assert_eq!(fb1.forecast_errors.len(), 3);
        assert!(fb1.mean_forecast_error().unwrap() >= 0.0);
        assert_eq!(s.iterations_observed(), 2);
    }

    #[test]
    fn decide_iteration_matches_per_layer_decides() {
        let pm = pm();
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(4, 8, 8, 8192));
        let layers = gen.next_iteration();
        let s = BalancerSession::new(Box::new(builtin::TopK::new(2)), 4);
        let batch = s.decide_iteration(&layers, &pm);
        for (l, d) in batch.iter().enumerate() {
            let single = s.decide_layer(l, &layers[l], &pm);
            assert_eq!(*d.placement, *single.placement, "layer {l}");
            assert_eq!(d.plan_cost, single.plan_cost);
        }
    }

    #[test]
    #[should_panic]
    fn layer_out_of_range_rejected() {
        let s = BalancerSession::new(Box::new(builtin::DeepspeedMoe), 2);
        let w = LoadMatrix::zeros(4, 4);
        s.decide_layer(2, &w, &pm());
    }
}
