//! [`BalancerSession`]: one policy bound to one run.
//!
//! The session owns what is shared per run — the layer count and, for
//! forecasting policies, the [`Prophet`] — and centralizes the
//! observe → score → drift → invalidate loop that `sim::simulate` (phase
//! 2) and `Trainer::step` used to each re-implement.  Drivers call
//! [`BalancerSession::decide_layer`] from their per-layer fan-out (or
//! [`BalancerSession::decide_iteration`] to let the session fan out) and
//! [`BalancerSession::observe_iteration`] once per iteration with the
//! actual gating results.

use super::{BalancingPolicy, DecideCtx, Decision, LayerFeedback, PolicyCounters};
use crate::moe::{LoadMatrix, Placement};
use crate::obs::{self, Labels, Recorder, Span};
use crate::perfmodel::PerfModel;
use crate::prophet::{DeviceForecaster, Prophet, ProphetConfig};
use crate::util::threads;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// What one iteration's observations told the session, aggregated over
/// layers (in layer order).
#[derive(Clone, Debug, Default)]
pub struct IterationFeedback {
    /// Forecast errors of the layers that had an outstanding forecast.
    pub forecast_errors: Vec<f64>,
    /// Layers whose drift detector fired this iteration.
    pub drift_layers: usize,
}

impl IterationFeedback {
    /// Mean forecast error (None when no layer had a forecast — warm-up
    /// iterations and non-forecasting policies).
    pub fn mean_forecast_error(&self) -> Option<f64> {
        if self.forecast_errors.is_empty() {
            None
        } else {
            Some(self.forecast_errors.iter().sum::<f64>() / self.forecast_errors.len() as f64)
        }
    }
}

/// One [`BalancingPolicy`] bound to one run.
pub struct BalancerSession {
    policy: Box<dyn BalancingPolicy>,
    prophet: Option<Prophet>,
    n_layers: usize,
    iterations_observed: usize,
    rec: Arc<dyn Recorder>,
    /// Device-health mask (`down[d]` == out of service); empty until the
    /// first [`BalancerSession::set_device_health`] call — the healthy
    /// fast path never allocates or checks placements.
    down: Vec<bool>,
    /// Per-layer last placement decided while fully healthy: the
    /// fallback when a policy's decision cannot be repaired under the
    /// mask.  Behind per-layer locks because `decide_layer` takes
    /// `&self` from the scoped-thread fan-out (uncontended — one thread
    /// per layer).
    last_good: Vec<Mutex<Option<Arc<Placement>>>>,
    /// Health transitions that forced a policy replan.
    health_replans: usize,
    failover_placements: AtomicUsize,
    fallback_placements: AtomicUsize,
    /// Decisions that hit the all-devices-down wall
    /// ([`crate::moe::AllDevicesDown`]): nothing to fail over to.
    all_devices_down: AtomicUsize,
    /// Arms the per-device slowdown forecaster
    /// (`ProphetConfig::device_forecast`); `None` = feature off.
    device_forecast_cfg: Option<ProphetConfig>,
    /// Built lazily on the first realized-slowdown observation, when the
    /// device count is first known.
    device_forecaster: Option<DeviceForecaster>,
}

impl BalancerSession {
    /// Bind `policy` to a run over `n_layers` MoE layers; builds the
    /// shared prophet when the policy forecasts.  Telemetry stays off
    /// (the zero-cost no-op recorder); see
    /// [`BalancerSession::with_recorder`].
    pub fn new(policy: Box<dyn BalancingPolicy>, n_layers: usize) -> Self {
        Self::with_recorder(policy, n_layers, obs::noop_arc())
    }

    /// Like [`BalancerSession::new`] with a live telemetry sink: decide
    /// and observe phases are span-timed (`balancer.decide`,
    /// `balancer.observe`, `prophet.observe`), drift firings counted,
    /// and forecast error gauged; the same recorder is served to
    /// policies via [`DecideCtx::rec`].
    pub fn with_recorder(
        mut policy: Box<dyn BalancingPolicy>,
        n_layers: usize,
        rec: Arc<dyn Recorder>,
    ) -> Self {
        assert!(n_layers >= 1, "session needs at least one layer");
        policy.bind(n_layers);
        let device_forecast_cfg = policy.prophet_config().filter(|cfg| cfg.device_forecast);
        let prophet = policy.prophet_config().map(|cfg| Prophet::new(cfg, n_layers));
        BalancerSession {
            policy,
            prophet,
            n_layers,
            iterations_observed: 0,
            rec,
            down: Vec::new(),
            last_good: (0..n_layers).map(|_| Mutex::new(None)).collect(),
            health_replans: 0,
            failover_placements: AtomicUsize::new(0),
            fallback_placements: AtomicUsize::new(0),
            all_devices_down: AtomicUsize::new(0),
            device_forecast_cfg,
            device_forecaster: None,
        }
    }

    /// The session's telemetry sink (the no-op recorder when off).
    pub fn recorder(&self) -> &Arc<dyn Recorder> {
        &self.rec
    }

    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Iterations fed through [`BalancerSession::observe_iteration`].
    pub fn iterations_observed(&self) -> usize {
        self.iterations_observed
    }

    /// The shared forecasting subsystem (None for non-forecasting
    /// policies).
    pub fn prophet(&self) -> Option<&Prophet> {
        self.prophet.as_ref()
    }

    /// Whether the per-device slowdown forecaster is armed
    /// (`prophet.device_forecast = true` on a forecasting policy).
    pub fn device_forecast_enabled(&self) -> bool {
        self.device_forecast_cfg.is_some()
    }

    /// The per-device slowdown forecaster, once armed and fed.
    pub fn device_forecaster(&self) -> Option<&DeviceForecaster> {
        self.device_forecaster.as_ref()
    }

    /// Feed one iteration's REALIZED per-device slowdown vector — what
    /// the devices actually ran at this iteration (the fault view's
    /// composed factors while degraded, the cluster's static vector while
    /// healthy).  No-op unless armed; returns the normalized-L1 error of
    /// the forecast that was outstanding for this iteration, when any.
    pub fn observe_device_slowdown(&mut self, slowdown: &[f64]) -> Option<f64> {
        let cfg = self.device_forecast_cfg.as_ref()?;
        let n = slowdown.len().max(1);
        if self.device_forecaster.as_ref().is_some_and(|f| f.n_devices() != n) {
            // Device count changed under us (lease resize): stale history
            // is about different hardware — start over.
            self.device_forecaster = None;
        }
        let f = self.device_forecaster.get_or_insert_with(|| DeviceForecaster::new(cfg, n));
        let err = f.observe(slowdown);
        if self.rec.enabled() {
            if let Some(e) = err {
                self.rec.gauge("prophet.device_forecast_error_l1", Labels::None, e);
            }
        }
        err
    }

    /// One-step-ahead per-device slowdown forecast: the planner's view of
    /// device health for the NEXT iteration.  `None` until armed and fed
    /// at least one observation — callers fall back to the static cluster
    /// vector.
    pub fn forecast_slowdown(&self) -> Option<Vec<f64>> {
        self.device_forecaster.as_ref()?.forecast()
    }

    /// Whole-run decision counters.
    pub fn counters(&self) -> PolicyCounters {
        self.policy.counters()
    }

    /// The health monitor's input: update the device-health mask
    /// (`down[d]` == device `d` is out of service).  On any transition —
    /// a device going down OR recovering — the policy is notified via
    /// [`BalancingPolicy::set_device_mask`] so cached placements replan
    /// under the new health state.  Returns whether a transition
    /// occurred.
    pub fn set_device_health(&mut self, down: &[bool]) -> bool {
        let n = down.len().max(self.down.len());
        let changed = (0..n).any(|d| {
            self.down.get(d).copied().unwrap_or(false) != down.get(d).copied().unwrap_or(false)
        });
        self.down = down.to_vec();
        if !changed {
            return false;
        }
        self.health_replans += 1;
        self.policy.set_device_mask(down);
        if self.rec.enabled() {
            self.rec.counter("balancer.health_replans", Labels::None, 1);
            self.rec.gauge(
                "balancer.devices_down",
                Labels::None,
                down.iter().filter(|&&d| d).count() as f64,
            );
        }
        true
    }

    /// The current device-health mask (empty = never faulted).
    pub fn device_health(&self) -> &[bool] {
        &self.down
    }

    /// Health transitions that forced a policy replan.
    pub fn health_replans(&self) -> usize {
        self.health_replans
    }

    /// Decisions repaired by stripping/failing replicas off down devices.
    pub fn failover_placements(&self) -> usize {
        self.failover_placements.load(Ordering::Relaxed)
    }

    /// Decisions replaced wholesale by the last-known-good fallback.
    pub fn fallback_placements(&self) -> usize {
        self.fallback_placements.load(Ordering::Relaxed)
    }

    /// Decisions made while EVERY device was down — unrepairable
    /// ([`crate::moe::AllDevicesDown`]); drivers are expected to refuse
    /// the iteration (simulator) or park the job (fleet) instead of
    /// pricing these.
    pub fn all_devices_down(&self) -> usize {
        self.all_devices_down.load(Ordering::Relaxed)
    }

    /// Decide one layer's placement.  `&self`: safe to call from a
    /// per-layer thread fan-out (drivers that also price per layer fold
    /// this into their own [`crate::util::threads::par_map`] closure).
    ///
    /// While any device is down, the decision passes through the health
    /// guard: replicas on down devices are failed over to live ones and
    /// an irreparable placement is replaced by the last known-good one —
    /// a `DeviceDown` event can never surface a placement that assigns
    /// experts to the downed device, and never a panic.
    ///
    /// Drivers that cache priced iterations (`sim::PriceState`) still
    /// call this every iteration: decide owns plan caching, drift
    /// bookkeeping, and the `balancer.*` counters, so only the pricing
    /// step downstream of the returned [`Decision`] may be skipped.
    pub fn decide_layer(&self, layer: usize, w: &LoadMatrix, pm: &PerfModel) -> Decision {
        assert!(layer < self.n_layers, "layer {layer} out of range");
        let _sp = Span::enter(&*self.rec, "balancer.decide", Labels::None);
        let ctx = DecideCtx { pm, prophet: self.prophet.as_ref(), rec: &*self.rec };
        let d = self.policy.decide(layer, w, &ctx);
        if self.down.iter().any(|&dn| dn) {
            self.enforce_health(layer, d)
        } else {
            *self.last_good[layer].lock().expect("last-good lock poisoned") =
                Some(Arc::clone(&d.placement));
            d
        }
    }

    /// All-down accounting: the typed [`crate::moe::AllDevicesDown`]
    /// refusal, surfaced as a counter (and up the stack as the
    /// simulator's error / the fleet's "job parked" diagnostic).
    fn note_all_devices_down(&self) {
        self.all_devices_down.fetch_add(1, Ordering::Relaxed);
        if self.rec.enabled() {
            self.rec.counter("balancer.all_devices_down", Labels::None, 1);
        }
    }

    /// Strip-and-fail-over `p` under the current mask, counting the
    /// typed all-down refusal instead of panicking (the guard in
    /// [`BalancerSession::enforce_health`] makes it unreachable, but the
    /// session's no-panic contract outranks that analysis).
    fn fail_over_counted(&self, p: &mut Placement) {
        if p.fail_over(&self.down).is_err() {
            self.note_all_devices_down();
        }
    }

    /// Repair `d` against the current down set; see
    /// [`BalancerSession::decide_layer`].  Never panics.
    fn enforce_health(&self, layer: usize, mut d: Decision) -> Decision {
        let down = &self.down;
        if (0..d.placement.n_devices()).all(|dev| down.get(dev).copied().unwrap_or(false)) {
            // Every device is down: `Placement::fail_over` would refuse
            // with the typed `AllDevicesDown`.  Count it and hand the
            // decision back unrepaired — no placement is valid under
            // this mask, and drivers reject all-down states before
            // pricing (the simulator errors out, the fleet parks the
            // job for the tick).
            self.note_all_devices_down();
            return d;
        }
        let touches_down = (0..d.placement.n_experts()).any(|e| {
            d.placement.replicas(e).iter().any(|dev| down.get(dev).copied().unwrap_or(false))
        });
        if touches_down {
            let mut p = (*d.placement).clone();
            self.fail_over_counted(&mut p);
            d.placement = Arc::new(p);
            self.failover_placements.fetch_add(1, Ordering::Relaxed);
            if self.rec.enabled() {
                self.rec.counter("balancer.failover_placements", Labels::None, 1);
            }
        }
        if d.placement.validate_with_down(down).is_err() {
            // The policy produced something unusable under the mask
            // (e.g. a budget-truncated or stale search): last-known-good
            // fallback, counter-tracked, never a panic.
            self.fallback_placements.fetch_add(1, Ordering::Relaxed);
            if self.rec.enabled() {
                self.rec.counter("balancer.fallback_placements", Labels::None, 1);
            }
            let last = self.last_good[layer].lock().expect("last-good lock poisoned").clone();
            let mut p = match last {
                Some(lg) => (*lg).clone(),
                None => Placement::identity(d.placement.n_experts(), d.placement.n_devices()),
            };
            self.fail_over_counted(&mut p);
            if p.validate_with_down(down).is_err() {
                let mut id = Placement::identity(p.n_experts(), p.n_devices());
                self.fail_over_counted(&mut id);
                p = id;
            }
            d.placement = Arc::new(p);
        }
        d
    }

    /// Decide all layers of one iteration, fanned out over scoped threads
    /// (serial below the [`threads`] work threshold — results identical).
    pub fn decide_iteration(&self, layers: &[LoadMatrix], pm: &PerfModel) -> Vec<Decision> {
        assert_eq!(layers.len(), self.n_layers, "layer count mismatch");
        let work = layers.first().map_or(1, |w| w.n_devices() * w.n_experts());
        threads::par_map(layers.len(), work, |l| self.decide_layer(l, &layers[l], pm))
    }

    /// Feed the ACTUAL gating results of one iteration, in layer order:
    /// scores the outstanding forecasts, advances the history, runs drift
    /// detection, and hands each layer's verdict to the policy (which
    /// reacts by invalidating caches, adjusting placements, ...).
    pub fn observe_iteration(&mut self, layers: &[LoadMatrix]) -> IterationFeedback {
        assert_eq!(layers.len(), self.n_layers, "layer count mismatch");
        let _sp = Span::enter(&*self.rec, "balancer.observe", Labels::None);
        let mut fb = IterationFeedback::default();
        for (l, w) in layers.iter().enumerate() {
            let layer_fb = match self.prophet.as_mut() {
                Some(prophet) => {
                    let _psp = Span::enter(&*self.rec, "prophet.observe", Labels::None);
                    let obs = prophet.observe_layer(l, w);
                    LayerFeedback { drift: obs.drift, forecast_error: obs.forecast_error }
                }
                None => LayerFeedback::default(),
            };
            if layer_fb.drift {
                fb.drift_layers += 1;
            }
            if let Some(e) = layer_fb.forecast_error {
                fb.forecast_errors.push(e);
            }
            self.policy.observe(l, w, &layer_fb);
        }
        self.iterations_observed += 1;
        if self.rec.enabled() {
            self.rec.counter("prophet.drift_layers", Labels::None, fb.drift_layers as u64);
            if let Some(e) = fb.mean_forecast_error() {
                self.rec.gauge("prophet.forecast_error_l1", Labels::None, e);
            }
        }
        fb
    }
}

impl std::fmt::Debug for BalancerSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BalancerSession")
            .field("policy", &self.policy.name())
            .field("n_layers", &self.n_layers)
            .field("forecasting", &self.prophet.is_some())
            .field("iterations_observed", &self.iterations_observed)
            .field("devices_down", &self.down.iter().filter(|&&d| d).count())
            .field("device_forecast", &self.device_forecast_cfg.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{builtin, ProphetOptions};
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;
    use crate::workload::{WorkloadConfig, WorkloadGen};

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(8, 1, 8192), &ClusterSpec::hpwnv(2))
    }

    #[test]
    fn non_forecasting_session_has_no_prophet() {
        let s = BalancerSession::new(Box::new(builtin::DeepspeedMoe), 3);
        assert!(s.prophet().is_none());
        assert_eq!(s.policy_name(), "Deepspeed-MoE");
        assert_eq!(s.n_layers(), 3);
    }

    #[test]
    fn forecasting_session_scores_and_feeds_back() {
        let mut s = BalancerSession::new(
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
            3,
        );
        assert!(s.prophet().is_some());
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(3, 8, 8, 8192));
        // Warm-up iteration: no outstanding forecast to score.
        let fb0 = s.observe_iteration(&gen.next_iteration());
        assert!(fb0.mean_forecast_error().is_none());
        // From iteration 1 on, every layer's forecast gets scored.
        let fb1 = s.observe_iteration(&gen.next_iteration());
        assert_eq!(fb1.forecast_errors.len(), 3);
        assert!(fb1.mean_forecast_error().unwrap() >= 0.0);
        assert_eq!(s.iterations_observed(), 2);
    }

    #[test]
    fn device_forecast_armed_learns_and_defaults_off() {
        let mut opts = ProphetOptions::full();
        opts.prophet.device_forecast = true;
        let mut s = BalancerSession::new(Box::new(builtin::ProProphet::new(opts)), 1);
        assert!(s.device_forecast_enabled());
        assert!(s.forecast_slowdown().is_none(), "nothing observed yet");
        assert!(s.observe_device_slowdown(&[1.0, 2.5]).is_none());
        assert_eq!(s.forecast_slowdown().unwrap(), vec![1.0, 2.5]);
        // A device-count change (lease resize) restarts the history.
        let _ = s.observe_device_slowdown(&[1.0, 1.0, 4.0]);
        assert_eq!(s.forecast_slowdown().unwrap(), vec![1.0, 1.0, 4.0]);
        assert_eq!(s.device_forecaster().unwrap().observations(), 1);
        // Off by default: observe is a no-op, forecast stays None.
        let mut off = BalancerSession::new(
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
            1,
        );
        assert!(!off.device_forecast_enabled());
        let _ = off.observe_device_slowdown(&[2.0, 2.0]);
        assert!(off.forecast_slowdown().is_none());
        // Non-forecasting policies can never arm it.
        let plain = BalancerSession::new(Box::new(builtin::DeepspeedMoe), 1);
        assert!(!plain.device_forecast_enabled());
    }

    #[test]
    fn decide_iteration_matches_per_layer_decides() {
        let pm = pm();
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(4, 8, 8, 8192));
        let layers = gen.next_iteration();
        let s = BalancerSession::new(Box::new(builtin::TopK::new(2)), 4);
        let batch = s.decide_iteration(&layers, &pm);
        for (l, d) in batch.iter().enumerate() {
            let single = s.decide_layer(l, &layers[l], &pm);
            assert_eq!(*d.placement, *single.placement, "layer {l}");
            assert_eq!(d.plan_cost, single.plan_cost);
        }
    }

    #[test]
    #[should_panic]
    fn layer_out_of_range_rejected() {
        let s = BalancerSession::new(Box::new(builtin::DeepspeedMoe), 2);
        let w = LoadMatrix::zeros(4, 4);
        s.decide_layer(2, &w, &pm());
    }

    #[test]
    fn device_down_never_places_experts_on_downed_device() {
        let pm = pm();
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(2, 8, 8, 8192));
        let layers = gen.next_iteration();
        // FasterMoE shadows heavy experts to ALL devices — the harshest
        // case for the guard.
        let mut s = BalancerSession::new(Box::new(builtin::FasterMoe::new()), 2);
        let down_dev = 3;
        let mut down = vec![false; 8];
        down[down_dev] = true;
        assert!(s.set_device_health(&down));
        assert!(!s.set_device_health(&down), "no transition, no replan");
        assert_eq!(s.health_replans(), 1);
        for d in s.decide_iteration(&layers, &pm) {
            assert!(d.placement.validate_with_down(&down).is_ok());
            for e in 0..d.placement.n_experts() {
                assert!(!d.placement.replicas(e).contains(down_dev));
            }
        }
        assert!(s.failover_placements() > 0);
        // Recovery: decisions return to the unguarded bit-exact form.
        assert!(s.set_device_health(&[false; 8]));
        assert_eq!(s.health_replans(), 2);
        let healthy = BalancerSession::new(Box::new(builtin::FasterMoe::new()), 2);
        for (l, d) in s.decide_iteration(&layers, &pm).iter().enumerate() {
            assert_eq!(*d.placement, *healthy.decide_layer(l, &layers[l], &pm).placement);
        }
    }

    #[test]
    fn all_devices_down_is_counted_never_a_panic() {
        // Regression (PR 8): with EVERY device down the repair pipeline
        // used to push decisions through `fail_over` into silently
        // emptied replica sets; now the typed refusal is counted
        // (`balancer.all_devices_down`) and decide still returns — the
        // driver (sim error / fleet park) owns the refusal.
        let pm = pm();
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(2, 8, 8, 8192));
        let layers = gen.next_iteration();
        let mut s = BalancerSession::new(Box::new(builtin::FasterMoe::new()), 2);
        assert!(s.set_device_health(&[true; 8]));
        assert_eq!(s.all_devices_down(), 0);
        let decisions = s.decide_iteration(&layers, &pm);
        assert_eq!(decisions.len(), 2);
        assert_eq!(s.all_devices_down(), 2, "one refusal per layer decision");
        // Recovery drains the guard: healthy decisions, no new refusals.
        assert!(s.set_device_health(&[false; 8]));
        for d in s.decide_iteration(&layers, &pm) {
            assert!(d.placement.validate().is_ok());
        }
        assert_eq!(s.all_devices_down(), 2);
    }

    #[test]
    fn fallback_serves_last_known_good_placement() {
        // A policy that drops home replicas (every expert lives on
        // devices {0, 7} only): once device 0 is down, the failover
        // strip leaves live homes missing — irreparable by failover, so
        // the session must fall back, not panic.
        struct Stubborn;
        impl BalancingPolicy for Stubborn {
            fn name(&self) -> String {
                "stubborn".into()
            }
            fn bind(&mut self, _n_layers: usize) {}
            fn decide(&self, _layer: usize, w: &LoadMatrix, _ctx: &DecideCtx<'_>) -> Decision {
                let mut p = Placement::identity(w.n_experts(), w.n_devices());
                let last = w.n_devices() - 1;
                for e in 0..w.n_experts() {
                    p.set_replicas(e, [0usize, last]);
                }
                Decision {
                    placement: Arc::new(p),
                    plan_cost: 0.0,
                    comm_style: crate::balancer::CommStyle::Pipelined,
                    schedule_kind: crate::balancer::ScheduleKind::Blocking,
                }
            }
        }
        let pm = pm();
        let w = LoadMatrix::from_rows(vec![vec![100; 8]; 8]);
        let mut s = BalancerSession::new(Box::new(Stubborn), 1);
        // Healthy decide seeds last-known-good.
        let healthy = s.decide_layer(0, &w, &pm);
        assert!(healthy.placement.replicas(1).contains(0));
        // Device 0 goes down: failover strips the only replica of every
        // expert, so the guard falls back (here: last-good, failed over).
        let mut down = vec![false; 8];
        down[0] = true;
        s.set_device_health(&down);
        let d = s.decide_layer(0, &w, &pm);
        assert!(d.placement.validate_with_down(&down).is_ok());
        assert_eq!(s.fallback_placements(), 1);
        assert!(s.failover_placements() >= 1);
        // A fresh session with no last-good history degrades to the
        // failed-over identity — still valid, still no panic.
        let mut fresh = BalancerSession::new(Box::new(Stubborn), 1);
        fresh.set_device_health(&down);
        let d = fresh.decide_layer(0, &w, &pm);
        assert!(d.placement.validate_with_down(&down).is_ok());
        assert_eq!(fresh.fallback_placements(), 1);
    }
}
