//! Open load-balancing policy API: the trait-based successor of the
//! closed policy enum that predated it (now `sim::reference::Policy`,
//! kept only as the frozen oracle's input vocabulary).
//!
//! The paper frames Pro-Prophet as one point in a *space* of system-level
//! MoE load balancers (Deepspeed-MoE, FasterMoE and top-k shadowing are
//! its baselines).  This module makes that space pluggable: a policy is a
//! [`BalancingPolicy`] trait object, and everything that used to be a
//! `match` arm smeared across `sim::simulate`, `single_layer_times` and
//! `Trainer::step` — planning, prophet observation, drift bookkeeping,
//! comm-style flags — now flows through two calls:
//!
//! ```text
//!   decide(layer, W, ctx)  ->  Decision { placement, plan_cost,
//!                                         comm_style, schedule_kind }
//!   observe(layer, W, fb)  <-  actual gating + prophet verdict
//! ```
//!
//! # The Decision/Session contract
//!
//! A **[`Decision`]** is everything the execution substrate needs to
//! price and schedule one layer: the expert [`Placement`] for the
//! upcoming iteration, the Plan cost actually paid (0 on cache reuse),
//! the [`CommStyle`] its parameter transfers use on the wire, and the
//! [`ScheduleKind`] its iteration timeline is assembled with.  Policies
//! return data; they never touch the engine or the scheduler directly —
//! that is what keeps them simulator-agnostic.
//!
//! A **[`BalancerSession`]** binds one policy to one run (a layer count
//! plus, when the policy forecasts, a shared [`Prophet`]).  It owns the
//! observe → score → drift → invalidate loop that the simulator and the
//! trainer previously each re-implemented (and had let diverge subtly):
//! `observe_iteration` scores outstanding forecasts, advances history,
//! runs drift detection, and hands each layer's verdict to the policy as
//! a [`LayerFeedback`].
//!
//! Threading: `decide` takes `&self` and is fanned out across layers on
//! scoped threads ([`crate::util::threads`]); per-layer mutable state
//! lives behind per-layer locks (uncontended — one thread per layer), so
//! parallel and serial execution are observably identical.  `observe` is
//! sequential in layer order, because history order matters.
//!
//! # Adding a policy in one file
//!
//! [`flexmoe`] is the worked example: a FlexMoE-style dynamic
//! re-placement baseline (expand/shrink expert replicas on observed load,
//! under a per-iteration migration budget) written entirely against this
//! module — it imports nothing from `sim::` and the simulator needed no
//! edits to run it.  The recipe:
//!
//! 1. Implement [`BalancingPolicy`] for your type.  `bind` allocates
//!    per-layer state, `decide` returns a [`Decision`], `observe` reacts
//!    to actual gating (see `flexmoe.rs` for the expand/shrink reaction).
//! 2. Register a constructor in [`registry`] (one `PolicyEntry` line).
//! 3. Done: `pro-prophet simulate --policy <name>`, the `[policy]` TOML
//!    table, and `sim::simulate_policy` all pick it up.
//!
//! The legacy `sim::Policy` migration shim is retired; the closed enum's
//! last copy lives in `sim::reference` as the frozen oracle's input
//! vocabulary, and the golden test in
//! `rust/tests/golden_equivalence.rs` pins the trait path bit-for-bit to
//! the pre-refactor enum path for all four original policies.

pub mod builtin;
pub mod flexmoe;
pub mod registry;
pub mod session;

pub use builtin::{DeepspeedMoe, FasterMoe, ProProphet, TopK};
pub use flexmoe::{FlexMoe, FlexMoeConfig};
pub use session::{BalancerSession, IterationFeedback};

use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::planner::PlannerConfig;
use crate::prophet::{Prophet, ProphetConfig};
use std::sync::Arc;

/// How a policy's parameter transfers (Trans/Agg) hit the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommStyle {
    /// Chunked scatter+allgather collective, pipelinable by the §V
    /// scheduler (Pro-Prophet's lightweight placements).
    Pipelined,
    /// Coarse blocking broadcast (FasterMoE shadowing, top-k-to-all):
    /// [`crate::perfmodel::COARSE_FACTOR`] slower per byte.
    Coarse,
}

/// How an iteration's block costs are assembled into a timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Blocking timeline with no load-balancing ops at all (pure EP).
    NoLoadBalance,
    /// Blocking timeline including the policy's LB ops.
    Blocking,
    /// Pro-Prophet's block-wise overlap schedule (paper §V, Algorithm 2).
    Blockwise,
}

/// One layer's placement decision for the upcoming iteration — the unit
/// the execution substrate prices and schedules.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Expert placement to run the iteration under.
    pub placement: Arc<Placement>,
    /// Seconds of Plan cost actually paid this iteration (0 when a cached
    /// placement was reused or the policy never searches).
    pub plan_cost: f64,
    pub comm_style: CommStyle,
    pub schedule_kind: ScheduleKind,
}

/// Whole-run decision counters, aggregated across layers (the
/// `SimReport` planning totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PolicyCounters {
    /// Placement searches actually executed.
    pub plans_run: usize,
    /// Decisions served from a cached placement.
    pub plans_reused: usize,
    /// Replans forced by drift detection.
    pub drift_replans: usize,
}

/// Read-only context handed to [`BalancingPolicy::decide`].
pub struct DecideCtx<'a> {
    /// Analytic performance model of the (model, cluster) pair.
    pub pm: &'a PerfModel,
    /// The session's shared forecasting subsystem — present iff the
    /// policy asked for one via [`BalancingPolicy::prophet_config`].
    pub prophet: Option<&'a Prophet>,
}

/// Post-iteration verdict for one layer, delivered with the observed
/// gating result.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerFeedback {
    /// The session's drift detector declared a regime change; cached
    /// placements for this layer should be invalidated.
    pub drift: bool,
    /// Normalized-L1 error of the forecast that was outstanding for this
    /// iteration (None when no forecast existed yet, or no prophet runs).
    pub forecast_error: Option<f64>,
}

/// A pluggable load-balancing policy.
///
/// Implementations are driven by a [`BalancerSession`]: `bind` once per
/// run, then per iteration `decide` for every layer (parallel, `&self`)
/// followed by `observe` for every layer (sequential, in order).  See the
/// [module docs](self) for the full contract and `flexmoe.rs` for a
/// worked one-file example.
pub trait BalancingPolicy: Send + Sync {
    /// Display name (report rows, CLI tables).
    fn name(&self) -> String;

    /// Bind to a run: allocate per-layer state for `n_layers` MoE layers.
    /// Called exactly once, before the first `decide`.
    fn bind(&mut self, n_layers: usize);

    /// Prophet configuration when this policy plans on forecasts; the
    /// session then owns a shared [`Prophet`], serves it to `decide` via
    /// [`DecideCtx`], and feeds every observation through it.
    fn prophet_config(&self) -> Option<ProphetConfig> {
        None
    }

    /// Placement decision for `layer`'s upcoming iteration.  `w` is the
    /// freshest load matrix available to the caller (the current
    /// iteration's gating in the simulator's warm-up, the last observed
    /// one otherwise); forecasting policies should prefer
    /// `ctx.prophet.forecast_matrix(layer)` when it exists.
    ///
    /// Takes `&self`: the session fans this call out across layers on
    /// scoped threads, so per-layer mutable state must live behind
    /// per-layer locks (see [`builtin::ProProphet`]).
    fn decide(&self, layer: usize, w: &LoadMatrix, ctx: &DecideCtx<'_>) -> Decision;

    /// Observed gating result of `layer`, with the session's prophet
    /// verdict.  Called sequentially in layer order once per iteration.
    fn observe(&mut self, layer: usize, w: &LoadMatrix, fb: &LayerFeedback) {
        let _ = (layer, w, fb);
    }

    /// Whole-run counters (see [`PolicyCounters`]).
    fn counters(&self) -> PolicyCounters {
        PolicyCounters::default()
    }
}

/// Options of the Pro-Prophet policy family (planner knobs, §V scheduler
/// switch, prophet forecasting knobs) — the Fig 14 ablation axes.
///
/// Lives here (not in `sim`) since the refactor; `sim::ProphetOptions`
/// re-exports it for the legacy enum path.
#[derive(Clone, Debug)]
pub struct ProphetOptions {
    pub planner: PlannerConfig,
    /// Block-wise overlap scheduling (§V) on/off.
    pub scheduler_on: bool,
    /// Forecasting subsystem knobs (predictor selection, drift detection).
    pub prophet: ProphetConfig,
}

impl Default for ProphetOptions {
    fn default() -> Self {
        ProphetOptions {
            planner: PlannerConfig::default(),
            scheduler_on: true,
            prophet: ProphetConfig::default(),
        }
    }
}

impl ProphetOptions {
    /// Planner only (scheduler ablated): Eq 6 evaluation, blocking timeline.
    pub fn planner_only() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: false,
            ..Default::default()
        }
    }

    /// Scheduler on, but the planner evaluates with the blocking Eq 6
    /// (i.e. without the §V-C combination).
    pub fn without_combination() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: true,
            ..Default::default()
        }
    }

    /// Full system: block-wise scheduler + Eq 8-aware planner.
    pub fn full() -> Self {
        ProphetOptions::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_is_cheap_to_clone() {
        let d = Decision {
            placement: Arc::new(Placement::identity(4, 4)),
            plan_cost: 0.5,
            comm_style: CommStyle::Pipelined,
            schedule_kind: ScheduleKind::Blockwise,
        };
        let d2 = d.clone();
        assert!(Arc::ptr_eq(&d.placement, &d2.placement));
        assert_eq!(d2.comm_style, CommStyle::Pipelined);
        assert_eq!(d2.schedule_kind, ScheduleKind::Blockwise);
    }

    #[test]
    fn prophet_options_presets() {
        let full = ProphetOptions::full();
        assert!(full.scheduler_on && full.planner.use_overlap_model);
        let po = ProphetOptions::planner_only();
        assert!(!po.scheduler_on && !po.planner.use_overlap_model);
        let nc = ProphetOptions::without_combination();
        assert!(nc.scheduler_on && !nc.planner.use_overlap_model);
    }
}
