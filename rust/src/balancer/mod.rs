//! Open load-balancing policy API: the trait-based successor of the
//! closed policy enum that predated it (now `sim::reference::Policy`,
//! kept only as the frozen oracle's input vocabulary).
//!
//! The paper frames Pro-Prophet as one point in a *space* of system-level
//! MoE load balancers (Deepspeed-MoE, FasterMoE and top-k shadowing are
//! its baselines).  This module makes that space pluggable: a policy is a
//! [`BalancingPolicy`] trait object, and everything that used to be a
//! `match` arm smeared across `sim::simulate`, `single_layer_times` and
//! `Trainer::step` — planning, prophet observation, drift bookkeeping,
//! comm-style flags — now flows through two calls:
//!
//! ```text
//!   decide(layer, W, ctx)  ->  Decision { placement, plan_cost,
//!                                         comm_style, schedule_kind }
//!   observe(layer, W, fb)  <-  actual gating + prophet verdict
//! ```
//!
//! # The Decision/Session contract
//!
//! A **[`Decision`]** is everything the execution substrate needs to
//! price and schedule one layer: the expert [`Placement`] for the
//! upcoming iteration, the Plan cost actually paid (0 on cache reuse),
//! the [`CommStyle`] its parameter transfers use on the wire, and the
//! [`ScheduleKind`] its iteration timeline is assembled with.  Policies
//! return data; they never touch the engine or the scheduler directly —
//! that is what keeps them simulator-agnostic.
//!
//! A **[`BalancerSession`]** binds one policy to one run (a layer count
//! plus, when the policy forecasts, a shared [`Prophet`]).  It owns the
//! observe → score → drift → invalidate loop that the simulator and the
//! trainer previously each re-implemented (and had let diverge subtly):
//! `observe_iteration` scores outstanding forecasts, advances history,
//! runs drift detection, and hands each layer's verdict to the policy as
//! a [`LayerFeedback`].
//!
//! Threading: `decide` takes `&self` and is fanned out across layers on
//! scoped threads ([`crate::util::threads`]); per-layer mutable state
//! lives behind per-layer locks (uncontended — one thread per layer), so
//! parallel and serial execution are observably identical.  `observe` is
//! sequential in layer order, because history order matters.
//!
//! # Adding a policy in one file
//!
//! [`flexmoe`] is the worked example: a FlexMoE-style dynamic
//! re-placement baseline (expand/shrink expert replicas on observed load,
//! under a per-iteration migration budget) written entirely against this
//! module — it imports nothing from `sim::` and the simulator needed no
//! edits to run it.  The recipe:
//!
//! 1. Implement [`BalancingPolicy`] for your type.  `bind` allocates
//!    per-layer state, `decide` returns a [`Decision`], `observe` reacts
//!    to actual gating (see `flexmoe.rs` for the expand/shrink reaction).
//! 2. Register a constructor in [`registry`] (one `PolicyEntry` line).
//! 3. Done: `pro-prophet simulate --policy <name>`, the `[policy]` TOML
//!    table, and `sim::simulate_policy` all pick it up.
//!
//! The legacy `sim::Policy` migration shim is retired; the closed enum's
//! last copy lives in `sim::reference` as the frozen oracle's input
//! vocabulary, and the golden test in
//! `rust/tests/golden_equivalence.rs` pins the trait path bit-for-bit to
//! the pre-refactor enum path for all four original policies.

pub mod builtin;
pub mod flexmoe;
pub mod registry;
pub mod session;

pub use builtin::{DeepspeedMoe, FasterMoe, ProProphet, TopK};
pub use flexmoe::{FlexMoe, FlexMoeConfig};
pub use session::{BalancerSession, IterationFeedback};

use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::planner::PlannerConfig;
use crate::prophet::{Prophet, ProphetConfig};
use std::sync::Arc;

/// How a policy's parameter transfers (Trans/Agg) hit the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommStyle {
    /// Chunked scatter+allgather collective, pipelinable by the §V
    /// scheduler (Pro-Prophet's lightweight placements).
    Pipelined,
    /// Coarse blocking broadcast (FasterMoE shadowing, top-k-to-all):
    /// [`crate::perfmodel::COARSE_FACTOR`] slower per byte.
    Coarse,
}

/// How an iteration's block costs are assembled into a timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Blocking timeline with no load-balancing ops at all (pure EP).
    NoLoadBalance,
    /// Blocking timeline including the policy's LB ops.
    Blocking,
    /// Pro-Prophet's block-wise overlap schedule (paper §V, Algorithm 2),
    /// priced on the frozen barrier Stage model.
    Blockwise,
    /// Algorithm 2 as a true-dependency DAG
    /// ([`crate::scheduler::build_blockwise_dag`]): no cross-stream stage
    /// barriers, per-device operator durations, priced by the per-device
    /// discrete-event executor ([`crate::sim::events`]) every iteration.
    /// Never slower than [`ScheduleKind::Blockwise`] under the engine's
    /// cost vectors (property-tested), and the only kind whose reported
    /// time sees per-device slack on homogeneous clusters too.
    DagRelaxed,
}

impl ScheduleKind {
    /// Canonical config/CLI spellings, in enum order.
    pub const NAMES: [&'static str; 4] =
        ["no_load_balance", "blocking", "blockwise", "dag_relaxed"];

    /// The spellings the `[policy] schedule` / `--schedule` overrides
    /// accept — `no_load_balance` parses but is rejected there (it is
    /// the Deepspeed-MoE policy, not a Pro-Prophet scheduling mode), so
    /// error messages must not advertise it.
    pub const OVERRIDE_NAMES: [&'static str; 3] = ["blocking", "blockwise", "dag_relaxed"];

    /// Canonical name (round-trips through [`ScheduleKind::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::NoLoadBalance => "no_load_balance",
            ScheduleKind::Blocking => "blocking",
            ScheduleKind::Blockwise => "blockwise",
            ScheduleKind::DagRelaxed => "dag_relaxed",
        }
    }

    /// Parse a config/CLI spelling (`[policy] schedule = "..."`,
    /// `simulate --schedule ...`).  Accepts `-` for `_` and the short
    /// `dag` alias; None for unknown strings.
    pub fn from_name(name: &str) -> Option<ScheduleKind> {
        match name {
            "no_load_balance" | "no-load-balance" => Some(ScheduleKind::NoLoadBalance),
            "blocking" => Some(ScheduleKind::Blocking),
            "blockwise" => Some(ScheduleKind::Blockwise),
            "dag_relaxed" | "dag-relaxed" | "dag" => Some(ScheduleKind::DagRelaxed),
            _ => None,
        }
    }
}

/// One layer's placement decision for the upcoming iteration — the unit
/// the execution substrate prices and schedules.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Expert placement to run the iteration under.
    pub placement: Arc<Placement>,
    /// Seconds of Plan cost actually paid this iteration (0 when a cached
    /// placement was reused or the policy never searches).
    pub plan_cost: f64,
    pub comm_style: CommStyle,
    pub schedule_kind: ScheduleKind,
}

/// Whole-run decision counters, aggregated across layers (the
/// `SimReport` planning totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PolicyCounters {
    /// Placement searches actually executed.
    pub plans_run: usize,
    /// Decisions served from a cached placement.
    pub plans_reused: usize,
    /// Replans forced by drift detection.
    pub drift_replans: usize,
}

/// Read-only context handed to [`BalancingPolicy::decide`].
pub struct DecideCtx<'a> {
    /// Analytic performance model of the (model, cluster) pair.
    pub pm: &'a PerfModel,
    /// The session's shared forecasting subsystem — present iff the
    /// policy asked for one via [`BalancingPolicy::prophet_config`].
    pub prophet: Option<&'a Prophet>,
    /// The session's telemetry sink ([`crate::obs::noop`] by default —
    /// disabled, zero-cost).  Policies time their phases through it
    /// (`prophet.forecast`, `plan.greedy_search`) and count searches;
    /// `decide` runs on scoped threads, so the recorder is shared.
    pub rec: &'a dyn crate::obs::Recorder,
}

/// Post-iteration verdict for one layer, delivered with the observed
/// gating result.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerFeedback {
    /// The session's drift detector declared a regime change; cached
    /// placements for this layer should be invalidated.
    pub drift: bool,
    /// Normalized-L1 error of the forecast that was outstanding for this
    /// iteration (None when no forecast existed yet, or no prophet runs).
    pub forecast_error: Option<f64>,
}

/// A pluggable load-balancing policy.
///
/// Implementations are driven by a [`BalancerSession`]: `bind` once per
/// run, then per iteration `decide` for every layer (parallel, `&self`)
/// followed by `observe` for every layer (sequential, in order).  See the
/// [module docs](self) for the full contract and `flexmoe.rs` for a
/// worked one-file example.
pub trait BalancingPolicy: Send + Sync {
    /// Display name (report rows, CLI tables).
    fn name(&self) -> String;

    /// Bind to a run: allocate per-layer state for `n_layers` MoE layers.
    /// Called exactly once, before the first `decide`.
    fn bind(&mut self, n_layers: usize);

    /// Prophet configuration when this policy plans on forecasts; the
    /// session then owns a shared [`Prophet`], serves it to `decide` via
    /// [`DecideCtx`], and feeds every observation through it.
    fn prophet_config(&self) -> Option<ProphetConfig> {
        None
    }

    /// Placement decision for `layer`'s upcoming iteration.  `w` is the
    /// freshest load matrix available to the caller (the current
    /// iteration's gating in the simulator's warm-up, the last observed
    /// one otherwise); forecasting policies should prefer
    /// `ctx.prophet.forecast_matrix(layer)` when it exists.
    ///
    /// Takes `&self`: the session fans this call out across layers on
    /// scoped threads, so per-layer mutable state must live behind
    /// per-layer locks (see [`builtin::ProProphet`]).
    fn decide(&self, layer: usize, w: &LoadMatrix, ctx: &DecideCtx<'_>) -> Decision;

    /// Observed gating result of `layer`, with the session's prophet
    /// verdict.  Called sequentially in layer order once per iteration.
    fn observe(&mut self, layer: usize, w: &LoadMatrix, fb: &LayerFeedback) {
        let _ = (layer, w, fb);
    }

    /// Whole-run counters (see [`PolicyCounters`]).
    fn counters(&self) -> PolicyCounters {
        PolicyCounters::default()
    }

    /// Device-health update from the session (`down[d]` == device `d` is
    /// out of service; an all-false or empty slice means fully healthy).
    /// Called only on transitions.  Policies that cache placements or
    /// search should invalidate the cache and exclude the down devices
    /// from future searches (see [`builtin::ProProphet`]); the default
    /// ignores health — the session's failover guard still keeps every
    /// decision off down devices.
    fn set_device_mask(&mut self, down: &[bool]) {
        let _ = down;
    }
}

/// Options of the Pro-Prophet policy family (planner knobs, §V scheduler
/// switch, prophet forecasting knobs) — the Fig 14 ablation axes.
///
/// Lives here (not in `sim`) since the refactor; `sim::ProphetOptions`
/// re-exports it for the legacy enum path.
#[derive(Clone, Debug)]
pub struct ProphetOptions {
    pub planner: PlannerConfig,
    /// Block-wise overlap scheduling (§V) on/off.
    pub scheduler_on: bool,
    /// With the scheduler on, assemble iterations as the relaxed
    /// true-dependency DAG ([`ScheduleKind::DagRelaxed`]) instead of the
    /// barrier-stage form ([`ScheduleKind::Blockwise`]).
    pub relaxed_dag: bool,
    /// Forecasting subsystem knobs (predictor selection, drift detection).
    pub prophet: ProphetConfig,
}

impl Default for ProphetOptions {
    fn default() -> Self {
        ProphetOptions {
            planner: PlannerConfig::default(),
            scheduler_on: true,
            relaxed_dag: false,
            prophet: ProphetConfig::default(),
        }
    }
}

impl ProphetOptions {
    /// Planner only (scheduler ablated): Eq 6 evaluation, blocking timeline.
    pub fn planner_only() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: false,
            ..Default::default()
        }
    }

    /// Scheduler on, but the planner evaluates with the blocking Eq 6
    /// (i.e. without the §V-C combination).
    pub fn without_combination() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: true,
            ..Default::default()
        }
    }

    /// Full system: block-wise scheduler + Eq 8-aware planner.
    pub fn full() -> Self {
        ProphetOptions::default()
    }

    /// Full system on the relaxed execution mode: Algorithm 2 as a
    /// true-dependency DAG priced by the per-device DES, with the
    /// slack-aware planner cost model
    /// ([`crate::perfmodel::PerfModel::layer_time_sn_relaxed`]) ranking
    /// candidates on heterogeneous clusters.
    pub fn dag() -> Self {
        ProphetOptions {
            planner: PlannerConfig { slack_aware: true, ..Default::default() },
            relaxed_dag: true,
            ..Default::default()
        }
    }

    /// Apply an explicit schedule-kind override (the `[policy] schedule`
    /// TOML key / `simulate --schedule` flag — ONE shared mapping so the
    /// two surfaces cannot drift): `dag_relaxed` and `blockwise` force
    /// the scheduler on (relaxed vs barrier assembly; `dag_relaxed`
    /// additionally arms the planner's slack-aware cost model),
    /// `blocking`/`no_load_balance` force it off.  Callers should reject
    /// `no_load_balance` beforehand (it is a policy choice — Deepspeed-
    /// MoE — not a Pro-Prophet scheduling mode); it is mapped like
    /// `blocking` here only so the function is total.
    pub fn apply_schedule(&mut self, kind: ScheduleKind) {
        match kind {
            ScheduleKind::DagRelaxed => {
                self.scheduler_on = true;
                self.relaxed_dag = true;
                self.planner.slack_aware = true;
            }
            // Barrier kinds strip the relaxed knobs INCLUDING the slack
            // cost model: a dag-mode options object downgraded to a
            // barrier kind must price like the frozen Pro-Prophet, not
            // keep ranking candidates with the relaxed estimate.
            ScheduleKind::Blockwise => {
                self.scheduler_on = true;
                self.relaxed_dag = false;
                self.planner.slack_aware = false;
            }
            ScheduleKind::Blocking | ScheduleKind::NoLoadBalance => {
                self.scheduler_on = false;
                self.relaxed_dag = false;
                self.planner.slack_aware = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_is_cheap_to_clone() {
        let d = Decision {
            placement: Arc::new(Placement::identity(4, 4)),
            plan_cost: 0.5,
            comm_style: CommStyle::Pipelined,
            schedule_kind: ScheduleKind::Blockwise,
        };
        let d2 = d.clone();
        assert!(Arc::ptr_eq(&d.placement, &d2.placement));
        assert_eq!(d2.comm_style, CommStyle::Pipelined);
        assert_eq!(d2.schedule_kind, ScheduleKind::Blockwise);
    }

    #[test]
    fn prophet_options_presets() {
        let full = ProphetOptions::full();
        assert!(full.scheduler_on && full.planner.use_overlap_model);
        assert!(!full.relaxed_dag, "barrier pricing stays the default");
        let po = ProphetOptions::planner_only();
        assert!(!po.scheduler_on && !po.planner.use_overlap_model);
        let nc = ProphetOptions::without_combination();
        assert!(nc.scheduler_on && !nc.planner.use_overlap_model);
        let dag = ProphetOptions::dag();
        assert!(dag.scheduler_on && dag.relaxed_dag && dag.planner.slack_aware);
    }

    #[test]
    fn apply_schedule_maps_every_kind() {
        let mut o = ProphetOptions::default();
        o.apply_schedule(ScheduleKind::DagRelaxed);
        assert!(o.scheduler_on && o.relaxed_dag && o.planner.slack_aware);
        // Downgrading to a barrier kind strips ALL relaxed knobs — the
        // slack cost model must not survive the switch.
        o.apply_schedule(ScheduleKind::Blockwise);
        assert!(o.scheduler_on && !o.relaxed_dag && !o.planner.slack_aware);
        o.apply_schedule(ScheduleKind::Blocking);
        assert!(!o.scheduler_on && !o.relaxed_dag && !o.planner.slack_aware);
        let mut o = ProphetOptions::dag();
        o.apply_schedule(ScheduleKind::NoLoadBalance);
        assert!(!o.scheduler_on && !o.relaxed_dag && !o.planner.slack_aware);
    }

    #[test]
    fn schedule_kind_names_round_trip() {
        for kind in [
            ScheduleKind::NoLoadBalance,
            ScheduleKind::Blocking,
            ScheduleKind::Blockwise,
            ScheduleKind::DagRelaxed,
        ] {
            assert_eq!(ScheduleKind::from_name(kind.name()), Some(kind));
            assert!(ScheduleKind::NAMES.contains(&kind.name()));
        }
        assert_eq!(ScheduleKind::from_name("dag"), Some(ScheduleKind::DagRelaxed));
        assert_eq!(
            ScheduleKind::from_name("dag-relaxed"),
            Some(ScheduleKind::DagRelaxed)
        );
        assert_eq!(ScheduleKind::from_name("barrier"), None);
        assert_eq!(ScheduleKind::from_name(""), None);
        // Every override spelling is a real kind, and the rejected
        // no_load_balance is exactly the one left out.
        for name in ScheduleKind::OVERRIDE_NAMES {
            assert!(ScheduleKind::NAMES.contains(&name));
            assert!(ScheduleKind::from_name(name).is_some());
        }
        assert!(!ScheduleKind::OVERRIDE_NAMES.contains(&"no_load_balance"));
    }
}
