//! String-keyed policy registry — the single lookup behind the CLI
//! (`--policy`, `pro-prophet info`), the `[policy]` TOML table and the
//! benches.
//!
//! Every entry is a constructor taking the run's [`ProphetOptions`] (the
//! Pro-Prophet family reads them; baselines ignore them), so one options
//! object parameterizes any policy uniformly.  `top<k>` names are parsed
//! generically (`top2`, `top3`, `top7`, ...).

use super::{builtin, flexmoe, BalancingPolicy, ProphetOptions, ScheduleKind};
use crate::planner::PlannerConfig;

/// One registered policy.
pub struct PolicyEntry {
    /// Canonical registry key.
    pub name: &'static str,
    /// Alternative spellings accepted by [`build`].
    pub aliases: &'static [&'static str],
    /// One-line description for `--help`/`info` listings.
    pub summary: &'static str,
    build: fn(&ProphetOptions) -> Box<dyn BalancingPolicy>,
}

impl PolicyEntry {
    /// Construct this policy with `opts`.
    pub fn build(&self, opts: &ProphetOptions) -> Box<dyn BalancingPolicy> {
        (self.build)(opts)
    }
}

/// The registry. Order is the display order of listings.
pub const ENTRIES: &[PolicyEntry] = &[
    PolicyEntry {
        name: "deepspeed",
        aliases: &["deepspeed-moe"],
        summary: "Deepspeed-MoE: pure expert parallelism, no load balancing",
        build: |_| Box::new(builtin::DeepspeedMoe),
    },
    PolicyEntry {
        name: "fastermoe",
        aliases: &[],
        summary: "FasterMoE: dynamic shadowing to ALL devices, blocking broadcast",
        build: |_| Box::new(builtin::FasterMoe::new()),
    },
    PolicyEntry {
        name: "top2",
        aliases: &[],
        summary: "replicate the 2 heaviest experts to every device (top<k> works too)",
        build: |_| Box::new(builtin::TopK::new(2)),
    },
    PolicyEntry {
        name: "top3",
        aliases: &[],
        summary: "replicate the 3 heaviest experts to every device",
        build: |_| Box::new(builtin::TopK::new(3)),
    },
    PolicyEntry {
        name: "flexmoe",
        aliases: &[],
        summary: "FlexMoE-style incremental replica expand/shrink under a migration budget",
        build: |_| Box::new(flexmoe::FlexMoe::default()),
    },
    PolicyEntry {
        name: "pro-prophet",
        aliases: &["prophet"],
        summary: "Pro-Prophet: forecast-driven planner + block-wise overlap scheduler",
        build: |opts| Box::new(builtin::ProProphet::new(opts.clone())),
    },
    PolicyEntry {
        name: "pro-prophet-dag",
        aliases: &["prophet-dag", "dag"],
        summary: "Pro-Prophet on the relaxed true-dependency DAG (per-device DES pricing, slack-aware planner)",
        build: |opts| {
            // Same mapping as `[policy] schedule = "dag_relaxed"` / the
            // CLI `--schedule` flag — one definition of "dag mode".
            let mut o = opts.clone();
            o.apply_schedule(ScheduleKind::DagRelaxed);
            Box::new(builtin::ProProphet::new(o))
        },
    },
    PolicyEntry {
        name: "planner-only",
        aliases: &[],
        summary: "Pro-Prophet planner with the scheduler ablated (Fig 14 arm)",
        build: |opts| {
            Box::new(builtin::ProProphet::new(ProphetOptions {
                planner: PlannerConfig {
                    use_overlap_model: false,
                    // The ablation arm prices with the blocking Eq 6; the
                    // overlap-shaped slack estimate must not leak in via a
                    // `schedule = "dag_relaxed"` options object.
                    slack_aware: false,
                    ..opts.planner.clone()
                },
                scheduler_on: false,
                relaxed_dag: false,
                prophet: opts.prophet.clone(),
            }))
        },
    },
];

/// Canonical names, in display order.
pub fn names() -> Vec<&'static str> {
    ENTRIES.iter().map(|e| e.name).collect()
}

/// Whether `name` resolves to a policy (canonical, alias, or `top<k>`).
pub fn is_known(name: &str) -> bool {
    lookup(name).is_some() || parse_top_k(name).is_some()
}

/// Construct the policy registered under `name` with `opts`; None for
/// unknown names.
pub fn build(name: &str, opts: &ProphetOptions) -> Option<Box<dyn BalancingPolicy>> {
    if let Some(entry) = lookup(name) {
        return Some(entry.build(opts));
    }
    parse_top_k(name).map(|k| Box::new(builtin::TopK::new(k)) as Box<dyn BalancingPolicy>)
}

/// Multi-line listing for `--help` and `pro-prophet info`.
pub fn describe() -> String {
    let mut out = String::from("registered balancing policies:\n");
    for e in ENTRIES {
        let alias = if e.aliases.is_empty() {
            String::new()
        } else {
            format!(" (alias: {})", e.aliases.join(", "))
        };
        out.push_str(&format!("  {:<14}{}{}\n", e.name, e.summary, alias));
    }
    out
}

fn lookup(name: &str) -> Option<&'static PolicyEntry> {
    ENTRIES
        .iter()
        .find(|e| e.name == name || e.aliases.contains(&name))
}

/// `top<k>` with k >= 1 (top2/top3 are also first-class entries).
fn parse_top_k(name: &str) -> Option<usize> {
    name.strip_prefix("top")?.parse::<usize>().ok().filter(|&k| k >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_constructs() {
        let opts = ProphetOptions::default();
        for e in ENTRIES {
            let p = build(e.name, &opts)
                .unwrap_or_else(|| panic!("registered name {:?} failed to build", e.name));
            assert!(!p.name().is_empty(), "{} has an empty display name", e.name);
            for alias in e.aliases {
                assert!(build(alias, &opts).is_some(), "alias {alias:?} broken");
            }
        }
    }

    #[test]
    fn generic_top_k_parses() {
        let opts = ProphetOptions::default();
        assert_eq!(build("top7", &opts).unwrap().name(), "top7");
        assert!(build("top0", &opts).is_none(), "top0 is not a policy");
        assert!(build("topx", &opts).is_none());
    }

    #[test]
    fn unknown_names_are_rejected() {
        let opts = ProphetOptions::default();
        for bad in ["", "magic", "pro_prophet", "deepspeedmoe"] {
            assert!(build(bad, &opts).is_none(), "{bad:?} should not resolve");
            assert!(!is_known(bad));
        }
        assert!(is_known("pro-prophet"));
        assert!(is_known("prophet"));
        assert!(is_known("top5"));
    }

    #[test]
    fn planner_only_entry_ablates_scheduler() {
        let p = build("planner-only", &ProphetOptions::default()).unwrap();
        assert_eq!(p.name(), "Pro-Prophet(planner)");
        // The ablation arm strips BOTH relaxed knobs from incoming
        // options (e.g. a `schedule = "dag_relaxed"` experiment asking
        // for the planner-only baseline): blocking Eq-6 pricing must not
        // silently become the overlap-shaped slack estimate.
        let p = build("planner-only", &ProphetOptions::dag()).unwrap();
        assert_eq!(p.name(), "Pro-Prophet(planner)");
    }

    #[test]
    fn dag_entry_and_aliases_build_the_relaxed_variant() {
        let opts = ProphetOptions::default();
        for name in ["pro-prophet-dag", "prophet-dag", "dag"] {
            let p = build(name, &opts).unwrap_or_else(|| panic!("{name:?} missing"));
            assert_eq!(p.name(), "Pro-Prophet(dag)", "{name}");
        }
        assert!(is_known("pro-prophet-dag") && is_known("dag"));
        assert!(describe().contains("pro-prophet-dag"));
    }

    #[test]
    fn listing_covers_all_entries() {
        let d = describe();
        for e in ENTRIES {
            assert!(d.contains(e.name), "listing misses {}", e.name);
        }
    }
}
