//! FlexMoE-style dynamic re-placement baseline — and the worked example
//! of adding a policy to the open [`crate::balancer`] API in one file.
//!
//! FlexMoE (Nie et al., SIGMOD'23; see PAPERS.md) monitors expert
//! popularity during training and *incrementally* expands/shrinks each
//! expert's replica set instead of re-solving placement from scratch:
//! a hot expert gains a replica on a device that sends it many tokens, a
//! cooled-down expert gives its replicas back, and a per-iteration
//! migration budget bounds how many parameter movements one adjustment
//! step may trigger.
//!
//! Contrast with the neighbours in the registry:
//! * FasterMoE re-decides from scratch every iteration and always
//!   broadcasts to ALL devices (coarse);
//! * Pro-Prophet re-plans on forecasts with a full greedy search;
//! * FlexMoE carries yesterday's placement forward and nudges it — cheap
//!   decisions, bounded movement, but it reacts one iteration late and
//!   has no overlap scheduler.
//!
//! This file imports **nothing from `sim::`** — only `moe`, `perfmodel`
//! and the trait contract — which is exactly the point: the simulator,
//! trainer and CLI run it unmodified through the registry.

use super::{
    BalancingPolicy, CommStyle, DecideCtx, Decision, LayerFeedback, PolicyCounters, ScheduleKind,
};
use crate::moe::{LoadMatrix, Placement};
use std::sync::{Arc, Mutex};

/// Knobs of the FlexMoE-style baseline.
#[derive(Clone, Copy, Debug)]
pub struct FlexMoeConfig {
    /// Adjust only while max/mean per-device computed load exceeds this
    /// (1.0 = always chase perfect balance; FlexMoE tolerates slack).
    pub imbalance_trigger: f64,
    /// Replica expansions + shrinks one observation step may perform per
    /// layer (the migration budget bounding Trans volume per iteration).
    pub migration_budget: usize,
}

impl Default for FlexMoeConfig {
    fn default() -> Self {
        FlexMoeConfig { imbalance_trigger: 1.1, migration_budget: 4 }
    }
}

/// Per-layer state: the placement carried across iterations.
#[derive(Debug, Default)]
struct LayerState {
    /// Current placement (None until the first matrix fixes the shape).
    placement: Option<Arc<Placement>>,
    /// The last observation changed the placement; the next decide pays
    /// one Plan cost for it.
    pending_adjustment: bool,
    plans_run: usize,
    plans_reused: usize,
}

impl LayerState {
    /// Current placement, (re)initialized to identity on first use or
    /// shape change.
    fn placement_for(&mut self, w: &LoadMatrix) -> Arc<Placement> {
        let stale = match &self.placement {
            Some(p) => p.n_experts() != w.n_experts() || p.n_devices() != w.n_devices(),
            None => true,
        };
        if stale {
            self.placement = Some(Arc::new(Placement::identity(w.n_experts(), w.n_devices())));
            self.pending_adjustment = false;
        }
        Arc::clone(self.placement.as_ref().unwrap())
    }
}

/// The policy. One `LayerState` per MoE layer, behind per-layer locks so
/// `decide` can fan out with `&self`.
#[derive(Debug, Default)]
pub struct FlexMoe {
    pub cfg: FlexMoeConfig,
    layers: Vec<Mutex<LayerState>>,
}

impl FlexMoe {
    pub fn new(cfg: FlexMoeConfig) -> Self {
        FlexMoe { cfg, layers: Vec::new() }
    }
}

impl BalancingPolicy for FlexMoe {
    fn name(&self) -> String {
        "FlexMoE".into()
    }

    fn bind(&mut self, n_layers: usize) {
        self.layers = (0..n_layers).map(|_| Mutex::new(LayerState::default())).collect();
    }

    fn decide(&self, layer: usize, w: &LoadMatrix, ctx: &DecideCtx<'_>) -> Decision {
        let mut st = self
            .layers
            .get(layer)
            .expect("FlexMoe::decide before bind()")
            .lock()
            .expect("layer lock poisoned");
        let placement = st.placement_for(w);
        let plan_cost = if st.pending_adjustment {
            st.pending_adjustment = false;
            st.plans_run += 1;
            ctx.pm.t_plan
        } else {
            st.plans_reused += 1;
            0.0
        };
        Decision {
            placement,
            plan_cost,
            comm_style: CommStyle::Pipelined,
            // On homogeneous clusters FlexMoE keeps the frozen Blocking
            // timeline (it has no overlap scheduler of its own).  On a
            // straggler cluster it upgrades to the relaxed-DAG execution
            // mode: dynamic re-placement systems (FlexMoE, LAER-MoE)
            // claim their wins in exactly this regime by letting the
            // runtime schedule around the slow device, and DagRelaxed is
            // the execution mode that models that — dependency-driven
            // issue instead of stage barriers.  (The straggler itself is
            // visible either way: heterogeneous runs are DES-priced
            // since PR 4; this changes how the iteration is ASSEMBLED.)
            schedule_kind: if ctx.pm.is_heterogeneous() {
                ScheduleKind::DagRelaxed
            } else {
                ScheduleKind::Blocking
            },
        }
    }

    fn observe(&mut self, layer: usize, w: &LoadMatrix, _fb: &LayerFeedback) {
        let mut st = self.layers[layer].lock().expect("layer lock poisoned");
        // Adjust a WORKING COPY against the freshly observed load; the
        // result serves the next iteration's decide.
        let mut p = (*st.placement_for(w)).clone();
        if adjust_placement(&mut p, w, &self.cfg) {
            st.placement = Some(Arc::new(p));
            st.pending_adjustment = true;
        }
    }

    fn counters(&self) -> PolicyCounters {
        let mut c = PolicyCounters::default();
        for st in &self.layers {
            let st = st.lock().expect("layer lock poisoned");
            c.plans_run += st.plans_run;
            c.plans_reused += st.plans_reused;
        }
        c
    }
}

/// One FlexMoE adjustment step: shrink replicas of cooled-down experts,
/// then expand hot experts towards their token sources, spending at most
/// `cfg.migration_budget` replica changes.  Returns whether anything
/// changed.  Deterministic: ties break towards the lowest index.
fn adjust_placement(p: &mut Placement, w: &LoadMatrix, cfg: &FlexMoeConfig) -> bool {
    let d = w.n_devices();
    let e_count = w.n_experts();
    if d < 2 || w.total_tokens() == 0 {
        return false;
    }
    let total = w.total_tokens();
    let mut changed = false;
    let mut budget = cfg.migration_budget;

    // Shrink: an expert whose whole load fits the per-device average no
    // longer justifies replication — give its replicas back (reclaims
    // memory and future Agg volume, FlexMoE's "shrink" transition).
    for e in 0..e_count {
        if budget == 0 {
            break;
        }
        if p.replicas(e).len() > 1 && w.expert_load(e).saturating_mul(d as u64) <= total {
            p.set_replicas(e, [p.home(e)]);
            changed = true;
            budget -= 1;
        }
    }

    // Expand: while the computed load is imbalanced, replicate the
    // hottest device's most remote-fed expert onto its largest token
    // source (routing then computes those tokens at the source — the
    // lightweight-placement effect, without FasterMoE's full broadcast).
    //
    // Each step re-routes in full: bounded by the migration budget (a
    // handful of O(D·E) passes, same order as the simulator's own
    // pricing), unlike the per-candidate re-route PR 2 eliminated from
    // the greedy search.  If budgets ever grow, port this loop to
    // `moe::RoutingState` deltas.
    while budget > 0 {
        let h = w.route(p).h;
        let max = h.iter().copied().max().unwrap_or(0);
        let mean = total as f64 / d as f64;
        if (max as f64) <= cfg.imbalance_trigger * mean.max(1.0) {
            break;
        }
        let mut hot = 0;
        for (i, &v) in h.iter().enumerate() {
            if v > h[hot] {
                hot = i;
            }
        }
        // Best (expert homed on hot, source device) by remote inflow.
        let mut best: Option<(u64, usize, usize)> = None;
        for e in (0..e_count).filter(|&e| p.home(e) == hot) {
            for src in (0..d).filter(|&src| !p.replicas(e).contains(src)) {
                let inflow = w.get(src, e);
                if inflow > 0 && best.map_or(true, |(b, _, _)| inflow > b) {
                    best = Some((inflow, e, src));
                }
            }
        }
        match best {
            Some((_, e, src)) => {
                p.add_replica(e, src);
                changed = true;
                budget -= 1;
            }
            None => break, // hot device's load is not expandable
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;
    use crate::metrics::balance_degree;
    use crate::perfmodel::PerfModel;

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &ClusterSpec::hpwnv(1))
    }

    /// Expert 0 (homed on device 0) is fed mostly by devices 1-3.
    fn skewed_w() -> LoadMatrix {
        LoadMatrix::from_rows(vec![
            vec![100, 64, 64, 64],
            vec![300, 64, 64, 64],
            vec![300, 64, 64, 64],
            vec![300, 64, 64, 64],
        ])
    }

    #[test]
    fn first_decision_is_identity_and_free() {
        let mut p = FlexMoe::default();
        p.bind(1);
        let pm = pm();
        let d = p.decide(0, &skewed_w(), &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert!(d.placement.is_identity());
        assert_eq!(d.plan_cost, 0.0);
        assert_eq!(d.schedule_kind, ScheduleKind::Blocking);
    }

    #[test]
    fn observation_expands_hot_expert_towards_sources() {
        let mut p = FlexMoe::default();
        p.bind(1);
        let pm = pm();
        let w = skewed_w();
        let ctx = DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() };
        p.decide(0, &w, &ctx);
        p.observe(0, &w, &LayerFeedback::default());
        let d = p.decide(0, &w, &ctx);
        assert!(!d.placement.is_identity(), "imbalance must trigger expansion");
        assert!(d.placement.replicas(0).len() > 1, "expert 0 is the hot one");
        assert!(
            d.placement.replicas(0).len() < 4,
            "expansion is incremental, not a FasterMoE broadcast"
        );
        assert_eq!(d.plan_cost, pm.t_plan, "the adjustment pays one Plan cost");
        assert!(d.placement.validate().is_ok());
        // The adjusted placement balances the observed load better.
        let before = balance_degree(&w.route_identity().h);
        let after = balance_degree(&w.route(&d.placement).h);
        assert!(after < before, "balance degree {after} !< {before}");
        assert_eq!(p.counters().plans_run, 1);
        assert_eq!(p.counters().plans_reused, 1);
    }

    #[test]
    fn straggler_switches_flexmoe_to_dag_relaxed() {
        let mut p = FlexMoe::default();
        p.bind(1);
        let cluster = ClusterSpec::hpwnv(1).with_slowdown(2, 2.0);
        let pm_het = PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &cluster);
        let d = p.decide(0, &skewed_w(), &DecideCtx { pm: &pm_het, prophet: None, rec: crate::obs::noop() });
        assert_eq!(d.schedule_kind, ScheduleKind::DagRelaxed);
        // Homogeneous clusters keep the frozen Blocking pricing.
        let d = p.decide(0, &skewed_w(), &DecideCtx { pm: &pm(), prophet: None, rec: crate::obs::noop() });
        assert_eq!(d.schedule_kind, ScheduleKind::Blocking);
    }

    #[test]
    fn balanced_load_is_left_alone() {
        let mut p = FlexMoe::default();
        p.bind(1);
        let w = LoadMatrix::from_rows(vec![vec![256; 4]; 4]);
        p.observe(0, &w, &LayerFeedback::default());
        let pm = pm();
        let d = p.decide(0, &w, &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert!(d.placement.is_identity());
        assert_eq!(d.plan_cost, 0.0);
    }

    #[test]
    fn cooled_expert_shrinks_back() {
        let mut p = FlexMoe::new(FlexMoeConfig { migration_budget: 8, ..Default::default() });
        p.bind(1);
        let pm = pm();
        let ctx = DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() };
        let hot = skewed_w();
        p.decide(0, &hot, &ctx);
        p.observe(0, &hot, &LayerFeedback::default());
        assert!(p.decide(0, &hot, &ctx).placement.replicas(0).len() > 1);
        // Load evens out: the replicas are given back.
        let cool = LoadMatrix::from_rows(vec![vec![256; 4]; 4]);
        p.observe(0, &cool, &LayerFeedback::default());
        let d = p.decide(0, &cool, &ctx);
        assert!(d.placement.is_identity(), "shrink must reclaim replicas");
    }

    #[test]
    fn migration_budget_bounds_changes() {
        let mut p = FlexMoe::new(FlexMoeConfig {
            imbalance_trigger: 1.0,
            migration_budget: 1,
        });
        p.bind(1);
        let w = skewed_w();
        p.observe(0, &w, &LayerFeedback::default());
        let pm = pm();
        let d = p.decide(0, &w, &DecideCtx { pm: &pm, prophet: None, rec: crate::obs::noop() });
        assert_eq!(
            d.placement.transfer_copies(),
            1,
            "budget 1 allows exactly one replica move per observation"
        );
    }
}
