//! Summary statistics used throughout metrics, benches and the balance
//! degree definition of the paper (std-dev of the input distribution).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (the paper's "balance degree" is the std
/// of the input-distribution tensor).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean); 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp: NaN sorts to the end (after +inf) instead of panicking —
    // the same NaN hole PR 9 closed in the DES interval merge.
    v.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation of two equal-length series.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 0.0;
    }
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

/// Mean absolute percentage error of `est` vs ground truth `real`
/// (Fig 13 reports the performance model's mean estimation error).
pub fn mape(est: &[f64], real: &[f64]) -> f64 {
    assert_eq!(est.len(), real.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (e, r) in est.iter().zip(real) {
        if r.abs() > 1e-12 {
            acc += ((e - r) / r).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

/// Online mean/std accumulator (Welford) for streaming bench timings.
#[derive(Clone, Debug)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    /// Same as [`Welford::new`].  A derived `Default` would start
    /// `min`/`max` at 0.0, corrupting them for any all-positive (or
    /// all-negative) series.
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn mape_zero_for_exact() {
        assert_eq!(mape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mape(&[1.1], &[1.0]) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_survives_nan() {
        // Regression: the old `partial_cmp(..).unwrap()` comparator
        // panicked on NaN input.  total_cmp sorts NaN after +inf, so the
        // finite quantiles are unaffected and nothing panics.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn welford_default_is_new() {
        // Regression: the derived Default started min/max at 0.0, so an
        // all-positive series reported min() == 0.0.
        let mut w = Welford::default();
        w.push(5.0);
        w.push(7.0);
        assert_eq!(w.min(), 5.0);
        assert_eq!(w.max(), 7.0);
        let neg = {
            let mut w = Welford::default();
            w.push(-3.0);
            w
        };
        assert_eq!(neg.max(), -3.0);
    }

    #[test]
    fn cv_balance_direction() {
        // Perfectly balanced load has cv 0; skewed load has larger cv.
        assert_eq!(cv(&[5.0, 5.0, 5.0]), 0.0);
        assert!(cv(&[14.0, 1.0, 0.0]) > 1.0);
    }
}
