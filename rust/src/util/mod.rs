//! Small self-contained substrates (no external crates are available
//! offline, so PRNG, JSON, CLI parsing, bitsets, statistics and the
//! property-testing harness are all built here).

pub mod bitset;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threads;
