//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! The `rand` crate is unavailable offline; this is the standard public
//! domain generator pair (Blackman & Vigna), plus the distribution helpers
//! the workload generator needs (uniform, normal, Dirichlet-ish gamma,
//! multinomial, Zipf).

/// xoshiro256** seeded via SplitMix64. Deterministic and portable.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-layer / per-device rngs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free 128-bit multiply method (Lemire).
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Marsaglia–Tsang gamma sampler, shape `a` > 0, scale 1.
    pub fn gamma(&mut self, a: f64) -> f64 {
        if a < 1.0 {
            // Boost via Gamma(a) = Gamma(a+1) * U^(1/a).
            let g = self.gamma(a + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / a);
        }
        let d = a - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet sample with per-component concentrations.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut out: Vec<f64> = alpha.iter().map(|&a| self.gamma(a).max(1e-12)).collect();
        let sum: f64 = out.iter().sum();
        for v in &mut out {
            *v /= sum;
        }
        out
    }

    /// Multinomial: distribute `n` trials over `probs` (must sum to ~1).
    pub fn multinomial(&mut self, n: u64, probs: &[f64]) -> Vec<u64> {
        let mut out = vec![0u64; probs.len()];
        let mut remaining = n;
        let mut rest: f64 = 1.0;
        for (i, &p) in probs.iter().enumerate() {
            if remaining == 0 {
                break;
            }
            if i + 1 == probs.len() {
                out[i] = remaining;
                break;
            }
            let q = (p / rest).clamp(0.0, 1.0);
            let draw = self.binomial(remaining, q);
            out[i] = draw;
            remaining -= draw;
            rest = (rest - p).max(1e-12);
        }
        out
    }

    /// Binomial(n, p) — inversion for small n·p, normal approx for large.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        let np = n as f64 * p;
        if n < 64 || np < 16.0 || (n as f64 * (1.0 - p)) < 16.0 {
            // Direct Bernoulli sum (n small enough).
            let mut c = 0;
            for _ in 0..n {
                if self.f64() < p {
                    c += 1;
                }
            }
            c
        } else {
            // Normal approximation with continuity correction, clamped.
            let sd = (np * (1.0 - p)).sqrt();
            let x = (np + sd * self.normal() + 0.5).floor();
            x.clamp(0.0, n as f64) as u64
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s`.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // CDF inversion over precomputed-free harmonic approximation:
        // fall back to linear scan (n is small everywhere we use this).
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &a in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let m = (0..n).map(|_| r.gamma(a)).sum::<f64>() / n as f64;
            assert!((m - a).abs() < 0.15 * a.max(0.3), "shape {a} mean {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(15);
        let p = r.dirichlet(&[0.5, 1.0, 2.0, 4.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn multinomial_conserves_total() {
        let mut r = Rng::new(17);
        let probs = [0.1, 0.2, 0.3, 0.4];
        for _ in 0..100 {
            let c = r.multinomial(1000, &probs);
            assert_eq!(c.iter().sum::<u64>(), 1000);
        }
    }

    #[test]
    fn multinomial_proportions() {
        let mut r = Rng::new(19);
        let probs = [0.7, 0.2, 0.1];
        let c = r.multinomial(200_000, &probs);
        for (ci, pi) in c.iter().zip(probs.iter()) {
            let frac = *ci as f64 / 200_000.0;
            assert!((frac - pi).abs() < 0.02, "{frac} vs {pi}");
        }
    }

    #[test]
    fn binomial_edge_cases() {
        let mut r = Rng::new(21);
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
        let x = r.binomial(1_000_000, 0.5);
        assert!((x as f64 - 500_000.0).abs() < 5_000.0);
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(23);
        let mut counts = [0usize; 8];
        for _ in 0..10_000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 3);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(25);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
