//! Fixed-capacity bitset used for device sets in expert placements.
//!
//! Device counts in the paper top out at 32; we support arbitrary sizes via
//! a small Vec<u64> but keep the API minimal and allocation-light.

/// A set of small unsigned integers (device ids).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    pub fn new(capacity: usize) -> Self {
        BitSet { words: vec![0; capacity.div_ceil(64)], capacity }
    }

    pub fn full(capacity: usize) -> Self {
        let mut s = Self::new(capacity);
        for i in 0..capacity {
            s.insert(i);
        }
        s
    }

    pub fn singleton(capacity: usize, bit: usize) -> Self {
        let mut s = Self::new(capacity);
        s.insert(bit);
        s
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, bit: usize) {
        assert!(bit < self.capacity, "bit {bit} >= capacity {}", self.capacity);
        self.words[bit / 64] |= 1u64 << (bit % 64);
    }

    #[inline]
    pub fn remove(&mut self, bit: usize) {
        assert!(bit < self.capacity);
        self.words[bit / 64] &= !(1u64 << (bit % 64));
    }

    /// Remove every bit (in place, no reallocation).
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Insert every bit in `0..capacity` (in place, no reallocation).
    pub fn insert_all(&mut self) {
        self.words.fill(!0);
        let tail = self.capacity % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    #[inline]
    pub fn contains(&self, bit: usize) -> bool {
        bit < self.capacity && self.words[bit / 64] & (1u64 << (bit % 64)) != 0
    }

    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.capacity).filter(move |&i| self.contains(i))
    }

    pub fn union_with(&mut self, other: &BitSet) {
        assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    pub fn intersect_with(&mut self, other: &BitSet) {
        assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{{")?;
        for (n, i) in self.iter().enumerate() {
            if n > 0 {
                write!(f, ",")?;
            }
            write!(f, "{i}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(100);
        assert!(!s.contains(70));
        s.insert(70);
        assert!(s.contains(70));
        assert_eq!(s.len(), 1);
        s.remove(70);
        assert!(!s.contains(70));
        assert!(s.is_empty());
    }

    #[test]
    fn full_and_singleton() {
        assert_eq!(BitSet::full(33).len(), 33);
        let s = BitSet::singleton(8, 3);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn union_intersect() {
        let mut a = BitSet::singleton(10, 1);
        let b = BitSet::singleton(10, 2);
        a.union_with(&b);
        assert_eq!(a.len(), 2);
        let mut c = a.clone();
        c.intersect_with(&b);
        assert_eq!(c.iter().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        BitSet::new(8).insert(8);
    }

    #[test]
    fn clear_and_insert_all_in_place() {
        for cap in [0usize, 1, 8, 63, 64, 65, 130] {
            let mut s = BitSet::new(cap);
            s.insert_all();
            assert_eq!(s.len(), cap, "insert_all must fill exactly {cap} bits");
            assert_eq!(s, BitSet::full(cap));
            s.clear();
            assert!(s.is_empty());
            assert_eq!(s.capacity(), cap);
        }
    }

    #[test]
    fn iter_order_ascending() {
        let mut s = BitSet::new(70);
        for &b in &[65, 2, 40] {
            s.insert(b);
        }
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![2, 40, 65]);
    }
}
