//! Scoped-thread fan-out helpers (no external crates offline, so a tiny
//! deterministic chunked map built on `std::thread::scope`).
//!
//! Used by the simulator, session and trainer to parallelize per-layer
//! work (planning, pricing, histogram spreading) across MoE layers.
//! Results are always returned in input order, so parallel and serial
//! execution are observably identical; `PRO_PROPHET_THREADS=1` forces
//! serial and any explicit `PRO_PROPHET_THREADS=N` overrides the
//! work-size heuristic below.
//!
//! Callers pass a `work` hint — approximate units of work per item
//! (conventionally the D·E cell count of the layer's load matrix).  When
//! the whole map's `items × work` falls under
//! [`SERIAL_WORK_THRESHOLD`], the map stays serial: thread spawn
//! overhead (tens of µs per worker) dominates planning/pricing at tiny
//! (D, E), which is exactly the regime the ROADMAP flagged.

/// Total work units (items × per-item hint) below which fan-outs stay
/// serial.  Calibrated coarsely: one D·E "unit" costs on the order of
/// tens of ns in planning/pricing, a spawned worker costs tens of µs, so
/// a map under ~4k units cannot amortize even one extra thread.
pub const SERIAL_WORK_THRESHOLD: usize = 4096;

/// Worker threads to use for `tasks` independent items of roughly
/// `work_per_task` units each: the machine's available parallelism,
/// capped by the task count, serial below [`SERIAL_WORK_THRESHOLD`].
/// An explicit `PRO_PROPHET_THREADS` (>0) overrides both the auto count
/// and the threshold; 0/unset = auto.
pub fn for_tasks(tasks: usize, work_per_task: usize) -> usize {
    let explicit = std::env::var("PRO_PROPHET_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0);
    thread_count(tasks, work_per_task, explicit)
}

/// The pure decision behind [`for_tasks`]: `explicit` is the parsed
/// `PRO_PROPHET_THREADS` override (None/0 = auto).  Split out so the
/// threshold and override rules are testable without mutating
/// process-global environment (setenv races with concurrent readers).
pub fn thread_count(tasks: usize, work_per_task: usize, explicit: Option<usize>) -> usize {
    let n = match explicit {
        Some(n) => n,
        None => {
            if tasks.saturating_mul(work_per_task.max(1)) < SERIAL_WORK_THRESHOLD {
                1
            } else {
                std::thread::available_parallelism()
                    .map_or(1, std::num::NonZeroUsize::get)
            }
        }
    };
    n.min(tasks).max(1)
}

/// `out[i] = f(i)` for `i in 0..n`, fanned out over scoped threads in
/// contiguous chunks (serial below the work threshold).  Deterministic:
/// identical to the serial map.
pub fn par_map<T, F>(n: usize, work_per_task: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = for_tasks(n, work_per_task);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (ci, slots) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + i));
                }
            });
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("par_map worker panicked"))
        .collect()
}

/// `out[i] = f(i, &mut items[i])`, fanned out over scoped threads.  Each
/// worker owns a disjoint sub-slice, so per-item mutable state (e.g. one
/// `Planner` per MoE layer) parallelizes without locks.
pub fn par_map_mut<P, T, F>(items: &mut [P], work_per_task: usize, f: F) -> Vec<T>
where
    P: Send,
    T: Send,
    F: Fn(usize, &mut P) -> T + Sync,
{
    let n = items.len();
    let threads = for_tasks(n, work_per_task);
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, p)| f(i, p)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for ((ci, slots), part) in
            out.chunks_mut(chunk).enumerate().zip(items.chunks_mut(chunk))
        {
            let f = &f;
            s.spawn(move || {
                for ((i, slot), p) in slots.iter_mut().enumerate().zip(part.iter_mut()) {
                    *slot = Some(f(ci * chunk + i, p));
                }
            });
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("par_map_mut worker panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hint large enough that n >= 2 always crosses the threshold.
    const BIG: usize = SERIAL_WORK_THRESHOLD;

    #[test]
    fn par_map_matches_serial_in_order() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            let got = par_map(n, BIG, |i| i * i + 1);
            let want: Vec<usize> = (0..n).map(|i| i * i + 1).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn par_map_mut_mutates_each_item_once() {
        let mut items: Vec<u64> = (0..37).collect();
        let doubled = par_map_mut(&mut items, BIG, |i, p| {
            *p *= 2;
            (i as u64, *p)
        });
        for (i, &(idx, v)) in doubled.iter().enumerate() {
            assert_eq!(idx, i as u64);
            assert_eq!(v, 2 * i as u64);
            assert_eq!(items[i], 2 * i as u64);
        }
    }

    #[test]
    fn results_identical_on_both_sides_of_threshold() {
        // The regression gate for the work-size heuristic: tiny work
        // (serial path) and huge work (parallel path) must be observably
        // identical, for both map flavors.
        for n in [1usize, 3, 16, 257] {
            let serial = par_map(n, 1, |i| i.wrapping_mul(31) ^ 7);
            let parallel = par_map(n, BIG, |i| i.wrapping_mul(31) ^ 7);
            assert_eq!(serial, parallel, "par_map n={n}");

            let mut a: Vec<u64> = (0..n as u64).collect();
            let mut b = a.clone();
            let ra = par_map_mut(&mut a, 1, |i, p| {
                *p += i as u64;
                *p
            });
            let rb = par_map_mut(&mut b, BIG, |i, p| {
                *p += i as u64;
                *p
            });
            assert_eq!(ra, rb, "par_map_mut n={n}");
            assert_eq!(a, b);
        }
    }

    #[test]
    fn thread_count_bounds() {
        assert_eq!(thread_count(0, BIG, None), 1);
        assert_eq!(thread_count(1, BIG, None), 1);
        assert!(thread_count(1000, BIG, None) >= 1);
        // Saturating total-work product: no overflow panic.
        assert!(thread_count(usize::MAX, usize::MAX, None) >= 1);
    }

    #[test]
    fn work_threshold_and_explicit_override() {
        // All assertions go through the pure `thread_count` so the test
        // neither mutates process-global environment (setenv races with
        // every concurrent par_map caller reading it) nor breaks when a
        // developer runs the suite with PRO_PROPHET_THREADS exported.

        // Auto mode, tiny work: 12 layers of an 8x8 load matrix (the
        // ROADMAP's "tiny D·E" case) stays serial; one task never fans
        // out regardless of work.
        assert_eq!(thread_count(12, 64, None), 1);
        assert_eq!(thread_count(1, usize::MAX, None), 1, "one task never fans out");
        assert!(thread_count(12, BIG, None) >= 1);

        // PRO_PROPHET_THREADS=1 is the manual escape hatch and an
        // explicit count beats the work heuristic in both directions.
        assert_eq!(thread_count(1000, BIG, Some(1)), 1);
        assert_eq!(thread_count(1000, 1, Some(3)), 3);
        // 0/unparsable map to None before thread_count (see for_tasks).
        assert_eq!(thread_count(1000, 1, None), 1);
    }

    #[test]
    fn for_tasks_is_consistent_with_current_env() {
        // The env plumbing itself, WITHOUT mutating the variable: read
        // whatever is set and check for_tasks agrees with thread_count
        // fed the same parse.  Holds whether or not the suite runs with
        // PRO_PROPHET_THREADS exported.
        let explicit = std::env::var("PRO_PROPHET_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0);
        for (tasks, work) in [(0, BIG), (1, 1), (12, 64), (1000, BIG)] {
            assert_eq!(for_tasks(tasks, work), thread_count(tasks, work, explicit));
        }
    }
}
