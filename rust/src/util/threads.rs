//! Scoped-thread fan-out helpers (no external crates offline, so a tiny
//! deterministic chunked map built on `std::thread::scope`).
//!
//! Used by the simulator and trainer to parallelize per-layer work
//! (planning, pricing, histogram spreading) across MoE layers.  Results
//! are always returned in input order, so parallel and serial execution
//! are observably identical; `PRO_PROPHET_THREADS=1` forces serial.

/// Worker threads to use for `tasks` independent items: the machine's
/// available parallelism, capped by the task count, overridable via the
/// `PRO_PROPHET_THREADS` environment variable (0/unset = auto).
pub fn for_tasks(tasks: usize) -> usize {
    let auto = std::thread::available_parallelism().map_or(1, std::num::NonZeroUsize::get);
    let n = std::env::var("PRO_PROPHET_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(auto);
    n.min(tasks).max(1)
}

/// `out[i] = f(i)` for `i in 0..n`, fanned out over scoped threads in
/// contiguous chunks.  Deterministic: identical to the serial map.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = for_tasks(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (ci, slots) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + i));
                }
            });
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("par_map worker panicked"))
        .collect()
}

/// `out[i] = f(i, &mut items[i])`, fanned out over scoped threads.  Each
/// worker owns a disjoint sub-slice, so per-item mutable state (e.g. one
/// `Planner` per MoE layer) parallelizes without locks.
pub fn par_map_mut<P, T, F>(items: &mut [P], f: F) -> Vec<T>
where
    P: Send,
    T: Send,
    F: Fn(usize, &mut P) -> T + Sync,
{
    let n = items.len();
    let threads = for_tasks(n);
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, p)| f(i, p)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for ((ci, slots), part) in
            out.chunks_mut(chunk).enumerate().zip(items.chunks_mut(chunk))
        {
            let f = &f;
            s.spawn(move || {
                for ((i, slot), p) in slots.iter_mut().enumerate().zip(part.iter_mut()) {
                    *slot = Some(f(ci * chunk + i, p));
                }
            });
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("par_map_mut worker panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial_in_order() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            let got = par_map(n, |i| i * i + 1);
            let want: Vec<usize> = (0..n).map(|i| i * i + 1).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn par_map_mut_mutates_each_item_once() {
        let mut items: Vec<u64> = (0..37).collect();
        let doubled = par_map_mut(&mut items, |i, p| {
            *p *= 2;
            (i as u64, *p)
        });
        for (i, &(idx, v)) in doubled.iter().enumerate() {
            assert_eq!(idx, i as u64);
            assert_eq!(v, 2 * i as u64);
            assert_eq!(items[i], 2 * i as u64);
        }
    }

    #[test]
    fn thread_count_bounds() {
        assert_eq!(for_tasks(0), 1);
        assert_eq!(for_tasks(1), 1);
        assert!(for_tasks(1000) >= 1);
    }
}
