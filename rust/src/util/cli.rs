//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. `known_flags` lists options that
    /// take no value (anything else starting with `--` consumes one).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        iter: I,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` = end of options.
                    positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        return Err(format!("option --{body} is missing a value"));
                    }
                    opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    return Err(format!("option --{body} is missing a value"));
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { opts, flags, positional })
    }

    /// Parse std::env::args() (skipping argv[0]).
    pub fn from_env(known_flags: &[&str]) -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--steps", "100", "--preset=e2e"], &[]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.str_or("preset", "tiny"), "e2e");
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["train", "--verbose", "--k", "2", "extra"], &["verbose"]);
        assert_eq!(a.subcommand(), Some("train"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("k", 1), 2);
        assert_eq!(a.positional(), &["train".to_string(), "extra".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]);
        assert_eq!(a.f64_or("alpha", 0.1), 0.1);
        assert!(!a.flag("missing"));
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse_from(
            ["--steps".to_string(), "--other".to_string()],
            &[]
        )
        .is_err());
        assert!(Args::parse_from(["--steps".to_string()], &[]).is_err());
    }

    #[test]
    fn double_dash_ends_options() {
        let a = parse(&["--", "--not-an-option"], &[]);
        assert_eq!(a.positional(), &["--not-an-option".to_string()]);
    }

    #[test]
    #[should_panic]
    fn type_error_panics() {
        parse(&["--steps", "abc"], &[]).usize_or("steps", 0);
    }
}
