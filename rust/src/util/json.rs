//! Minimal JSON: a recursive-descent parser (for `artifacts/*_manifest.json`)
//! and a writer (for metrics / bench reports). serde is unavailable offline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Strict integer read: `None` for negative, non-finite, or
    /// fractional numbers (an `as usize` cast would silently saturate
    /// them to 0, letting a malformed checkpoint or metrics file parse
    /// as valid).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (stable key order — Obj is a BTreeMap).
    /// Kept inherent (not `Display`) because callers treat it as the
    /// one-and-only wire format, not a human rendering.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report building.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = rest.get(..ch_len).ok_or("bad utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| "bad utf8")?);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e1").unwrap(), Json::Num(-125.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":null},"z":true}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ⚡\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ⚡"));
    }

    #[test]
    fn manifest_shape() {
        // Mirrors the structure aot.py emits.
        let src = r#"{
          "preset": "tiny",
          "config": {"n_experts": 4, "num_tensors": 30},
          "tensors": [{"name": "tok_emb", "shape": [64, 32]}],
          "artifacts": {"train_step": "tiny_train_step.hlo.txt"}
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("config").unwrap().get("n_experts").unwrap().as_usize(), Some(4));
        let t0 = v.get("tensors").unwrap().idx(0).unwrap();
        assert_eq!(t0.get("shape").unwrap().idx(1).unwrap().as_usize(), Some(32));
    }

    #[test]
    fn as_usize_rejects_non_integers() {
        // Regression: `as_f64().map(|x| x as usize)` silently saturated
        // negative and non-finite numbers to 0, so a malformed
        // checkpoint field like `"iterations_done": -3` parsed as a
        // valid 0 instead of failing the schema gate.
        assert_eq!(parse("-3").unwrap().as_usize(), None);
        assert_eq!(parse("-0.5").unwrap().as_usize(), None);
        assert_eq!(parse("2.5").unwrap().as_usize(), None);
        assert_eq!(parse("1e400").unwrap().as_usize(), None, "overflows to +inf");
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::NEG_INFINITY).as_usize(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None, "strings never coerce");
        // The valid cases checkpoint/report actually rely on.
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(parse("40").unwrap().as_usize(), Some(40));
        assert_eq!(parse("4e2").unwrap().as_usize(), Some(400));
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":["a"]}"#);
    }
}
