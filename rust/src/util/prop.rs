//! Property-based testing harness (proptest is unavailable offline).
//!
//! A `Cases` driver runs a property over many seeded-random inputs and, on
//! failure, reports the seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop::Cases::new(200).run(|rng| {
//!     let d = rng.below(30) + 2;
//!     // ... build a random input, assert the invariant ...
//! });
//! ```

use super::rng::Rng;

/// Number of cases is configurable via PROP_CASES (useful for soak runs).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

pub struct Cases {
    n: usize,
    base_seed: u64,
}

impl Cases {
    pub fn new(n: usize) -> Self {
        let base_seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Cases { n, base_seed }
    }

    pub fn default() -> Self {
        Self::new(default_cases())
    }

    /// Run `property` across `n` deterministic random cases.  Panics (with
    /// the case seed in the message) on the first failing case.
    pub fn run<F: FnMut(&mut Rng)>(&self, mut property: F) {
        for case in 0..self.n {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut rng = Rng::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                property(&mut rng)
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property failed on case {case} (replay with PROP_SEED={seed} PROP_CASES=1): {msg}"
                );
            }
        }
    }
}

/// Helper: random vector of counts with a given total (token histogram).
pub fn random_histogram(rng: &mut Rng, buckets: usize, total: u64, skew: f64) -> Vec<u64> {
    let alpha: Vec<f64> = (0..buckets).map(|_| skew.max(1e-3)).collect();
    let p = rng.dirichlet(&alpha);
    rng.multinomial(total, &p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Cases::new(32).run(|rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_failure_with_seed() {
        let err = std::panic::catch_unwind(|| {
            Cases::new(16).run(|rng| {
                assert!(rng.below(10) != 3, "hit the forbidden value");
            });
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
    }

    #[test]
    fn histogram_total_conserved() {
        Cases::new(32).run(|rng| {
            let h = random_histogram(rng, 8, 1000, 0.3);
            assert_eq!(h.iter().sum::<u64>(), 1000);
            assert_eq!(h.len(), 8);
        });
    }
}
