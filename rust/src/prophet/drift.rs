//! Drift detection: forecast-vs-observation error thresholding with a
//! cooldown, replacing the planner's former ad-hoc similarity check.
//!
//! Locality (paper Fig 4) justifies reusing a placement across
//! iterations, but it breaks at workload boundaries.  The detector
//! watches the similarity between what the prophet forecast and what the
//! gate actually routed; a drop below the threshold forces a replan
//! regardless of the replan interval.  The cooldown suppresses trigger
//! storms while the predictors re-converge on the new regime (each
//! trigger already forces a replan — re-triggering every iteration inside
//! the transient would only burn search time).

/// The shared distribution-similarity core (re-exported so drift callers
/// and the `prophet` façade keep one obvious name for it).
pub use crate::metrics::similarity_f64;

/// Threshold + cooldown drift detector.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    /// Minimum forecast/observation similarity before drift is declared.
    pub threshold: f64,
    /// Checks to suppress after a trigger (0 = may trigger every check).
    pub cooldown: usize,
    /// Checks since the last trigger (saturating).
    since_trigger: usize,
    /// Lifetime trigger count.
    pub triggers: usize,
    /// Lifetime check count.
    pub checks: usize,
}

impl DriftDetector {
    pub fn new(threshold: f64, cooldown: usize) -> Self {
        assert!((0.0..=1.0).contains(&threshold), "threshold {threshold} out of [0,1]");
        DriftDetector {
            threshold,
            cooldown,
            since_trigger: usize::MAX,
            triggers: 0,
            checks: 0,
        }
    }

    /// Compare a forecast against the observation it was made for.
    /// Returns true when drift is declared (and the cooldown re-arms).
    pub fn check(&mut self, expected: &[f64], observed: &[f64]) -> bool {
        self.checks += 1;
        let sim = similarity_f64(expected, observed);
        if sim < self.threshold && self.since_trigger >= self.cooldown {
            self.since_trigger = 0;
            self.triggers += 1;
            true
        } else {
            self.since_trigger = self.since_trigger.saturating_add(1);
            false
        }
    }

    /// Integer-count convenience (planner-side distributions).
    pub fn check_counts(&mut self, expected: &[u64], observed: &[u64]) -> bool {
        let e: Vec<f64> = expected.iter().map(|&x| x as f64).collect();
        let o: Vec<f64> = observed.iter().map(|&x| x as f64).collect();
        self.check(&e, &o)
    }

    /// True while the cooldown suppresses triggers.
    pub fn cooling_down(&self) -> bool {
        self.since_trigger < self.cooldown
    }

    pub fn reset(&mut self) {
        self.since_trigger = usize::MAX;
        self.triggers = 0;
        self.checks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn similarity_f64_matches_integer_version() {
        use crate::planner::locality::similarity;
        let a = [5u64, 3, 2];
        let b = [10u64, 6, 4];
        let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
        assert!((similarity_f64(&af, &bf) - similarity(&a, &b)).abs() < 1e-12);
        assert_eq!(similarity_f64(&[0.0], &[0.0]), 1.0);
        assert_eq!(similarity_f64(&[1.0], &[0.0]), 0.0);
    }

    #[test]
    fn triggers_on_shift_not_on_stability() {
        let mut d = DriftDetector::new(0.9, 0);
        assert!(!d.check(&[100.0, 100.0], &[105.0, 95.0]));
        assert!(d.check(&[100.0, 100.0], &[500.0, 10.0]));
        assert_eq!(d.triggers, 1);
        assert_eq!(d.checks, 2);
    }

    #[test]
    fn cooldown_suppresses_storms() {
        let mut d = DriftDetector::new(0.9, 3);
        let stable = [100.0, 100.0];
        let shifted = [500.0, 10.0];
        assert!(d.check(&stable, &shifted)); // first trigger
        assert!(!d.check(&stable, &shifted)); // suppressed (1)
        assert!(d.cooling_down());
        assert!(!d.check(&stable, &shifted)); // suppressed (2)
        assert!(!d.check(&stable, &shifted)); // suppressed (3)
        assert!(d.check(&stable, &shifted)); // cooldown elapsed
        assert_eq!(d.triggers, 2);
    }

    #[test]
    fn first_check_can_trigger() {
        // A fresh detector is armed (no warm-up grace period).
        let mut d = DriftDetector::new(0.9, 10);
        assert!(d.check(&[1.0, 0.0], &[0.0, 1.0]));
    }

    #[test]
    fn check_counts_agrees_with_check() {
        let mut a = DriftDetector::new(0.8, 0);
        let mut b = DriftDetector::new(0.8, 0);
        let hit_a = a.check_counts(&[10, 0], &[0, 10]);
        let hit_b = b.check(&[10.0, 0.0], &[0.0, 10.0]);
        assert_eq!(hit_a, hit_b);
    }
}
