//! Bounded trace store: a ring buffer of per-iteration, per-layer load
//! matrices — the training-statistics history every prophet component
//! reads.  Persists via the existing `workload::trace` text format, so
//! stored history interoperates with `pro-prophet trace`, the simulator
//! and the benches.

use crate::moe::LoadMatrix;
use crate::workload::Trace;
use std::collections::VecDeque;
use std::path::Path;

/// Ring buffer of the last `capacity` iterations of per-layer gating
/// statistics.  Dimensions are locked in by the first pushed iteration.
#[derive(Clone, Debug)]
pub struct TraceStore {
    capacity: usize,
    n_layers: usize,
    n_devices: usize,
    n_experts: usize,
    /// iterations[i][l] = layer l's load matrix, oldest first.
    iterations: VecDeque<Vec<LoadMatrix>>,
    /// Lifetime iterations pushed (including evicted ones).
    total_pushed: usize,
}

impl TraceStore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "store capacity must be >= 1");
        TraceStore {
            capacity,
            n_layers: 0,
            n_devices: 0,
            n_experts: 0,
            iterations: VecDeque::with_capacity(capacity),
            total_pushed: 0,
        }
    }

    /// Record one iteration, evicting the oldest when full.  The first
    /// push fixes (layers, devices, experts); later pushes must match.
    pub fn push(&mut self, layers: Vec<LoadMatrix>) {
        assert!(!layers.is_empty(), "iteration must contain >= 1 layer");
        if self.total_pushed == 0 {
            self.n_layers = layers.len();
            self.n_devices = layers[0].n_devices();
            self.n_experts = layers[0].n_experts();
        }
        assert_eq!(layers.len(), self.n_layers, "layer count changed");
        for w in &layers {
            assert_eq!(w.n_devices(), self.n_devices, "device count changed");
            assert_eq!(w.n_experts(), self.n_experts, "expert count changed");
        }
        if self.iterations.len() == self.capacity {
            self.iterations.pop_front();
        }
        self.iterations.push_back(layers);
        self.total_pushed += 1;
    }

    pub fn len(&self) -> usize {
        self.iterations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.iterations.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn total_pushed(&self) -> usize {
        self.total_pushed
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Most recent iteration (all layers).
    pub fn latest(&self) -> Option<&[LoadMatrix]> {
        self.iterations.back().map(Vec::as_slice)
    }

    /// Most recent load matrix of one layer.
    pub fn latest_layer(&self, layer: usize) -> Option<&LoadMatrix> {
        self.iterations.back().and_then(|it| it.get(layer))
    }

    /// One layer's history, oldest first.
    pub fn layer_history(&self, layer: usize) -> Vec<&LoadMatrix> {
        self.iterations.iter().filter_map(|it| it.get(layer)).collect()
    }

    /// One layer's distribution history (token counts per expert), oldest
    /// first — the predictor family's training stream.
    pub fn distributions(&self, layer: usize) -> Vec<Vec<u64>> {
        self.iterations
            .iter()
            .filter_map(|it| it.get(layer))
            .map(LoadMatrix::distribution)
            .collect()
    }

    /// Snapshot the buffered history as a [`Trace`] (for persistence or
    /// replay through the simulator).
    pub fn to_trace(&self) -> Trace {
        let mut t = Trace::new(self.n_layers, self.n_devices, self.n_experts);
        for layers in &self.iterations {
            t.push(layers.clone());
        }
        t
    }

    /// Build a store from a trace, keeping only the newest `capacity`
    /// iterations (the ring-buffer semantics applied retroactively).
    pub fn from_trace(capacity: usize, trace: &Trace) -> TraceStore {
        let mut store = TraceStore::new(capacity);
        let skip = trace.len().saturating_sub(capacity);
        for layers in trace.iterations.iter().skip(skip) {
            store.push(layers.clone());
        }
        // Dimension metadata survives even for an empty trace.
        if store.total_pushed == 0 {
            store.n_layers = trace.n_layers;
            store.n_devices = trace.n_devices;
            store.n_experts = trace.n_experts;
        } else {
            store.total_pushed = trace.len();
        }
        store
    }

    /// Persist to the `workload::trace` v1 text format.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        self.to_trace().save(path)
    }

    /// Load from a trace file, keeping the newest `capacity` iterations.
    pub fn load(capacity: usize, path: &Path) -> Result<TraceStore, String> {
        Ok(Self::from_trace(capacity, &Trace::load(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadConfig, WorkloadGen};

    fn gen_iterations(n: usize) -> Vec<Vec<LoadMatrix>> {
        let mut g = WorkloadGen::new(WorkloadConfig::paper_default(2, 4, 4, 1024));
        (0..n).map(|_| g.next_iteration()).collect()
    }

    #[test]
    fn ring_buffer_evicts_oldest() {
        let mut s = TraceStore::new(3);
        let its = gen_iterations(5);
        for it in &its {
            s.push(it.clone());
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.total_pushed(), 5);
        // Oldest two evicted: history starts at iteration 2.
        assert_eq!(s.layer_history(0)[0], &its[2][0]);
        assert_eq!(s.latest_layer(1), Some(&its[4][1]));
        assert_eq!(s.distributions(0).len(), 3);
    }

    #[test]
    fn persistence_roundtrips_via_trace_format() {
        let mut s = TraceStore::new(8);
        for it in gen_iterations(4) {
            s.push(it);
        }
        let path = std::env::temp_dir().join("prophet_store_roundtrip.txt");
        s.save(&path).unwrap();
        let back = TraceStore::load(8, &path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.len(), 4);
        assert_eq!(back.to_trace(), s.to_trace());
    }

    #[test]
    fn load_respects_capacity() {
        let mut s = TraceStore::new(16);
        for it in gen_iterations(6) {
            s.push(it.clone());
        }
        let path = std::env::temp_dir().join("prophet_store_capacity.txt");
        s.save(&path).unwrap();
        let back = TraceStore::load(2, &path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.len(), 2);
        // The kept iterations are the NEWEST two.
        assert_eq!(
            back.latest_layer(0).unwrap().distribution(),
            s.latest_layer(0).unwrap().distribution()
        );
    }

    #[test]
    fn empty_store_accessors() {
        let s = TraceStore::new(4);
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert!(s.latest().is_none());
        assert!(s.latest_layer(0).is_none());
        assert!(s.layer_history(0).is_empty());
    }

    #[test]
    #[should_panic]
    fn shape_change_rejected() {
        let mut s = TraceStore::new(4);
        s.push(vec![LoadMatrix::zeros(4, 4)]);
        s.push(vec![LoadMatrix::zeros(4, 4), LoadMatrix::zeros(4, 4)]);
    }
}
