//! Pro-Prophet's profiling & forecasting subsystem: own the training
//! statistics, predict the next iteration's load, and decide when the
//! world has drifted enough to force a replan.
//!
//! Data flow (trainer/simulator → prophet → planner):
//!
//! ```text
//!   gate loads (LoadMatrix per layer)
//!        │ observe_layer()
//!        ▼
//!   [store]     ring-buffer history (persistable as workload::trace v1)
//!   [ensemble]  per-layer predictor family + online model selection
//!   [drift]     forecast-error threshold + cooldown
//!        │ forecast_matrix()
//!        ▼
//!   planner::Planner::plan()  — runs one iteration EARLY on the forecast
//! ```
//!
//! The paper profiles training statistics and feeds them to the planner
//! (§III–§V); this module makes that a first-class subsystem instead of a
//! single EMA bolted onto the planner.  "Prediction Is All MoE Needs"
//! (arXiv:2404.16914) motivates the predictor family: expert loads move
//! from fluctuating to stabilizing and are highly predictable from
//! history.

pub mod device;
pub mod drift;
pub mod ensemble;
pub mod predictors;
pub mod store;

pub use device::DeviceForecaster;
pub use drift::{similarity_f64, DriftDetector};
pub use ensemble::{Ensemble, PredictorScore};
pub use predictors::{LoadPredictor, PredictorKind};
pub use store::TraceStore;

use crate::moe::LoadMatrix;

/// Prophet knobs (config-file `[prophet]` table / CLI flags).
#[derive(Clone, Debug, PartialEq)]
pub struct ProphetConfig {
    /// Trace-store ring-buffer capacity (iterations of history kept).
    pub history: usize,
    /// EMA predictor smoothing (weight of the newest observation).
    pub ema_beta: f64,
    /// Sliding-window size for the window-mean and trend predictors.
    pub window: usize,
    /// Weight of the newest error in each predictor's rolling score.
    pub error_decay: f64,
    /// Minimum forecast/observation similarity before drift is declared.
    pub drift_threshold: f64,
    /// Iterations a drift trigger stays suppressed after firing.
    pub drift_cooldown: usize,
    /// Which predictor serves forecasts (Auto = adaptive ensemble).
    pub predictor: PredictorKind,
    /// Arm the per-device slowdown forecaster ([`DeviceForecaster`]): the
    /// balancer session learns a device-health vector from realized
    /// iteration results and the planner prices candidates against the
    /// FORECAST slowdown instead of the static `ClusterSpec` vector.
    /// Off by default — with it off, planning sees exactly the static
    /// cluster description, bit-identical to earlier builds.
    pub device_forecast: bool,
}

impl Default for ProphetConfig {
    fn default() -> Self {
        ProphetConfig {
            history: 64,
            ema_beta: 0.7,
            window: 8,
            error_decay: 0.3,
            drift_threshold: 0.8,
            drift_cooldown: 4,
            predictor: PredictorKind::Auto,
            device_forecast: false,
        }
    }
}

impl ProphetConfig {
    /// Range-check every knob, so config files and CLI flags fail with a
    /// proper error instead of a panic deep inside `Prophet::new`.
    pub fn validate(&self) -> Result<(), String> {
        if self.history < 1 {
            return Err("prophet.history must be >= 1".into());
        }
        if self.window < 1 {
            return Err("prophet.window must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.ema_beta) {
            return Err(format!("prophet.ema_beta {} out of [0,1]", self.ema_beta));
        }
        if !(self.error_decay > 0.0 && self.error_decay <= 1.0) {
            return Err(format!("prophet.error_decay {} out of (0,1]", self.error_decay));
        }
        if !(0.0..=1.0).contains(&self.drift_threshold) {
            return Err(format!(
                "prophet.drift_threshold {} out of [0,1]",
                self.drift_threshold
            ));
        }
        Ok(())
    }
}

/// What one observation told us about one layer.
#[derive(Clone, Debug)]
pub struct LayerObservation {
    /// The drift detector declared a regime change; the planner's cached
    /// placement for this layer should be invalidated.
    pub drift: bool,
    /// Normalized-L1 error of the forecast that was served for this
    /// iteration (None when no forecast existed yet).
    pub forecast_error: Option<f64>,
}

/// Per-layer forecasting state.
struct LayerCell {
    ensemble: Ensemble,
    drift: DriftDetector,
    /// Forecast currently outstanding (what we told the planner).
    served: Option<Vec<f64>>,
}

/// The subsystem: one ensemble + drift detector per MoE layer, sharing a
/// bounded trace store.
pub struct Prophet {
    pub cfg: ProphetConfig,
    store: TraceStore,
    layers: Vec<LayerCell>,
    /// Layers of the iteration currently being observed (flushed to the
    /// store when all `n_layers` have arrived).
    pending: Vec<LoadMatrix>,
}

impl Prophet {
    pub fn new(cfg: ProphetConfig, n_layers: usize) -> Self {
        assert!(n_layers >= 1, "need at least one layer");
        let layers = (0..n_layers)
            .map(|_| LayerCell {
                ensemble: Ensemble::new(
                    cfg.predictor,
                    cfg.ema_beta,
                    cfg.window,
                    cfg.error_decay,
                ),
                drift: DriftDetector::new(cfg.drift_threshold, cfg.drift_cooldown),
                served: None,
            })
            .collect();
        Prophet {
            store: TraceStore::new(cfg.history.max(1)),
            layers,
            pending: Vec::with_capacity(n_layers),
            cfg,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Record one layer's observed gating result.  Layers must arrive in
    /// order 0..n_layers; completing a full iteration flushes it to the
    /// trace store.  Scores the outstanding forecast, runs drift
    /// detection, and re-arms the next forecast.
    pub fn observe_layer(&mut self, layer: usize, w: &LoadMatrix) -> LayerObservation {
        assert_eq!(
            layer,
            self.pending.len(),
            "layers must be observed in order (expected layer {}, got {layer})",
            self.pending.len()
        );
        let dist = w.distribution();
        let cell = &mut self.layers[layer];
        let drift = match &cell.served {
            Some(forecast) => {
                let observed: Vec<f64> = dist.iter().map(|&x| x as f64).collect();
                cell.drift.check(forecast, &observed)
            }
            None => false,
        };
        let forecast_error = cell.ensemble.observe(&dist);
        cell.served = cell.ensemble.predict();
        self.pending.push(w.clone());
        if self.pending.len() == self.layers.len() {
            self.store.push(std::mem::take(&mut self.pending));
        }
        LayerObservation { drift, forecast_error }
    }

    /// Record a whole iteration at once.
    pub fn observe_iteration(&mut self, layers: &[LoadMatrix]) -> Vec<LayerObservation> {
        assert_eq!(layers.len(), self.layers.len(), "layer count mismatch");
        layers
            .iter()
            .enumerate()
            .map(|(l, w)| self.observe_layer(l, w))
            .collect()
    }

    /// The forecast distribution (tokens per expert) outstanding for
    /// `layer`'s next iteration.
    pub fn forecast(&self, layer: usize) -> Option<&[f64]> {
        self.layers[layer].served.as_deref()
    }

    /// Forecast as a full [`LoadMatrix`] the planner can consume: the
    /// latest observed matrix of the layer is rescaled column-by-column to
    /// the forecast distribution, preserving the device affinity of each
    /// expert's inputs (experts with no observed inputs are spread evenly).
    pub fn forecast_matrix(&self, layer: usize) -> Option<LoadMatrix> {
        let forecast = self.forecast(layer)?;
        let last = self
            .pending
            .get(layer)
            .or_else(|| self.store.latest_layer(layer))?;
        let n_devices = last.n_devices();
        let n_experts = last.n_experts();
        assert_eq!(forecast.len(), n_experts, "forecast width mismatch");
        let mut w = LoadMatrix::zeros(n_devices, n_experts);
        for e in 0..n_experts {
            let target = forecast[e].max(0.0);
            let col: u64 = (0..n_devices).map(|d| last.get(d, e)).sum();
            if col > 0 {
                for d in 0..n_devices {
                    let scaled = last.get(d, e) as f64 * target / col as f64;
                    w.set(d, e, scaled.round() as u64);
                }
            } else {
                // No affinity information: spread evenly (same split rule
                // as the trainer's spread_histogram).
                let t = target.round() as u64;
                for d in 0..n_devices {
                    w.set(d, e, crate::moe::even_split(t, n_devices, d));
                }
            }
        }
        Some(w)
    }

    /// Name of the predictor currently serving `layer`'s forecasts.
    pub fn selected_predictor(&self, layer: usize) -> &'static str {
        self.layers[layer].ensemble.selected_name()
    }

    /// Per-predictor scoreboard for one layer.
    pub fn scores(&self, layer: usize) -> Vec<PredictorScore> {
        self.layers[layer].ensemble.scores()
    }

    /// Mean forecast error per predictor, aggregated across layers
    /// (NaN-free: layers that never scored a predictor are skipped).
    pub fn aggregate_scores(&self) -> Vec<(String, f64, f64)> {
        let names: Vec<&'static str> =
            self.layers[0].ensemble.scores().iter().map(|s| s.name).collect();
        names
            .iter()
            .map(|&name| {
                let mut l1 = 0.0;
                let mut cos = 0.0;
                let mut n = 0usize;
                for cell in &self.layers {
                    for s in cell.ensemble.scores() {
                        if s.name == name && s.evaluations > 0 {
                            l1 += s.mean_l1;
                            cos += s.mean_cosine;
                            n += 1;
                        }
                    }
                }
                if n == 0 {
                    (name.to_string(), f64::NAN, f64::NAN)
                } else {
                    (name.to_string(), l1 / n as f64, cos / n as f64)
                }
            })
            .collect()
    }

    /// Lifetime drift triggers across all layers.
    pub fn drift_triggers(&self) -> usize {
        self.layers.iter().map(|c| c.drift.triggers).sum()
    }

    /// The shared statistics history.
    pub fn store(&self) -> &TraceStore {
        &self.store
    }

    /// Reset all forecasting state (drops history and scoreboards).
    pub fn reset(&mut self) {
        let capacity = self.store.capacity();
        self.store = TraceStore::new(capacity);
        self.pending.clear();
        for cell in &mut self.layers {
            cell.ensemble.reset();
            cell.drift.reset();
            cell.served = None;
        }
    }
}

impl std::fmt::Debug for Prophet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Prophet")
            .field("cfg", &self.cfg)
            .field("layers", &self.layers.len())
            .field("history", &self.store.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{WorkloadConfig, WorkloadGen};

    fn gen(drift: f64) -> WorkloadGen {
        let mut cfg = WorkloadConfig::paper_default(3, 8, 8, 8192);
        cfg.drift = drift;
        WorkloadGen::new(cfg)
    }

    #[test]
    fn forecast_appears_after_one_iteration() {
        let mut p = Prophet::new(ProphetConfig::default(), 3);
        let mut g = gen(0.05);
        assert!(p.forecast_matrix(0).is_none());
        p.observe_iteration(&g.next_iteration());
        for l in 0..3 {
            assert!(p.forecast(l).is_some());
            let w = p.forecast_matrix(l).unwrap();
            assert_eq!(w.n_devices(), 8);
            assert_eq!(w.n_experts(), 8);
        }
    }

    #[test]
    fn last_value_forecast_matrix_reproduces_last_matrix() {
        // When the served forecast IS the last distribution, the rescaled
        // matrix is exactly the last observed matrix.
        let cfg = ProphetConfig {
            predictor: PredictorKind::LastValue,
            ..Default::default()
        };
        let mut p = Prophet::new(cfg, 1);
        let mut g = gen(0.05);
        let it = g.next_iteration();
        p.observe_iteration(&it);
        assert_eq!(p.forecast_matrix(0).unwrap(), it[0]);
    }

    #[test]
    fn forecasts_beat_nothing_on_local_workloads() {
        // On a high-locality stream the served forecast error stays small.
        let mut p = Prophet::new(ProphetConfig::default(), 3);
        let mut g = gen(0.05);
        let mut errs = Vec::new();
        for _ in 0..15 {
            for obs in p.observe_iteration(&g.next_iteration()) {
                if let Some(e) = obs.forecast_error {
                    errs.push(e);
                }
            }
        }
        assert!(!errs.is_empty());
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean < 0.15, "forecast error too large: {mean}");
    }

    #[test]
    fn drift_fires_on_regime_change_only() {
        let cfg = ProphetConfig {
            drift_threshold: 0.7,
            drift_cooldown: 2,
            ..Default::default()
        };
        let mut p = Prophet::new(cfg, 1);
        let stable = LoadMatrix::from_rows(vec![vec![800, 50, 50, 124]; 4]);
        for _ in 0..5 {
            let obs = p.observe_iteration(std::slice::from_ref(&stable));
            assert!(!obs[0].drift, "stable stream must not drift");
        }
        // Violent shift: the heavy expert moves.
        let shifted = LoadMatrix::from_rows(vec![vec![50, 50, 800, 124]; 4]);
        let obs = p.observe_iteration(std::slice::from_ref(&shifted));
        assert!(obs[0].drift, "regime change must trigger drift");
        assert_eq!(p.drift_triggers(), 1);
    }

    #[test]
    fn store_collects_full_iterations() {
        let mut p = Prophet::new(ProphetConfig { history: 4, ..Default::default() }, 2);
        let mut g = WorkloadGen::new(WorkloadConfig::paper_default(2, 8, 8, 8192));
        for _ in 0..6 {
            p.observe_iteration(&g.next_iteration());
        }
        assert_eq!(p.store().len(), 4);
        assert_eq!(p.store().total_pushed(), 6);
        assert_eq!(p.store().n_layers(), 2);
    }

    #[test]
    #[should_panic]
    fn out_of_order_layers_rejected() {
        let mut p = Prophet::new(ProphetConfig::default(), 2);
        let w = LoadMatrix::zeros(4, 4);
        p.observe_layer(1, &w);
    }
}
