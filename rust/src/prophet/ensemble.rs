//! Online ensemble over the predictor family: every member forecasts each
//! iteration, each forecast is scored against the next observation
//! (normalized L1 + cosine), and the member with the lowest rolling error
//! serves the forecast.  This is the adaptive selection FlexMoE-style
//! monitoring enables (arXiv:2304.03946) without committing to a single
//! model of the load dynamics.

use super::predictors::{self, LoadPredictor, PredictorKind};
use crate::metrics::{cosine_similarity, normalized_l1};

/// Per-predictor scoreboard entry (for reports and the fig_forecast bench).
#[derive(Clone, Debug)]
pub struct PredictorScore {
    pub name: &'static str,
    /// Exponentially-decayed normalized-L1 error (the selection criterion).
    pub rolling_l1: f64,
    /// Lifetime mean normalized-L1 error.
    pub mean_l1: f64,
    /// Lifetime mean cosine similarity of forecast vs observation.
    pub mean_cosine: f64,
    /// Iterations this predictor was the one serving forecasts.
    pub selections: usize,
    /// Forecasts of this predictor that were scored.
    pub evaluations: usize,
}

/// Adaptive forecaster: the full family plus online model selection.
pub struct Ensemble {
    predictors: Vec<Box<dyn LoadPredictor>>,
    /// Rolling (exponentially decayed) normalized-L1 error per predictor;
    /// NAN until the predictor has been scored once.
    rolling: Vec<f64>,
    sum_l1: Vec<f64>,
    sum_cos: Vec<f64>,
    evals: Vec<usize>,
    selections: Vec<usize>,
    /// Index of the member currently serving forecasts.
    selected: usize,
    /// `Some(i)` pins selection to member i (non-Auto [`PredictorKind`]).
    forced: Option<usize>,
    /// Weight of the newest error in the rolling average.
    error_decay: f64,
    observations: usize,
}

impl Ensemble {
    /// Build the family.  `kind` = [`PredictorKind::Auto`] selects
    /// adaptively; any other kind pins that member (the others still
    /// observe and are scored, so reports can compare them).
    pub fn new(kind: PredictorKind, ema_beta: f64, window: usize, error_decay: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&error_decay) && error_decay > 0.0,
            "error_decay {error_decay} out of (0,1]"
        );
        let predictors = predictors::family(ema_beta, window);
        let forced = match kind {
            PredictorKind::Auto => None,
            k => predictors.iter().position(|p| p.name() == k.name()),
        };
        let n = predictors.len();
        Ensemble {
            predictors,
            rolling: vec![f64::NAN; n],
            sum_l1: vec![0.0; n],
            sum_cos: vec![0.0; n],
            evals: vec![0; n],
            selections: vec![0; n],
            selected: forced.unwrap_or(0),
            forced,
            error_decay,
            observations: 0,
        }
    }

    /// Score every member's outstanding forecast against `dist`, feed the
    /// observation to all members, and re-select.  Returns the normalized
    /// L1 error of the forecast that was actually SERVED for this
    /// iteration (None when no forecast existed yet).
    pub fn observe(&mut self, dist: &[u64]) -> Option<f64> {
        let mut served_error = None;
        for (i, p) in self.predictors.iter().enumerate() {
            if let Some(forecast) = p.predict() {
                let l1 = normalized_l1(&forecast, dist);
                let cos = cosine_similarity(&forecast, dist);
                self.rolling[i] = if self.rolling[i].is_nan() {
                    l1
                } else {
                    self.error_decay * l1 + (1.0 - self.error_decay) * self.rolling[i]
                };
                self.sum_l1[i] += l1;
                self.sum_cos[i] += cos;
                self.evals[i] += 1;
                if i == self.selected {
                    served_error = Some(l1);
                }
            }
        }
        for p in &mut self.predictors {
            p.observe(dist);
        }
        self.selected = match self.forced {
            Some(i) => i,
            None => self.best_by_rolling(),
        };
        self.selections[self.selected] += 1;
        self.observations += 1;
        served_error
    }

    fn best_by_rolling(&self) -> usize {
        let mut best = 0;
        let mut best_err = f64::INFINITY;
        for (i, &r) in self.rolling.iter().enumerate() {
            if !r.is_nan() && r < best_err {
                best_err = r;
                best = i;
            }
        }
        best
    }

    /// Forecast served for the next iteration (from the selected member;
    /// falls back to any member with a forecast so one observation is
    /// always enough to start planning early).
    pub fn predict(&self) -> Option<Vec<f64>> {
        self.predictors[self.selected]
            .predict()
            .or_else(|| self.predictors.iter().find_map(|p| p.predict()))
    }

    pub fn selected_name(&self) -> &'static str {
        self.predictors[self.selected].name()
    }

    pub fn observations(&self) -> usize {
        self.observations
    }

    /// Scoreboard over the whole family.
    pub fn scores(&self) -> Vec<PredictorScore> {
        (0..self.predictors.len())
            .map(|i| PredictorScore {
                name: self.predictors[i].name(),
                rolling_l1: self.rolling[i],
                mean_l1: if self.evals[i] > 0 {
                    self.sum_l1[i] / self.evals[i] as f64
                } else {
                    f64::NAN
                },
                mean_cosine: if self.evals[i] > 0 {
                    self.sum_cos[i] / self.evals[i] as f64
                } else {
                    f64::NAN
                },
                selections: self.selections[i],
                evaluations: self.evals[i],
            })
            .collect()
    }

    /// Reset all members and the scoreboard (workload boundary).
    pub fn reset(&mut self) {
        for p in &mut self.predictors {
            p.reset();
        }
        self.rolling.fill(f64::NAN);
        self.sum_l1.fill(0.0);
        self.sum_cos.fill(0.0);
        self.evals.fill(0);
        self.selections.fill(0);
        self.selected = self.forced.unwrap_or(0);
        self.observations = 0;
    }
}

impl std::fmt::Debug for Ensemble {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ensemble")
            .field("selected", &self.selected_name())
            .field("observations", &self.observations)
            .field("rolling", &self.rolling)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_after_one_observation() {
        let mut e = Ensemble::new(PredictorKind::Auto, 0.7, 4, 0.3);
        assert!(e.predict().is_none());
        assert!(e.observe(&[5, 5]).is_none()); // nothing to score yet
        assert!(e.predict().is_some());
    }

    #[test]
    fn converges_to_trend_on_linear_ramp() {
        // A steady ramp: trend forecasts exactly, last/ema/window lag.
        let mut e = Ensemble::new(PredictorKind::Auto, 0.7, 4, 0.3);
        for t in 0..20u64 {
            e.observe(&[100 + 40 * t, 1000 - 40 * t]);
        }
        assert_eq!(e.selected_name(), "trend");
        let scores = e.scores();
        let trend = scores.iter().find(|s| s.name == "trend").unwrap();
        let last = scores.iter().find(|s| s.name == "last").unwrap();
        assert!(trend.mean_l1 < last.mean_l1);
        assert!(trend.selections > 0);
    }

    #[test]
    fn converges_to_smoother_on_noisy_constant() {
        // Alternating noise around a constant: averaging beats last-value.
        let mut e = Ensemble::new(PredictorKind::Auto, 0.5, 6, 0.3);
        for t in 0..40u64 {
            let jitter = if t % 2 == 0 { 60 } else { 0 };
            e.observe(&[300 + jitter, 300 + (60 - jitter)]);
        }
        assert_ne!(e.selected_name(), "last");
        let scores = e.scores();
        let window = scores.iter().find(|s| s.name == "window").unwrap();
        let last = scores.iter().find(|s| s.name == "last").unwrap();
        assert!(
            window.mean_l1 < last.mean_l1,
            "window {} !< last {}",
            window.mean_l1,
            last.mean_l1
        );
    }

    #[test]
    fn forced_kind_pins_selection() {
        let mut e = Ensemble::new(PredictorKind::Ema, 0.7, 4, 0.3);
        for t in 0..10u64 {
            e.observe(&[10 * t, 100]);
        }
        assert_eq!(e.selected_name(), "ema");
    }

    #[test]
    fn served_error_reflects_forecast_quality() {
        let mut e = Ensemble::new(PredictorKind::LastValue, 0.7, 4, 0.3);
        e.observe(&[100, 0]);
        // Forecast was [100, 0]; observation identical -> zero error.
        assert!(e.observe(&[100, 0]).unwrap() < 1e-12);
        // Forecast still [100, 0]; observation flipped -> maximal error.
        assert!((e.observe(&[0, 100]).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reset_restarts() {
        let mut e = Ensemble::new(PredictorKind::Auto, 0.7, 4, 0.3);
        e.observe(&[1, 2]);
        e.reset();
        assert_eq!(e.observations(), 0);
        assert!(e.predict().is_none());
    }
}
