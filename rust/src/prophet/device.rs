//! Per-device slowdown forecasting: the device-health half of closing
//! ROADMAP Next-direction 1.
//!
//! The load side of the prophet forecasts *what* arrives next iteration
//! (tokens per expert); this module forecasts *how fast* each device will
//! run it.  A [`DeviceForecaster`] learns a slowdown vector from the
//! realized per-iteration device health (the same composed vector
//! `faults::FaultView` prices the DES with — in a real system this would
//! be the profiler's measured per-device busy-time ratios) and serves a
//! one-step-ahead forecast the planner consumes through
//! [`crate::perfmodel::PerfModel::with_device_slowdown`], replacing the
//! static `ClusterSpec::device_slowdown` as the candidate evaluator's
//! view of device health.
//!
//! Implementation: the existing [`Ensemble`] machinery (last/ema/window/
//! trend members scored by rolling L1 error) already does online
//! one-step-ahead forecasting of `u64` vectors — a slowdown vector is
//! just not integer-valued, so observations are encoded in fixed point
//! ([`SCALE`] = 1e-6 resolution).  Round-trip is exact for every factor
//! the config surface can express (1.0, 2.5, 0.5, ... — anything with at
//! most 6 decimal places), so a constant vector forecasts back exactly
//! (property-tested).
//!
//! A down device reports slowdown 0.0; the forecaster clamps it to
//! [`MIN_SLOWDOWN`] instead of learning "infinitely fast": down-ness is
//! the health monitor's job (mask + failover), the forecast only models
//! the speed of devices that are running.

use super::ensemble::Ensemble;
use super::ProphetConfig;

/// Fixed-point encoding: slowdown 1.0 ⇔ 1_000_000 ensemble units.
const SCALE: f64 = 1e6;

/// Floor for observed factors: a down device (slowdown 0.0) must not
/// teach the forecaster that the device is infinitely fast.
pub const MIN_SLOWDOWN: f64 = 1e-3;

/// Online per-device slowdown forecaster (see module docs).
pub struct DeviceForecaster {
    ensemble: Ensemble,
    n_devices: usize,
    /// Reused encode buffer: steady-state observation is allocation-free
    /// on this side (the ensemble members keep their own state).
    encoded: Vec<u64>,
    observations: usize,
}

impl DeviceForecaster {
    /// One forecaster per run, sized to the cluster; reuses the prophet's
    /// knobs (predictor kind, EMA beta, window, error decay).
    pub fn new(cfg: &ProphetConfig, n_devices: usize) -> Self {
        assert!(n_devices >= 1, "need at least one device");
        DeviceForecaster {
            ensemble: Ensemble::new(cfg.predictor, cfg.ema_beta, cfg.window, cfg.error_decay),
            n_devices,
            encoded: Vec::with_capacity(n_devices),
            observations: 0,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Iterations observed so far.
    pub fn observations(&self) -> usize {
        self.observations
    }

    /// Feed one iteration's realized slowdown vector (missing entries
    /// mean 1.0 — nominal).  Returns the normalized-L1 error of the
    /// forecast that was outstanding for this iteration, when one was.
    pub fn observe(&mut self, slowdown: &[f64]) -> Option<f64> {
        self.encoded.clear();
        for d in 0..self.n_devices {
            let s = slowdown.get(d).copied().unwrap_or(1.0).max(MIN_SLOWDOWN);
            debug_assert!(s.is_finite(), "non-finite slowdown observation");
            self.encoded.push((s * SCALE).round() as u64);
        }
        self.observations += 1;
        self.ensemble.observe(&self.encoded)
    }

    /// One-step-ahead slowdown forecast (`None` until the first
    /// observation).  Entries are clamped to [`MIN_SLOWDOWN`].
    pub fn forecast(&self) -> Option<Vec<f64>> {
        let f = self.ensemble.predict()?;
        debug_assert_eq!(f.len(), self.n_devices);
        Some(f.iter().map(|&x| (x / SCALE).max(MIN_SLOWDOWN)).collect())
    }

    /// Name of the ensemble member currently serving forecasts.
    pub fn selected_predictor(&self) -> &'static str {
        self.ensemble.selected_name()
    }

    /// Drop all learned state (e.g. after a lease resize changes the
    /// device set).
    pub fn reset(&mut self) {
        self.ensemble.reset();
        self.observations = 0;
    }
}

impl std::fmt::Debug for DeviceForecaster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceForecaster")
            .field("n_devices", &self.n_devices)
            .field("observations", &self.observations)
            .field("selected", &self.selected_predictor())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prophet::PredictorKind;

    fn cfg(kind: PredictorKind) -> ProphetConfig {
        ProphetConfig { predictor: kind, ..Default::default() }
    }

    #[test]
    fn none_before_first_observation() {
        let f = DeviceForecaster::new(&cfg(PredictorKind::Auto), 4);
        assert!(f.forecast().is_none());
        assert_eq!(f.observations(), 0);
    }

    #[test]
    fn constant_vector_roundtrips_exactly_with_last_value() {
        // encode(2.5) = 2_500_000; LastValue predicts it verbatim;
        // 2_500_000 / 1e6 divides back to exactly 2.5 (both exactly
        // representable, correctly rounded quotient).
        let mut f = DeviceForecaster::new(&cfg(PredictorKind::LastValue), 4);
        let v = [1.0, 2.5, 0.5, 1.0];
        let _ = f.observe(&v);
        let got = f.forecast().unwrap();
        for (g, w) in got.iter().zip(v) {
            assert_eq!(g.to_bits(), w.to_bits(), "{g} != {w}");
        }
    }

    #[test]
    fn constant_vector_converges_for_every_kind() {
        for kind in [
            PredictorKind::Auto,
            PredictorKind::LastValue,
            PredictorKind::Ema,
            PredictorKind::WindowMean,
            PredictorKind::LinearTrend,
        ] {
            let mut f = DeviceForecaster::new(&cfg(kind), 3);
            let v = [1.0, 2.5, 1.0];
            let mut last_err = None;
            for _ in 0..6 {
                last_err = f.observe(&v);
            }
            let got = f.forecast().unwrap();
            for (g, w) in got.iter().zip(v) {
                assert!((g - w).abs() < 1e-9, "{kind:?}: {g} != {w}");
            }
            // The outstanding forecast was scored (and scored perfect).
            assert!(last_err.unwrap() < 1e-9, "{kind:?}");
        }
    }

    #[test]
    fn down_device_is_floored_not_learned_as_free() {
        let mut f = DeviceForecaster::new(&cfg(PredictorKind::LastValue), 2);
        let _ = f.observe(&[1.0, 0.0]);
        let got = f.forecast().unwrap();
        assert_eq!(got[0], 1.0);
        assert!(got[1] >= MIN_SLOWDOWN && got[1] <= 2.0 * MIN_SLOWDOWN);
    }

    #[test]
    fn short_vector_means_nominal_and_reset_forgets() {
        let mut f = DeviceForecaster::new(&cfg(PredictorKind::LastValue), 3);
        let _ = f.observe(&[2.0]);
        assert_eq!(f.forecast().unwrap(), vec![2.0, 1.0, 1.0]);
        f.reset();
        assert!(f.forecast().is_none());
        assert_eq!(f.observations(), 0);
    }

    #[test]
    fn tracks_a_step_change() {
        // 5 nominal iterations, then device 1 degrades to 3x: within a
        // few observations the forecast must follow (LastValue follows
        // immediately; Auto selects whatever scored best, which after
        // the switch converges to the new level too).
        let mut f = DeviceForecaster::new(&cfg(PredictorKind::LastValue), 2);
        for _ in 0..5 {
            let _ = f.observe(&[1.0, 1.0]);
        }
        let _ = f.observe(&[1.0, 3.0]);
        let got = f.forecast().unwrap();
        assert_eq!(got[1], 3.0);
    }
}
