//! The predictor family: one-step-ahead forecasters of a MoE layer's
//! input distribution (tokens per expert).
//!
//! Every predictor sees the stream of observed distributions and offers a
//! forecast for the NEXT iteration, so the Plan primitive can run one
//! iteration early (paper §V-A).  The family spans the spectrum the
//! literature identifies:
//!
//! * [`LastValue`] — pure locality (paper Fig 4): tomorrow looks like
//!   today.
//! * [`Ema`] — exponential smoothing (absorbs the planner's former
//!   `LocalityPredictor`).
//! * [`WindowMean`] — sliding-window mean, robust to sampling noise.
//! * [`LinearTrend`] — per-expert least-squares trend, tracks the slow
//!   popularity migration of "Prediction Is All MoE Needs"
//!   (arXiv:2404.16914).

use std::collections::VecDeque;

/// A one-step-ahead forecaster of per-expert load distributions.
///
/// `Send + Sync` are supertraits so a `Prophet` (which boxes a predictor
/// family per layer) can be shared read-only across the simulator's
/// scoped-thread planning fan-out; every in-tree predictor is plain data.
pub trait LoadPredictor: Send + Sync {
    /// Short stable identifier (used in reports and knob parsing).
    fn name(&self) -> &'static str;
    /// Feed the observed distribution of the current iteration.
    fn observe(&mut self, dist: &[u64]);
    /// Forecast for the next iteration (None until enough observations).
    /// Values are in token units (same scale as the observations).
    fn predict(&self) -> Option<Vec<f64>>;
    /// Drop all state (e.g. at a workload boundary).
    fn reset(&mut self);
}

pub(crate) fn to_f64(dist: &[u64]) -> Vec<f64> {
    dist.iter().map(|&x| x as f64).collect()
}

/// Predict exactly the last observed distribution (pure locality).
#[derive(Clone, Debug, Default)]
pub struct LastValue {
    last: Option<Vec<f64>>,
}

impl LastValue {
    pub fn new() -> Self {
        Self::default()
    }
}

impl LoadPredictor for LastValue {
    fn name(&self) -> &'static str {
        "last"
    }

    fn observe(&mut self, dist: &[u64]) {
        self.last = Some(to_f64(dist));
    }

    fn predict(&self) -> Option<Vec<f64>> {
        self.last.clone()
    }

    fn reset(&mut self) {
        self.last = None;
    }
}

/// Exponential moving average.  `beta` is the weight of the NEWEST
/// observation (1.0 degenerates to [`LastValue`]) — the same convention as
/// the planner's former `LocalityPredictor`.
#[derive(Clone, Debug)]
pub struct Ema {
    pub beta: f64,
    ema: Option<Vec<f64>>,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..=1.0).contains(&beta), "beta {beta} out of [0,1]");
        Ema { beta, ema: None }
    }
}

impl LoadPredictor for Ema {
    fn name(&self) -> &'static str {
        "ema"
    }

    fn observe(&mut self, dist: &[u64]) {
        let xs = to_f64(dist);
        self.ema = Some(match self.ema.take() {
            None => xs,
            Some(prev) => prev
                .iter()
                .zip(&xs)
                .map(|(p, x)| (1.0 - self.beta) * p + self.beta * x)
                .collect(),
        });
    }

    fn predict(&self) -> Option<Vec<f64>> {
        self.ema.clone()
    }

    fn reset(&mut self) {
        self.ema = None;
    }
}

/// Mean of the last `window` observations.
#[derive(Clone, Debug)]
pub struct WindowMean {
    pub window: usize,
    buf: VecDeque<Vec<f64>>,
}

impl WindowMean {
    pub fn new(window: usize) -> Self {
        assert!(window >= 1, "window must be >= 1");
        WindowMean { window, buf: VecDeque::new() }
    }
}

impl LoadPredictor for WindowMean {
    fn name(&self) -> &'static str {
        "window"
    }

    fn observe(&mut self, dist: &[u64]) {
        if self.buf.len() == self.window {
            self.buf.pop_front();
        }
        self.buf.push_back(to_f64(dist));
    }

    fn predict(&self) -> Option<Vec<f64>> {
        let first = self.buf.front()?;
        let mut acc = vec![0.0; first.len()];
        for obs in &self.buf {
            for (a, x) in acc.iter_mut().zip(obs) {
                *a += x;
            }
        }
        let n = self.buf.len() as f64;
        for a in &mut acc {
            *a /= n;
        }
        Some(acc)
    }

    fn reset(&mut self) {
        self.buf.clear();
    }
}

/// Per-expert least-squares linear trend over the last `window`
/// observations, extrapolated one step ahead (negative extrapolations are
/// clamped to zero — loads are counts).
#[derive(Clone, Debug)]
pub struct LinearTrend {
    pub window: usize,
    buf: VecDeque<Vec<f64>>,
}

impl LinearTrend {
    pub fn new(window: usize) -> Self {
        assert!(window >= 2, "trend window must be >= 2");
        LinearTrend { window, buf: VecDeque::new() }
    }
}

impl LoadPredictor for LinearTrend {
    fn name(&self) -> &'static str {
        "trend"
    }

    fn observe(&mut self, dist: &[u64]) {
        if self.buf.len() == self.window {
            self.buf.pop_front();
        }
        self.buf.push_back(to_f64(dist));
    }

    fn predict(&self) -> Option<Vec<f64>> {
        let n = self.buf.len();
        if n == 0 {
            return None;
        }
        if n == 1 {
            return self.buf.front().cloned();
        }
        // x = 0..n-1, forecast at x = n.  Sxx = sum (x - x̄)².
        let e = self.buf[0].len();
        let x_mean = (n - 1) as f64 / 2.0;
        let sxx: f64 = (0..n).map(|t| (t as f64 - x_mean).powi(2)).sum();
        let mut y_mean = vec![0.0; e];
        for obs in &self.buf {
            for (m, y) in y_mean.iter_mut().zip(obs) {
                *m += y;
            }
        }
        for m in &mut y_mean {
            *m /= n as f64;
        }
        let mut sxy = vec![0.0; e];
        for (t, obs) in self.buf.iter().enumerate() {
            let dx = t as f64 - x_mean;
            for (s, (y, m)) in sxy.iter_mut().zip(obs.iter().zip(&y_mean)) {
                *s += dx * (y - m);
            }
        }
        Some(
            (0..e)
                .map(|i| {
                    let slope = sxy[i] / sxx;
                    (y_mean[i] + slope * (n as f64 - x_mean)).max(0.0)
                })
                .collect(),
        )
    }

    fn reset(&mut self) {
        self.buf.clear();
    }
}

/// Which predictor (or the adaptive ensemble) serves forecasts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PredictorKind {
    /// Online ensemble: per layer, the predictor with the lowest rolling
    /// forecast error serves the forecast.
    #[default]
    Auto,
    LastValue,
    Ema,
    WindowMean,
    LinearTrend,
}

impl PredictorKind {
    pub fn from_name(name: &str) -> Option<PredictorKind> {
        match name {
            "auto" | "ensemble" => Some(PredictorKind::Auto),
            "last" | "last-value" | "locality" => Some(PredictorKind::LastValue),
            "ema" => Some(PredictorKind::Ema),
            "window" | "window-mean" | "mean" => Some(PredictorKind::WindowMean),
            "trend" | "linear-trend" | "linear" => Some(PredictorKind::LinearTrend),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PredictorKind::Auto => "auto",
            PredictorKind::LastValue => "last",
            PredictorKind::Ema => "ema",
            PredictorKind::WindowMean => "window",
            PredictorKind::LinearTrend => "trend",
        }
    }

    /// All concrete (non-Auto) members of the family.
    pub fn family() -> [PredictorKind; 4] {
        [
            PredictorKind::LastValue,
            PredictorKind::Ema,
            PredictorKind::WindowMean,
            PredictorKind::LinearTrend,
        ]
    }
}

/// Instantiate the full predictor family (ensemble member order is stable:
/// last, ema, window, trend — ties in the ensemble resolve to the earlier
/// member).
pub fn family(ema_beta: f64, window: usize) -> Vec<Box<dyn LoadPredictor>> {
    vec![
        Box::new(LastValue::new()),
        Box::new(Ema::new(ema_beta)),
        Box::new(WindowMean::new(window)),
        Box::new(LinearTrend::new(window.max(2))),
    ]
}

/// Instantiate a single predictor by kind (`Auto` maps to the whole
/// family; callers wanting the ensemble should use
/// [`super::ensemble::Ensemble`] instead).
pub fn single(kind: PredictorKind, ema_beta: f64, window: usize) -> Box<dyn LoadPredictor> {
    match kind {
        PredictorKind::Auto | PredictorKind::LastValue => Box::new(LastValue::new()),
        PredictorKind::Ema => Box::new(Ema::new(ema_beta)),
        PredictorKind::WindowMean => Box::new(WindowMean::new(window)),
        PredictorKind::LinearTrend => Box::new(LinearTrend::new(window.max(2))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(p: &mut dyn LoadPredictor, seq: &[Vec<u64>]) {
        for d in seq {
            p.observe(d);
        }
    }

    #[test]
    fn all_predictors_exact_on_constant_sequences() {
        let seq: Vec<Vec<u64>> = vec![vec![40, 10, 50]; 6];
        for mut p in family(0.6, 4) {
            feed(p.as_mut(), &seq);
            let f = p.predict().expect(p.name());
            for (got, want) in f.iter().zip([40.0, 10.0, 50.0]) {
                assert!(
                    (got - want).abs() < 1e-9,
                    "{}: {got} != {want}",
                    p.name()
                );
            }
        }
    }

    #[test]
    fn none_before_first_observation() {
        for p in family(0.5, 4) {
            assert!(p.predict().is_none(), "{}", p.name());
        }
    }

    #[test]
    fn last_value_tracks_latest() {
        let mut p = LastValue::new();
        feed(&mut p, &[vec![10, 20, 30], vec![40, 50, 60]]);
        assert_eq!(p.predict().unwrap(), vec![40.0, 50.0, 60.0]);
    }

    #[test]
    fn ema_beta_one_is_last_value() {
        let mut p = Ema::new(1.0);
        feed(&mut p, &[vec![10, 20, 30], vec![40, 50, 60]]);
        assert_eq!(p.predict().unwrap(), vec![40.0, 50.0, 60.0]);
    }

    #[test]
    fn ema_smooths() {
        let mut p = Ema::new(0.5);
        feed(&mut p, &[vec![100, 0], vec![0, 100]]);
        let f = p.predict().unwrap();
        assert!((f[0] - 50.0).abs() < 1e-9);
        assert!((f[1] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn window_mean_averages_and_slides() {
        let mut p = WindowMean::new(2);
        feed(&mut p, &[vec![0], vec![10], vec![20]]);
        // Window holds [10, 20].
        assert!((p.predict().unwrap()[0] - 15.0).abs() < 1e-9);
    }

    #[test]
    fn linear_trend_matches_ramps_exactly() {
        // y = 10 + 5t per expert 0, y = 100 - 2t per expert 1.
        let mut p = LinearTrend::new(6);
        for t in 0..5u64 {
            p.observe(&[10 + 5 * t, 100 - 2 * t]);
        }
        let f = p.predict().unwrap();
        assert!((f[0] - 35.0).abs() < 1e-9, "ramp up: {}", f[0]);
        assert!((f[1] - 90.0).abs() < 1e-9, "ramp down: {}", f[1]);
    }

    #[test]
    fn linear_trend_clamps_negative_forecasts() {
        let mut p = LinearTrend::new(4);
        for t in 0..4u64 {
            p.observe(&[30u64.saturating_sub(10 * t)]);
        }
        // Extrapolation would be negative; counts cannot be.
        assert!(p.predict().unwrap()[0] >= 0.0);
    }

    #[test]
    fn reset_clears_state() {
        for mut p in family(0.5, 3) {
            p.observe(&[1, 2, 3]);
            assert!(p.predict().is_some());
            p.reset();
            assert!(p.predict().is_none(), "{}", p.name());
        }
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in PredictorKind::family() {
            assert_eq!(PredictorKind::from_name(k.name()), Some(k));
        }
        assert_eq!(PredictorKind::from_name("auto"), Some(PredictorKind::Auto));
        assert_eq!(PredictorKind::from_name("bogus"), None);
    }
}
