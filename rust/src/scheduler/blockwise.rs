//! Schedule builders: the blocking baseline timeline and the paper's
//! block-wise overlap strategy (Algorithm 2).
//!
//! Inputs are per-block operator costs ([`BlockCosts`]); builders assemble
//! a [`Schedule`] whose stages encode exactly which communication hides
//! under which computation:
//!
//! * `Plan` of iteration *j+1* hides under the A2A of iteration *j*;
//! * `Trans` of block *i+1* splits into two sub-operators hidden under
//!   `FEC_i` and `FNEC_i` (Fig 9c), sized so the FNEC window is filled
//!   first (its duration is static and known before training, §V-B);
//! * `Agg` of block *i+1* splits under `BNEC_i` and `BEC_i`;
//! * block 0's `Trans` (start of FP) and `Agg` (end of BP) have no earlier
//!   computation to hide under and stay exposed — the scheduling-space
//!   constraint that confines Trans/Agg within one iteration (§V-A).

use super::dag::OpDag;
use super::{A2aPhase, Op, OpInstance, Schedule, Stage};

/// Modeled durations of every operator of one MoE block.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockCosts {
    pub a2a: f64,   // one A2A exchange (all four priced equally, Eq 1)
    pub fec: f64,   // forward expert computation
    pub bec: f64,   // backward expert computation (~2x fec)
    pub fnec: f64,  // forward non-MoE computation
    pub bnec: f64,  // backward non-MoE computation
    pub trans: f64, // parameter transfer of this block's placement
    pub agg: f64,   // gradient aggregation (mirrors trans)
    pub plan: f64,  // greedy-search cost for this block's next iteration
}

/// Per-device durations of every operator of one MoE block — the
/// device-level refinement of [`BlockCosts`] the DAG builders and the
/// discrete-event executor consume (each vector has one entry per
/// device; see [`crate::sim::Engine::device_block_costs_styled`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceBlockCosts {
    pub a2a: Vec<f64>,
    pub fec: Vec<f64>,
    pub bec: Vec<f64>,
    pub fnec: Vec<f64>,
    pub bnec: Vec<f64>,
    pub trans: Vec<f64>,
    pub agg: Vec<f64>,
    pub plan: Vec<f64>,
}

impl DeviceBlockCosts {
    /// Replicate scalar costs onto every device (the homogeneous case).
    pub fn uniform(c: &BlockCosts, n_devices: usize) -> Self {
        DeviceBlockCosts {
            a2a: vec![c.a2a; n_devices],
            fec: vec![c.fec; n_devices],
            bec: vec![c.bec; n_devices],
            fnec: vec![c.fnec; n_devices],
            bnec: vec![c.bnec; n_devices],
            trans: vec![c.trans; n_devices],
            agg: vec![c.agg; n_devices],
            plan: vec![c.plan; n_devices],
        }
    }

    pub fn n_devices(&self) -> usize {
        self.a2a.len()
    }
}

fn any_pos(v: &[f64]) -> bool {
    v.iter().any(|&x| x > 0.0)
}

/// Which load-balancing ops a policy performs at all.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadBalanceOps {
    /// Pure EP (Deepspeed-MoE): no Plan/Trans/Agg.
    None,
    /// Search + place + reduce on the critical path (FasterMoE, or the
    /// Pro-Prophet planner with the scheduler ablated off).
    Blocking,
}

/// How a Trans/Agg primitive is mapped onto the two per-block overlap
/// windows — the three strategies of the paper's Fig 9.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SplitMode {
    /// Fig 9a: schedule the whole primitive onto the expert-computation
    /// window only (FEC for Trans, BEC for Agg).
    ExpertOnly,
    /// Fig 9b: schedule the whole primitive onto the non-MoE window only.
    NonExpertOnly,
    /// Fig 9c (Pro-Prophet): split into two sub-operators, filling the
    /// statically-known non-MoE window first and overflowing the rest
    /// into the expert window.
    #[default]
    Split,
}

/// Sub-operator split of a communication op across two overlap windows:
/// fill the second (static, known-ahead) window first, overflow into the
/// first (paper §V-B "exhaustively fill in the communication idle").
fn split2(total: f64, window2: f64, mode: SplitMode) -> (f64, f64) {
    match mode {
        SplitMode::ExpertOnly => (total, 0.0),
        SplitMode::NonExpertOnly => (0.0, total),
        SplitMode::Split => {
            let part2 = total.min(window2.max(0.0));
            (total - part2, part2)
        }
    }
}

/// Sequential baseline timeline (paper Fig 7 order, every op blocking).
pub fn build_blocking(blocks: &[BlockCosts], lb: LoadBalanceOps) -> Schedule {
    let mut stages = Vec::new();
    // Forward pass.
    for (i, c) in blocks.iter().enumerate() {
        if lb == LoadBalanceOps::Blocking {
            if c.plan > 0.0 {
                stages.push(Stage::comp_only(vec![OpInstance::new(
                    Op::Plan { block: i },
                    c.plan,
                )]));
            }
            if c.trans > 0.0 {
                stages.push(Stage::comm_only(vec![OpInstance::new(
                    Op::Trans { block: i, part: 0 },
                    c.trans,
                )]));
            }
        }
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::FwdDispatch },
            c.a2a,
        )]));
        stages.push(Stage::comp_only(vec![OpInstance::new(Op::Fec { block: i }, c.fec)]));
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::FwdCombine },
            c.a2a,
        )]));
        stages.push(Stage::comp_only(vec![OpInstance::new(
            Op::Fnec { block: i },
            c.fnec,
        )]));
    }
    // Backward pass (reverse block order).
    for (i, c) in blocks.iter().enumerate().rev() {
        stages.push(Stage::comp_only(vec![OpInstance::new(
            Op::Bnec { block: i },
            c.bnec,
        )]));
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::BwdDispatch },
            c.a2a,
        )]));
        stages.push(Stage::comp_only(vec![OpInstance::new(Op::Bec { block: i }, c.bec)]));
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::BwdCombine },
            c.a2a,
        )]));
        if lb == LoadBalanceOps::Blocking && c.agg > 0.0 {
            stages.push(Stage::comm_only(vec![OpInstance::new(
                Op::Agg { block: i, part: 0 },
                c.agg,
            )]));
        }
    }
    Schedule { stages }
}

/// Algorithm 2: the block-wise overlap schedule (Fig 9c splitting).
pub fn build_blockwise(blocks: &[BlockCosts]) -> Schedule {
    build_blockwise_mode(blocks, SplitMode::Split)
}

/// Algorithm 2 with an explicit Fig 9 splitting strategy (the Fig 9
/// ablation bench compares the three).
pub fn build_blockwise_mode(blocks: &[BlockCosts], mode: SplitMode) -> Schedule {
    let l = blocks.len();
    let mut stages = Vec::new();
    if l == 0 {
        return Schedule { stages };
    }

    // Block 0's Trans cannot hide under an earlier block — exposed at the
    // start of FP (but its Plan ran during the previous iteration's A2A,
    // so no Plan is charged here).
    if blocks[0].trans > 0.0 {
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::Trans { block: 0, part: 0 },
            blocks[0].trans,
        )]));
    }

    // ---- forward pass ----
    for i in 0..l {
        let c = &blocks[i];
        // Next block's Trans split across this block's two comp windows.
        let (t_fec_part, t_fnec_part) = match blocks.get(i + 1) {
            Some(nxt) => split2(nxt.trans, c.fnec, mode),
            None => (0.0, 0.0),
        };
        // Plan of the NEXT iteration for this block overlaps the dispatch
        // A2A (§V-A: earliest legal position is iteration j for iter j+1).
        let mut a2a1 = Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::FwdDispatch },
            c.a2a,
        )]);
        if c.plan > 0.0 {
            a2a1.comp.push(OpInstance::new(Op::Plan { block: i }, c.plan));
        }
        stages.push(a2a1);

        let mut fec = Stage::comp_only(vec![OpInstance::new(Op::Fec { block: i }, c.fec)]);
        if t_fec_part > 0.0 {
            fec.comm.push(OpInstance::new(Op::Trans { block: i + 1, part: 0 }, t_fec_part));
        }
        stages.push(fec);

        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::FwdCombine },
            c.a2a,
        )]));

        let mut fnec =
            Stage::comp_only(vec![OpInstance::new(Op::Fnec { block: i }, c.fnec)]);
        if t_fnec_part > 0.0 {
            fnec.comm.push(OpInstance::new(
                Op::Trans { block: i + 1, part: 1 },
                t_fnec_part,
            ));
        }
        stages.push(fnec);
    }

    // ---- backward pass (blocks in reverse; Agg of block i+1 hides under
    // the backward computations of block i) ----
    for i in (0..l).rev() {
        let c = &blocks[i];
        let (agg_bec_part, agg_bnec_part) = match blocks.get(i + 1) {
            Some(nxt) => split2(nxt.agg, c.bnec, mode),
            None => (0.0, 0.0),
        };

        let mut bnec =
            Stage::comp_only(vec![OpInstance::new(Op::Bnec { block: i }, c.bnec)]);
        if agg_bnec_part > 0.0 {
            bnec.comm.push(OpInstance::new(
                Op::Agg { block: i + 1, part: 0 },
                agg_bnec_part,
            ));
        }
        stages.push(bnec);

        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::BwdDispatch },
            c.a2a,
        )]));

        let mut bec = Stage::comp_only(vec![OpInstance::new(Op::Bec { block: i }, c.bec)]);
        if agg_bec_part > 0.0 {
            bec.comm.push(OpInstance::new(
                Op::Agg { block: i + 1, part: 1 },
                agg_bec_part,
            ));
        }
        stages.push(bec);

        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::A2a { block: i, phase: A2aPhase::BwdCombine },
            c.a2a,
        )]));
    }

    // Block 0's Agg has no later computation to hide under.
    if blocks[0].agg > 0.0 {
        stages.push(Stage::comm_only(vec![OpInstance::new(
            Op::Agg { block: 0, part: 0 },
            blocks[0].agg,
        )]));
    }

    Schedule { stages }
}

/// Element-wise [`split2`] over per-device vectors: each device splits
/// its own share of the transfer against its own static window.
fn split2_vec(total: &[f64], window2: &[f64], mode: SplitMode) -> (Vec<f64>, Vec<f64>) {
    let mut part1 = Vec::with_capacity(total.len());
    let mut part2 = Vec::with_capacity(total.len());
    for (&t, &w) in total.iter().zip(window2) {
        let (a, b) = split2(t, w, mode);
        part1.push(a);
        part2.push(b);
    }
    (part1, part2)
}

/// Algorithm 2 emitted as an explicit dependency DAG
/// ([`crate::scheduler::dag::OpDag`]) with per-device durations — the
/// relaxed, device-level form of [`build_blockwise`].
///
/// Node issue order is Algorithm 2's launch order (it doubles as the
/// per-stream FIFO order on every device); dependency edges carry only
/// the TRUE data dependencies of Fig 7:
///
/// * `A2A_dispatch(i)` and `Plan(i)` wait for `FNEC(i-1)` (block input);
/// * `FEC(i)` waits for its dispatch A2A and for this block's `Trans`
///   sub-operators (parameters must have arrived);
/// * `FNEC(i)` waits only for the combine A2A — unlike the barrier
///   model, it does NOT wait for the next block's in-flight `Trans`;
/// * backward mirrors forward, with `Agg(i)` waiting on `BEC(i)` (the
///   gradients it aggregates) rather than on a stage boundary.
///
/// With uniform per-device costs the executed DAG is never slower than
/// the barrier [`build_blockwise`] schedule (every DAG edge is implied
/// by some stage barrier); with per-device costs it additionally models
/// stragglers and per-device exposed communication.
pub fn build_blockwise_dag(blocks: &[DeviceBlockCosts], mode: SplitMode) -> OpDag {
    let l = blocks.len();
    if l == 0 {
        return OpDag::new(1);
    }
    let d = blocks[0].n_devices();
    let mut dag = OpDag::new(d);

    // Trans sub-operator node ids per block (FEC deps of that block).
    let mut trans_parts: Vec<Vec<usize>> = vec![Vec::new(); l];
    // Block 0's Trans is exposed at the start of FP (its Plan ran during
    // the previous iteration's A2A window).
    if any_pos(&blocks[0].trans) {
        let id = dag.push_slice(Op::Trans { block: 0, part: 0 }, &blocks[0].trans, &[]);
        trans_parts[0].push(id);
    }

    // ---- forward pass ----
    let mut fnec_ids: Vec<usize> = Vec::with_capacity(l);
    let mut prev_fnec: Option<usize> = None;
    for i in 0..l {
        let c = &blocks[i];
        let input_dep: Vec<usize> = prev_fnec.into_iter().collect();
        if any_pos(&c.plan) {
            dag.push_slice(Op::Plan { block: i }, &c.plan, &input_dep);
        }
        let a2a1 = dag.push_slice(
            Op::A2a { block: i, phase: A2aPhase::FwdDispatch },
            &c.a2a,
            &input_dep,
        );
        // Next block's Trans, split across this block's two comp windows
        // (issue order places part 0 in the FEC window, part 1 in FNEC's).
        let (t_fec_part, t_fnec_part) = match blocks.get(i + 1) {
            Some(nxt) => split2_vec(&nxt.trans, &c.fnec, mode),
            None => (vec![], vec![]),
        };
        if any_pos(&t_fec_part) {
            let id = dag.push_slice(Op::Trans { block: i + 1, part: 0 }, &t_fec_part, &[]);
            trans_parts[i + 1].push(id);
        }
        let mut fec_deps = vec![a2a1];
        fec_deps.extend_from_slice(&trans_parts[i]);
        let fec = dag.push_slice(Op::Fec { block: i }, &c.fec, &fec_deps);
        let a2a2 = dag.push_slice(
            Op::A2a { block: i, phase: A2aPhase::FwdCombine },
            &c.a2a,
            &[fec],
        );
        if any_pos(&t_fnec_part) {
            let id = dag.push_slice(Op::Trans { block: i + 1, part: 1 }, &t_fnec_part, &[]);
            trans_parts[i + 1].push(id);
        }
        let fnec = dag.push_slice(Op::Fnec { block: i }, &c.fnec, &[a2a2]);
        fnec_ids.push(fnec);
        prev_fnec = Some(fnec);
    }

    // ---- backward pass (blocks in reverse; Agg of block i+1 hides
    // under block i's backward computations) ----
    let mut bec_ids: Vec<usize> = vec![usize::MAX; l];
    let mut prev_bwd_combine: Option<usize> = None;
    for i in (0..l).rev() {
        let c = &blocks[i];
        let (agg_bec_part, agg_bnec_part) = match blocks.get(i + 1) {
            Some(nxt) => split2_vec(&nxt.agg, &c.bnec, mode),
            None => (vec![], vec![]),
        };
        if any_pos(&agg_bnec_part) {
            dag.push_slice(Op::Agg { block: i + 1, part: 0 }, &agg_bnec_part, &[bec_ids[i + 1]]);
        }
        let bnec_dep = match prev_bwd_combine {
            Some(id) => vec![id],
            None => vec![fnec_ids[l - 1]], // loss boundary: end of forward
        };
        let bnec = dag.push_slice(Op::Bnec { block: i }, &c.bnec, &bnec_dep);
        let a2a3 = dag.push_slice(
            Op::A2a { block: i, phase: A2aPhase::BwdDispatch },
            &c.a2a,
            &[bnec],
        );
        if any_pos(&agg_bec_part) {
            dag.push_slice(Op::Agg { block: i + 1, part: 1 }, &agg_bec_part, &[bec_ids[i + 1]]);
        }
        let bec = dag.push_slice(Op::Bec { block: i }, &c.bec, &[a2a3]);
        bec_ids[i] = bec;
        let a2a4 = dag.push_slice(
            Op::A2a { block: i, phase: A2aPhase::BwdCombine },
            &c.a2a,
            &[bec],
        );
        prev_bwd_combine = Some(a2a4);
    }

    // Block 0's Agg has no later computation to hide under.
    if any_pos(&blocks[0].agg) {
        dag.push_slice(Op::Agg { block: 0, part: 0 }, &blocks[0].agg, &[bec_ids[0]]);
    }

    dag
}

/// Sound upper bound on the relaxed-DAG makespan of one iteration —
/// `sim::events::execute(build_blockwise_dag(blocks, mode)).makespan`
/// can never exceed it — computed WITHOUT running the event executor:
/// the DAG is built (O(nodes·D), no timeline state) and every node is
/// charged its worst-device duration once ([`OpDag::serialized_bound`]).
///
/// This is the whole-iteration anchor of the slack-aware planner cost
/// model: the greedy search ranks individual candidates with the O(1)
/// [`crate::perfmodel::PerfModel::layer_time_sn_relaxed`] form, and this
/// bound ties that model back to the DES (`prop_planner_relaxed_bound_sound`
/// proves soundness on arbitrary per-device costs and a ≤ 2x gap on
/// homogeneous ones — with uniform durations every node occupies every
/// device, so `makespan >= max(comp_busy, comm_busy) >= bound / 2`).
pub fn relaxed_makespan_bound(blocks: &[DeviceBlockCosts], mode: SplitMode) -> f64 {
    build_blockwise_dag(blocks, mode).serialized_bound()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(trans: f64, agg: f64) -> BlockCosts {
        BlockCosts {
            a2a: 1.0,
            fec: 2.0,
            bec: 4.0,
            fnec: 1.5,
            bnec: 3.0,
            trans,
            agg,
            plan: 0.5,
        }
    }

    #[test]
    fn split_fills_static_window_first() {
        let m = SplitMode::Split;
        assert_eq!(split2(1.0, 1.5, m), (0.0, 1.0)); // fits entirely in FNEC
        assert_eq!(split2(2.0, 1.5, m), (0.5, 1.5)); // overflow into FEC window
        assert_eq!(split2(0.0, 1.5, m), (0.0, 0.0));
    }

    #[test]
    fn split_modes_fig9() {
        assert_eq!(split2(2.0, 1.5, SplitMode::ExpertOnly), (2.0, 0.0));
        assert_eq!(split2(2.0, 1.5, SplitMode::NonExpertOnly), (0.0, 2.0));
    }

    #[test]
    fn fig9c_never_slower_than_single_target_modes() {
        let blocks = [costs(3.0, 3.0); 4];
        let split = build_blockwise_mode(&blocks, SplitMode::Split).total_time();
        let fec = build_blockwise_mode(&blocks, SplitMode::ExpertOnly).total_time();
        let fnec = build_blockwise_mode(&blocks, SplitMode::NonExpertOnly).total_time();
        assert!(split <= fec + 1e-12, "{split} vs {fec}");
        assert!(split <= fnec + 1e-12, "{split} vs {fnec}");
    }

    #[test]
    fn blocking_deepspeed_has_no_lb_ops() {
        let sched = build_blocking(&[costs(1.0, 1.0); 3], LoadBalanceOps::None);
        assert!(sched
            .stages
            .iter()
            .flat_map(|s| s.comp.iter().chain(&s.comm))
            .all(|o| !o.op.is_load_balancing()));
        sched.validate_dependencies().unwrap();
    }

    #[test]
    fn blocking_lb_pays_everything() {
        let blocks = [costs(2.0, 2.0); 2];
        let sched = build_blocking(&blocks, LoadBalanceOps::Blocking);
        // Sequential: every op contributes its full duration.
        let expect: f64 = blocks
            .iter()
            .map(|c| 4.0 * c.a2a + c.fec + c.bec + c.fnec + c.bnec + c.trans + c.agg + c.plan)
            .sum();
        assert!((sched.total_time() - expect).abs() < 1e-12);
        sched.validate_dependencies().unwrap();
    }

    #[test]
    fn blockwise_faster_than_blocking() {
        let blocks = [costs(2.0, 2.0); 4];
        let blocking = build_blocking(&blocks, LoadBalanceOps::Blocking);
        let overlapped = build_blockwise(&blocks);
        assert!(overlapped.total_time() < blocking.total_time());
        overlapped.validate_dependencies().unwrap();
    }

    #[test]
    fn small_trans_fully_hidden() {
        // trans (1.0) < fnec (1.5): hides entirely; plan (0.5) < a2a (1.0).
        let blocks = [costs(1.0, 1.0); 3];
        let sched = build_blockwise(&blocks);
        let bd = sched.exposed_breakdown();
        // Only block 0's trans (exposed at start) and block 0's agg (end)
        // are charged.
        assert!((bd.get("place").copied().unwrap_or(0.0) - 1.0).abs() < 1e-12);
        assert!((bd.get("reduce").copied().unwrap_or(0.0) - 1.0).abs() < 1e-12);
        assert_eq!(bd.get("search"), None, "plan hides under A2A");
    }

    #[test]
    fn huge_trans_partially_exposed() {
        let mut blocks = vec![costs(0.0, 0.0); 2];
        blocks[1].trans = 100.0; // cannot hide under fec+fnec of block 0
        let sched = build_blockwise(&blocks);
        let bd = sched.exposed_breakdown();
        assert!(bd.get("place").copied().unwrap_or(0.0) > 90.0);
    }

    #[test]
    fn blockwise_never_loses_to_eq8_bound() {
        // The schedule realizes at least the Eq-8 overlap: total time must
        // not exceed the blocking schedule and must not be below the pure
        // comp+a2a lower bound.
        let blocks = [costs(3.0, 3.0); 4];
        let sched = build_blockwise(&blocks);
        let lower: f64 = blocks
            .iter()
            .map(|c| 4.0 * c.a2a + c.fec + c.bec + c.fnec + c.bnec)
            .sum();
        assert!(sched.total_time() >= lower - 1e-9);
        sched.validate_dependencies().unwrap();
    }

    #[test]
    fn empty_schedule() {
        assert_eq!(build_blockwise(&[]).total_time(), 0.0);
        assert!(build_blockwise_dag(&[], SplitMode::Split).is_empty());
        assert_eq!(relaxed_makespan_bound(&[], SplitMode::Split), 0.0);
    }

    #[test]
    fn relaxed_bound_dominates_executed_dag() {
        let blocks: Vec<DeviceBlockCosts> = (0..4)
            .map(|i| {
                let mut c = DeviceBlockCosts::uniform(&costs(3.0, 2.0), 3);
                c.fec[i % 3] *= 2.0; // some per-device skew
                c
            })
            .collect();
        for mode in [SplitMode::Split, SplitMode::ExpertOnly, SplitMode::NonExpertOnly] {
            let dag = build_blockwise_dag(&blocks, mode);
            let des = crate::sim::events::execute(&dag);
            let bound = relaxed_makespan_bound(&blocks, mode);
            assert!(
                des.makespan <= bound + 1e-9,
                "{mode:?}: DES {} exceeds bound {bound}",
                des.makespan
            );
            assert_eq!(bound, dag.serialized_bound());
        }
    }

    #[test]
    fn device_costs_uniform_replicates_scalars() {
        let c = costs(1.0, 2.0);
        let dc = DeviceBlockCosts::uniform(&c, 3);
        assert_eq!(dc.n_devices(), 3);
        assert_eq!(dc.fec, vec![2.0; 3]);
        assert_eq!(dc.trans, vec![1.0; 3]);
        assert_eq!(dc.agg, vec![2.0; 3]);
    }

    #[test]
    fn blockwise_dag_structure_matches_alg2() {
        let blocks: Vec<DeviceBlockCosts> =
            (0..3).map(|_| DeviceBlockCosts::uniform(&costs(2.0, 2.0), 4)).collect();
        let dag = build_blockwise_dag(&blocks, SplitMode::Split);
        dag.validate().unwrap();
        assert_eq!(dag.n_devices, 4);
        // Every op class present; per-block op multiset mirrors Fig 7.
        let count = |pred: &dyn Fn(&Op) -> bool| -> usize {
            dag.ops().iter().filter(|o| pred(o)).count()
        };
        assert_eq!(count(&|o| matches!(o, Op::Fec { .. })), 3);
        assert_eq!(count(&|o| matches!(o, Op::Bec { .. })), 3);
        assert_eq!(count(&|o| matches!(o, Op::A2a { .. })), 12);
        assert_eq!(count(&|o| matches!(o, Op::Plan { .. })), 3);
        assert!(count(&|o| matches!(o, Op::Trans { .. })) >= 3);
        assert!(count(&|o| matches!(o, Op::Agg { .. })) >= 3);
        // FEC depends on its dispatch A2A and on this block's Trans parts.
        for i in 0..dag.len() {
            let deps: Vec<usize> = dag.deps_of(i).collect();
            if let Op::Fec { block } = dag.op(i) {
                assert!(!deps.is_empty(), "FEC{block} has no deps");
                assert!(deps.iter().all(|&dx| dx < i));
                let has_dispatch = deps.iter().any(|&dx| {
                    matches!(
                        dag.op(dx),
                        Op::A2a { block: b, phase: A2aPhase::FwdDispatch } if b == block
                    )
                });
                assert!(has_dispatch, "FEC{block} missing dispatch dep");
            }
            if let Op::Agg { block, .. } = dag.op(i) {
                let on_bec = deps.iter().any(|&dx| {
                    matches!(dag.op(dx), Op::Bec { block: b } if b == block)
                });
                assert!(on_bec, "Agg{block} must wait for its BEC");
            }
        }
        // Trans/Agg volume is conserved vs the stage builder.
        let scalar = [costs(2.0, 2.0); 3];
        let sched = build_blockwise(&scalar);
        let sched_vol: f64 = sched
            .stages
            .iter()
            .flat_map(|s| s.comm.iter())
            .filter(|o| o.op.is_load_balancing())
            .map(|o| o.dur)
            .sum();
        let dag_vol: f64 = (0..dag.len())
            .filter(|&i| dag.op(i).is_load_balancing() && !matches!(dag.op(i), Op::Plan { .. }))
            .map(|i| dag.dur(i)[0])
            .sum();
        assert!((sched_vol - dag_vol).abs() < 1e-9, "{sched_vol} vs {dag_vol}");
    }

    #[test]
    fn single_block_trans_agg_exposed() {
        // With one block there is no previous block to hide under: both
        // trans and agg are exposed, matching the scheduling-space rule.
        let blocks = [costs(2.0, 2.0)];
        let sched = build_blockwise(&blocks);
        let bd = sched.exposed_breakdown();
        assert!((bd.get("place").copied().unwrap_or(0.0) - 2.0).abs() < 1e-12);
        assert!((bd.get("reduce").copied().unwrap_or(0.0) - 2.0).abs() < 1e-12);
    }
}
