//! Pro-Prophet scheduler (paper §V): the operator timeline of a MoE block,
//! the scheduling space, and the block-wise overlap strategy (Algorithm 2).
//!
//! A *MoE block* = one MoE layer + its adjacent non-MoE layer.  Per block
//! the forward pass runs `Plan → Trans → A2A → FEC → A2A → FNEC` and the
//! backward pass `A2A → BEC → A2A → BNEC → Agg` (paper Fig 7).  Each op is
//! either pure-communication (*comm*) or pure-computation (*comp*); ops in
//! the same [`Stage`] run on the two independent streams and overlap.
//!
//! The [`Stage`]/[`Schedule`] form is the frozen barrier model (one
//! global stream pair, a barrier after every stage).  Its device-level
//! successor lives in [`dag`]: ops carry per-device duration vectors and
//! ordering comes from explicit dependency edges, executed by
//! [`crate::sim::events`].  [`dag::from_schedule`] lowers a `Schedule`
//! into a barrier-shaped DAG (bit-for-bit equivalent under uniform
//! costs); [`build_blockwise_dag`] emits Algorithm 2 with true data
//! dependencies instead of barriers.

pub mod blockwise;
pub mod dag;

pub use blockwise::{
    build_blocking, build_blockwise, build_blockwise_dag, relaxed_makespan_bound, BlockCosts,
    DeviceBlockCosts, LoadBalanceOps, SplitMode,
};
pub use dag::OpDag;

/// The phase of one of the four A2A exchanges in a block (paper Fig 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum A2aPhase {
    /// Forward dispatch (tokens to experts).
    FwdDispatch,
    /// Forward combine (expert outputs back).
    FwdCombine,
    /// Backward dispatch (output grads to experts).
    BwdDispatch,
    /// Backward combine (input grads back).
    BwdCombine,
}

/// One operator instance on the timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Run the planner's greedy search (for the NEXT iteration — the
    /// locality pre-launch of §V-A).
    Plan { block: usize },
    /// Transfer expert parameters (part 0/1 when split into sub-operators).
    Trans { block: usize, part: u8 },
    /// Aggregate expert gradients to their home devices.
    Agg { block: usize, part: u8 },
    A2a { block: usize, phase: A2aPhase },
    /// Forward expert computation of the MoE layer.
    Fec { block: usize },
    /// Backward expert computation.
    Bec { block: usize },
    /// Forward computation of the non-MoE layer.
    Fnec { block: usize },
    /// Backward computation of the non-MoE layer.
    Bnec { block: usize },
}

/// Which stream an operator occupies (paper Fig 7 comm/comp tagging).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    Comp,
    Comm,
}

impl Op {
    /// comm/comp tagging per §V-A: Plan computes locally (all information
    /// is device-resident), Trans/Agg/A2A move bytes, the rest compute.
    pub fn stream(&self) -> Stream {
        match self {
            Op::Plan { .. } | Op::Fec { .. } | Op::Bec { .. } | Op::Fnec { .. }
            | Op::Bnec { .. } => Stream::Comp,
            Op::Trans { .. } | Op::Agg { .. } | Op::A2a { .. } => Stream::Comm,
        }
    }

    pub fn block(&self) -> usize {
        match *self {
            Op::Plan { block }
            | Op::Trans { block, .. }
            | Op::Agg { block, .. }
            | Op::A2a { block, .. }
            | Op::Fec { block }
            | Op::Bec { block }
            | Op::Fnec { block }
            | Op::Bnec { block } => block,
        }
    }

    /// Category used by the Table I breakdown.
    pub fn breakdown_key(&self) -> &'static str {
        match self {
            Op::Plan { .. } => "search",
            Op::Trans { .. } => "place",
            Op::Agg { .. } => "reduce",
            Op::A2a { .. } => "a2a",
            Op::Fec { .. } | Op::Bec { .. } => "expert_comp",
            Op::Fnec { .. } | Op::Bnec { .. } => "non_moe_comp",
        }
    }

    pub fn is_load_balancing(&self) -> bool {
        matches!(self, Op::Plan { .. } | Op::Trans { .. } | Op::Agg { .. })
    }
}

/// An op with its modeled duration (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpInstance {
    pub op: Op,
    pub dur: f64,
}

impl OpInstance {
    pub fn new(op: Op, dur: f64) -> Self {
        debug_assert!(dur >= 0.0, "negative duration for {op:?}");
        OpInstance { op, dur }
    }
}

/// Ops launched together; the comp and comm streams run in parallel, ops
/// within one stream serialize (paper Alg 2 "Launch for parallel {..}").
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub comp: Vec<OpInstance>,
    pub comm: Vec<OpInstance>,
}

impl Stage {
    pub fn comp_time(&self) -> f64 {
        self.comp.iter().map(|o| o.dur).sum()
    }

    pub fn comm_time(&self) -> f64 {
        self.comm.iter().map(|o| o.dur).sum()
    }

    /// Stage makespan: both streams must finish before the next stage (the
    /// data-dependency barrier between launch groups).
    pub fn time(&self) -> f64 {
        self.comp_time().max(self.comm_time())
    }

    pub fn comm_only(ops: Vec<OpInstance>) -> Stage {
        Stage { comp: vec![], comm: ops }
    }

    pub fn comp_only(ops: Vec<OpInstance>) -> Stage {
        Stage { comp: ops, comm: vec![] }
    }

    pub fn pair(comp: Vec<OpInstance>, comm: Vec<OpInstance>) -> Stage {
        Stage { comp, comm }
    }
}

/// A whole iteration's timeline.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub stages: Vec<Stage>,
}

impl Schedule {
    pub fn total_time(&self) -> f64 {
        self.stages.iter().map(Stage::time).sum()
    }

    /// Exposed (critical-path) seconds per breakdown category.  Within a
    /// stage the slower stream is on the critical path; its ops are charged
    /// proportionally, the faster stream's ops are fully hidden.
    pub fn exposed_breakdown(&self) -> std::collections::BTreeMap<&'static str, f64> {
        let mut out = std::collections::BTreeMap::new();
        for stage in &self.stages {
            let (ct, mt) = (stage.comp_time(), stage.comm_time());
            let (winners, total) = if ct >= mt {
                (&stage.comp, ct)
            } else {
                (&stage.comm, mt)
            };
            if total <= 0.0 {
                continue;
            }
            for op in winners {
                *out.entry(op.op.breakdown_key()).or_insert(0.0) += op.dur;
            }
        }
        out
    }

    /// Fraction of the iteration spent on exposed load-balancing ops
    /// (Search + Place + Reduce of Table I).
    pub fn lb_fraction(&self) -> f64 {
        let bd = self.exposed_breakdown();
        let lb = bd.get("search").unwrap_or(&0.0)
            + bd.get("place").unwrap_or(&0.0)
            + bd.get("reduce").unwrap_or(&0.0);
        let total = self.total_time();
        if total <= 0.0 {
            0.0
        } else {
            lb / total
        }
    }

    /// All data-dependency orderings of Fig 7 hold, per block:
    ///
    /// * `Trans` (last part) precedes the block's FEC (parameters before
    ///   compute);
    /// * forward A2A phase order: `FwdDispatch ≤ FEC ≤ FwdCombine`;
    /// * `FEC ≤ BEC` (forward before backward);
    /// * backward A2A phase order: `BwdDispatch ≤ BEC ≤ BwdCombine`;
    /// * `Agg` (first part) follows the block's BEC (gradients exist
    ///   before aggregation).
    ///
    /// Ops in the same stage launch together, so ties (`==`) are legal.
    pub fn validate_dependencies(&self) -> Result<(), String> {
        let first = |pred: &dyn Fn(&Op) -> bool| -> Option<usize> {
            self.stages.iter().enumerate().find_map(|(i, s)| {
                s.comp
                    .iter()
                    .chain(&s.comm)
                    .any(|o| pred(&o.op))
                    .then_some(i)
            })
        };
        let last = |pred: &dyn Fn(&Op) -> bool| -> Option<usize> {
            self.stages
                .iter()
                .enumerate()
                .filter(|(_, s)| s.comp.iter().chain(&s.comm).any(|o| pred(&o.op)))
                .map(|(i, _)| i)
                .next_back()
        };
        let blocks: std::collections::BTreeSet<usize> = self
            .stages
            .iter()
            .flat_map(|s| s.comp.iter().chain(&s.comm))
            .map(|o| o.op.block())
            .collect();
        // `a ≤ b` when both exist, else vacuously fine.
        let ordered = |a: Option<usize>, b: Option<usize>| match (a, b) {
            (Some(x), Some(y)) => x <= y,
            _ => true,
        };
        for &b in &blocks {
            let a2a = |phase: A2aPhase| {
                first(&move |o: &Op| {
                    matches!(o, Op::A2a { block, phase: p } if *block == b && *p == phase)
                })
            };
            let fec = first(&|o: &Op| matches!(o, Op::Fec { block } if *block == b));
            let bec = first(&|o: &Op| matches!(o, Op::Bec { block } if *block == b));
            let trans_last = last(&|o: &Op| matches!(o, Op::Trans { block, .. } if *block == b));
            let agg_first = first(&|o: &Op| matches!(o, Op::Agg { block, .. } if *block == b));
            if !ordered(trans_last, fec) {
                return Err(format!(
                    "block {b}: Trans finishes at stage {trans_last:?} after its FEC at {fec:?}"
                ));
            }
            if !ordered(a2a(A2aPhase::FwdDispatch), fec) {
                return Err(format!("block {b}: forward dispatch A2A after FEC"));
            }
            if !ordered(fec, a2a(A2aPhase::FwdCombine)) {
                return Err(format!("block {b}: forward combine A2A before FEC"));
            }
            if !ordered(fec, bec) {
                return Err(format!("block {b}: BEC at {bec:?} before FEC at {fec:?}"));
            }
            if !ordered(a2a(A2aPhase::BwdDispatch), bec) {
                return Err(format!("block {b}: backward dispatch A2A after BEC"));
            }
            if !ordered(bec, a2a(A2aPhase::BwdCombine)) {
                return Err(format!("block {b}: backward combine A2A before BEC"));
            }
            if !ordered(bec, agg_first) {
                return Err(format!("block {b}: Agg at {agg_first:?} before BEC at {bec:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(op: Op, dur: f64) -> OpInstance {
        OpInstance::new(op, dur)
    }

    #[test]
    fn stream_tagging_matches_paper() {
        assert_eq!(Op::Plan { block: 0 }.stream(), Stream::Comp);
        assert_eq!(Op::Trans { block: 0, part: 0 }.stream(), Stream::Comm);
        assert_eq!(Op::Agg { block: 0, part: 1 }.stream(), Stream::Comm);
        assert_eq!(
            Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }.stream(),
            Stream::Comm
        );
        assert_eq!(Op::Fec { block: 0 }.stream(), Stream::Comp);
    }

    #[test]
    fn stage_time_is_max_of_streams() {
        let s = Stage::pair(
            vec![inst(Op::Fec { block: 0 }, 3.0)],
            vec![inst(Op::Trans { block: 1, part: 0 }, 2.0)],
        );
        assert_eq!(s.time(), 3.0);
        assert_eq!(s.comp_time(), 3.0);
        assert_eq!(s.comm_time(), 2.0);
    }

    #[test]
    fn schedule_total_sums_stages() {
        let sched = Schedule {
            stages: vec![
                Stage::comm_only(vec![inst(
                    Op::A2a { block: 0, phase: A2aPhase::FwdDispatch },
                    1.0,
                )]),
                Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 2.0)]),
            ],
        };
        assert_eq!(sched.total_time(), 3.0);
    }

    #[test]
    fn hidden_comm_not_in_breakdown() {
        let sched = Schedule {
            stages: vec![Stage::pair(
                vec![inst(Op::Fec { block: 0 }, 5.0)],
                vec![inst(Op::Trans { block: 1, part: 0 }, 2.0)],
            )],
        };
        let bd = sched.exposed_breakdown();
        assert_eq!(bd.get("place"), None, "hidden Trans must not be charged");
        assert_eq!(bd.get("expert_comp"), Some(&5.0));
        assert_eq!(sched.lb_fraction(), 0.0);
    }

    #[test]
    fn exposed_comm_charged_when_dominant() {
        let sched = Schedule {
            stages: vec![Stage::pair(
                vec![inst(Op::Fec { block: 0 }, 1.0)],
                vec![inst(Op::Trans { block: 1, part: 0 }, 4.0)],
            )],
        };
        let bd = sched.exposed_breakdown();
        assert_eq!(bd.get("place"), Some(&4.0));
        assert!((sched.lb_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dependency_validation_catches_phase_violations() {
        let fec = || Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 1.0)]);
        let bec = || Stage::comp_only(vec![inst(Op::Bec { block: 0 }, 1.0)]);
        let a2a = |p: A2aPhase| {
            Stage::comm_only(vec![inst(Op::A2a { block: 0, phase: p }, 1.0)])
        };
        // Forward dispatch after FEC.
        let bad = Schedule { stages: vec![fec(), a2a(A2aPhase::FwdDispatch)] };
        assert!(bad.validate_dependencies().unwrap_err().contains("dispatch"));
        // Forward combine before FEC.
        let bad = Schedule { stages: vec![a2a(A2aPhase::FwdCombine), fec()] };
        assert!(bad.validate_dependencies().unwrap_err().contains("combine"));
        // Backward dispatch after BEC.
        let bad = Schedule { stages: vec![fec(), bec(), a2a(A2aPhase::BwdDispatch)] };
        assert!(bad
            .validate_dependencies()
            .unwrap_err()
            .contains("backward dispatch"));
        // Backward combine before BEC.
        let bad = Schedule { stages: vec![fec(), a2a(A2aPhase::BwdCombine), bec()] };
        assert!(bad
            .validate_dependencies()
            .unwrap_err()
            .contains("backward combine"));
        // Agg before BEC.
        let bad = Schedule {
            stages: vec![
                fec(),
                Stage::comm_only(vec![inst(Op::Agg { block: 0, part: 0 }, 1.0)]),
                bec(),
            ],
        };
        assert!(bad.validate_dependencies().unwrap_err().contains("Agg"));
        // The full Fig-7 order passes.
        let good = Schedule {
            stages: vec![
                a2a(A2aPhase::FwdDispatch),
                fec(),
                a2a(A2aPhase::FwdCombine),
                a2a(A2aPhase::BwdDispatch),
                bec(),
                a2a(A2aPhase::BwdCombine),
                Stage::comm_only(vec![inst(Op::Agg { block: 0, part: 0 }, 1.0)]),
            ],
        };
        good.validate_dependencies().unwrap();
    }

    #[test]
    fn dependency_validation_catches_late_trans() {
        let bad = Schedule {
            stages: vec![
                Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 1.0)]),
                Stage::comm_only(vec![inst(Op::Trans { block: 0, part: 0 }, 1.0)]),
            ],
        };
        assert!(bad.validate_dependencies().is_err());
        let good = Schedule {
            stages: vec![
                Stage::comm_only(vec![inst(Op::Trans { block: 0, part: 0 }, 1.0)]),
                Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 1.0)]),
            ],
        };
        assert!(good.validate_dependencies().is_ok());
    }
}
