//! Explicit operator dependency DAGs with **per-device** durations — the
//! input of the device-level discrete-event executor
//! ([`crate::sim::events`]).
//!
//! The barrier-stage [`Schedule`] collapses an iteration into one global
//! two-stream timeline: every op carries a single scalar duration (the
//! max over devices, pre-computed by the engine) and a hard barrier
//! separates consecutive stages.  That model cannot express stragglers,
//! per-device exposed communication, or heterogeneous clusters — exactly
//! the per-device phenomena the paper's §V timelines (Fig 7/8) reason
//! about.
//!
//! An [`OpDag`] keeps the operator vocabulary ([`Op`]) but
//!
//! * gives every node a **duration vector** (seconds per device), and
//! * replaces stage barriers with **explicit dependency edges**.
//!
//! Nodes are stored in issue order, which doubles as the per-stream FIFO
//! order on each device (one compute stream + one communication stream
//! per device, like the CUDA/NCCL pair the paper schedules onto).
//! Dependencies must point backwards (`dep < node index`), so a cycle is
//! unrepresentable by construction.
//!
//! # Storage (arena / SoA)
//!
//! Per-node `Vec<f64>` durations and `Vec<usize>` dep lists do not scale
//! to D ∈ {1k..10k} devices — 10k-device DAGs spend more time in the
//! allocator than in the event loop.  Storage is therefore flat:
//!
//! * **Duration arena**: one row-major `Vec<f64>`, node `i`'s per-device
//!   durations at `dur[i*D .. (i+1)*D]` ([`OpDag::dur`]).  The executor's
//!   collective-start scan reduces whole rows with `f64::max`, which the
//!   compiler autovectorizes.
//! * **CSR dependencies**: explicit edges live in one `dep_idx` array
//!   sliced by `dep_off` offsets — no per-node allocation.
//! * **Compressed barrier edges**: a barrier-shaped lowering makes every
//!   op of stage *s* depend on *every* op of stage *s-1* — O(ops²) edges
//!   if materialized.  Since a stage's ops are contiguous in issue
//!   order, each node instead stores one `(lo, hi)` node *range*;
//!   [`OpDag::deps_of`] yields the range then the explicit edges, so
//!   consumers never see the difference.
//!
//! Two builders produce DAGs:
//!
//! * [`from_schedule`] lowers a frozen [`Schedule`] into a
//!   **barrier-shaped** DAG (every op of stage *s* depends on every op of
//!   stage *s-1*, uniform durations).  Executing that DAG reproduces
//!   `Schedule::total_time()` and `Schedule::exposed_breakdown()`
//!   bit-for-bit — the equivalence gate of
//!   `rust/tests/integration_timeline.rs`.
//! * [`super::build_blockwise_dag`] emits Algorithm 2 directly as a DAG
//!   with true data dependencies (no cross-stream barriers), the relaxed
//!   form the barrier model over-constrains.

use super::{Op, OpInstance, Schedule, Stream};

/// A whole iteration as an operator dependency DAG over `n_devices`
/// device-local stream pairs, stored structure-of-arrays (see the module
/// docs).  (No `Default`: a zero-device DAG would bypass
/// [`OpDag::new`]'s `n_devices >= 1` invariant.)
#[derive(Clone, Debug, PartialEq)]
pub struct OpDag {
    pub n_devices: usize,
    ops: Vec<Op>,
    /// Row-major duration arena: node `i`, device `dev` at
    /// `i * n_devices + dev`.
    dur: Vec<f64>,
    /// CSR offsets into `dep_idx`; node `i`'s explicit deps are
    /// `dep_idx[dep_off[i] .. dep_off[i + 1]]`.
    dep_off: Vec<u32>,
    dep_idx: Vec<u32>,
    /// Compressed stage-barrier edges: node `i` additionally depends on
    /// every node in `barrier[i].0 .. barrier[i].1` (empty range = none).
    barrier: Vec<(u32, u32)>,
}

/// Iterator over one node's dependencies: the compressed barrier range
/// first, then the explicit CSR edges (each strictly less than the
/// node's own index).
#[derive(Clone, Debug)]
pub struct Deps<'a> {
    range: std::ops::Range<u32>,
    explicit: std::slice::Iter<'a, u32>,
}

impl Iterator for Deps<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        match self.range.next() {
            Some(i) => Some(i as usize),
            None => self.explicit.next().map(|&i| i as usize),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.range.len() + self.explicit.len();
        (n, Some(n))
    }
}

impl ExactSizeIterator for Deps<'_> {}

impl OpDag {
    pub fn new(n_devices: usize) -> Self {
        assert!(n_devices >= 1, "DAG needs at least one device");
        OpDag {
            n_devices,
            ops: Vec::new(),
            dur: Vec::new(),
            dep_off: vec![0],
            dep_idx: Vec::new(),
            barrier: Vec::new(),
        }
    }

    /// Core append: reserve the node's arena row, let `fill` write the
    /// per-device durations in place, record explicit deps + barrier
    /// range.  Returns the node index.
    fn push_filled(
        &mut self,
        op: Op,
        deps: &[usize],
        barrier: (u32, u32),
        fill: impl FnOnce(&mut [f64]),
    ) -> usize {
        let idx = self.ops.len();
        assert!(idx < u32::MAX as usize, "DAG node count overflows u32 indexing");
        for &d in deps {
            assert!(d < idx, "dep {d} of node {idx} is not an earlier node");
        }
        debug_assert!(barrier.0 <= barrier.1 && barrier.1 as usize <= idx);
        let d = self.n_devices;
        self.dur.resize(self.dur.len() + d, 0.0);
        let row = &mut self.dur[idx * d..(idx + 1) * d];
        fill(row);
        debug_assert!(
            row.iter().all(|d| d.is_finite() && *d >= 0.0),
            "non-finite or negative duration for {op:?}"
        );
        self.ops.push(op);
        self.dep_idx.extend(deps.iter().map(|&d| d as u32));
        self.dep_off.push(self.dep_idx.len() as u32);
        self.barrier.push(barrier);
        idx
    }

    /// Append a node with per-device durations; returns its index.
    pub fn push(&mut self, op: Op, dur: Vec<f64>, deps: Vec<usize>) -> usize {
        self.push_slice(op, &dur, &deps)
    }

    /// [`push`](Self::push) without consuming the inputs — the
    /// allocation-free form hot builders
    /// ([`super::build_blockwise_dag`]) use: durations are copied
    /// straight into the arena, dep indices into the CSR array.
    pub fn push_slice(&mut self, op: Op, dur: &[f64], deps: &[usize]) -> usize {
        assert_eq!(dur.len(), self.n_devices, "duration vector length for {op:?}");
        self.push_filled(op, deps, (0, 0), |row| row.copy_from_slice(dur))
    }

    /// Append a node whose duration is the same on every device.
    pub fn push_uniform(&mut self, op: Op, dur: f64, deps: Vec<usize>) -> usize {
        self.push_filled(op, &deps, (0, 0), |row| row.fill(dur))
    }

    /// The op of node `i`.
    #[inline]
    pub fn op(&self, i: usize) -> Op {
        self.ops[i]
    }

    /// All ops in issue order.
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Node `i`'s per-device durations (one arena row).
    #[inline]
    pub fn dur(&self, i: usize) -> &[f64] {
        &self.dur[i * self.n_devices..(i + 1) * self.n_devices]
    }

    /// Node `i`'s dependencies: barrier range first, then explicit edges.
    #[inline]
    pub fn deps_of(&self, i: usize) -> Deps<'_> {
        let (lo, hi) = self.barrier[i];
        Deps {
            range: lo..hi,
            explicit: self.dep_idx[self.dep_off[i] as usize..self.dep_off[i + 1] as usize]
                .iter(),
        }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Highest block id referenced by any node (None when empty).
    pub fn max_block(&self) -> Option<usize> {
        self.ops.iter().map(|op| op.block()).max()
    }

    /// Structural invariants: dependency edges (explicit and barrier
    /// ranges) point backwards (which also proves acyclicity — issue
    /// order is a topological order), the duration arena spans every
    /// (node, device) pair, and all durations are finite and
    /// non-negative.
    pub fn validate(&self) -> Result<(), String> {
        if self.dur.len() != self.ops.len() * self.n_devices {
            return Err(format!(
                "duration arena holds {} entries for {} nodes x {} devices",
                self.dur.len(),
                self.ops.len(),
                self.n_devices
            ));
        }
        for (i, op) in self.ops.iter().enumerate() {
            for (dev, &d) in self.dur(i).iter().enumerate() {
                if !d.is_finite() || d < 0.0 {
                    return Err(format!("node {i} ({op:?}): bad duration {d} on device {dev}"));
                }
            }
            let (lo, hi) = self.barrier[i];
            if lo > hi || hi as usize > i {
                return Err(format!(
                    "node {i} ({op:?}): barrier range {lo}..{hi} not earlier (cycle or forward edge)"
                ));
            }
            for dep in self.deps_of(i) {
                if dep >= i {
                    return Err(format!(
                        "node {i} ({op:?}): dep {dep} not earlier (cycle or forward edge)"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Sum of every node's worst-device duration — a sound upper bound on
    /// the executed makespan: the DES critical path
    /// ([`crate::sim::events::execute`]) walks predecessors with strictly
    /// decreasing node indices, so it visits each node at most once and
    /// charges it at most its worst device.  The planner's relaxed cost
    /// model ([`crate::scheduler::relaxed_makespan_bound`]) is built on
    /// this; `prop_planner_relaxed_bound_sound` pins both directions
    /// (sound on any costs, within 2x on homogeneous ones).
    pub fn serialized_bound(&self) -> f64 {
        self.dur
            .chunks_exact(self.n_devices)
            .map(|row| row.iter().copied().fold(0.0f64, f64::max))
            .sum()
    }

    /// Total busy seconds per device and stream: `(comp, comm)` vectors.
    pub fn busy_per_device(&self) -> (Vec<f64>, Vec<f64>) {
        let mut comp = vec![0.0; self.n_devices];
        let mut comm = vec![0.0; self.n_devices];
        self.busy_per_device_into(&mut comp, &mut comm);
        (comp, comm)
    }

    /// [`busy_per_device`](Self::busy_per_device) into caller-owned
    /// buffers (resized and zeroed here) — the allocation-free form for
    /// per-iteration callers.
    pub fn busy_per_device_into(&self, comp: &mut Vec<f64>, comm: &mut Vec<f64>) {
        comp.clear();
        comp.resize(self.n_devices, 0.0);
        comm.clear();
        comm.resize(self.n_devices, 0.0);
        for (i, op) in self.ops.iter().enumerate() {
            let acc = match op.stream() {
                Stream::Comp => &mut *comp,
                Stream::Comm => &mut *comm,
            };
            for (a, &d) in acc.iter_mut().zip(self.dur(i)) {
                *a += d;
            }
        }
    }
}

/// Lower a barrier-stage [`Schedule`] into a barrier-shaped [`OpDag`]
/// with **uniform** per-device durations: every op of stage *s* depends
/// on every op of stage *s-1* (stored as one compressed node range per
/// op), and each op takes its scalar duration on all devices.  Executing
/// the result on the DES reproduces the Stage model's `total_time()` /
/// `exposed_breakdown()` bit-for-bit (the oracle-equivalence property;
/// see `rust/tests/integration_timeline.rs`).
pub fn from_schedule(schedule: &Schedule, n_devices: usize) -> OpDag {
    from_schedule_with(schedule, n_devices, |op, row| row.fill(op.dur))
}

/// Like [`from_schedule`], but per-device durations are written by
/// `dur_of` directly into the node's arena row (e.g. the engine's
/// `*_per_device` costs, or slowdown-scaled vectors for straggler
/// scenarios) — no per-op `Vec` round trip.  The barrier shape is
/// preserved; only the durations refine.
pub fn from_schedule_with(
    schedule: &Schedule,
    n_devices: usize,
    mut dur_of: impl FnMut(&OpInstance, &mut [f64]),
) -> OpDag {
    let mut dag = OpDag::new(n_devices);
    // The previous non-empty stage, as a contiguous node range (its ops
    // were pushed back to back — the compressed barrier representation).
    let mut prev: (u32, u32) = (0, 0);
    for stage in &schedule.stages {
        let lo = dag.len() as u32;
        for op in stage.comp.iter().chain(&stage.comm) {
            dag.push_filled(op.op, &[], prev, |row| dur_of(op, row));
        }
        let hi = dag.len() as u32;
        if hi > lo {
            prev = (lo, hi);
        }
    }
    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{A2aPhase, Stage};

    fn inst(op: Op, dur: f64) -> OpInstance {
        OpInstance::new(op, dur)
    }

    fn deps(dag: &OpDag, i: usize) -> Vec<usize> {
        dag.deps_of(i).collect()
    }

    #[test]
    fn push_orders_and_validates() {
        let mut dag = OpDag::new(2);
        let a = dag.push_uniform(Op::Fec { block: 0 }, 1.0, vec![]);
        let b = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.5, 0.7], vec![a]);
        assert_eq!((a, b), (0, 1));
        assert_eq!(dag.len(), 2);
        assert_eq!(dag.max_block(), Some(0));
        dag.validate().unwrap();
        assert_eq!(dag.dur(0), &[1.0, 1.0]);
        assert_eq!(dag.dur(1), &[0.5, 0.7]);
        assert_eq!(deps(&dag, 0), Vec::<usize>::new());
        assert_eq!(deps(&dag, 1), vec![0]);
        let (comp, comm) = dag.busy_per_device();
        assert_eq!(comp, vec![1.0, 1.0]);
        assert_eq!(comm, vec![0.5, 0.7]);
        // The _into form reuses caller buffers bit-identically.
        let (mut c2, mut m2) = (vec![9.0; 7], Vec::new());
        dag.busy_per_device_into(&mut c2, &mut m2);
        assert_eq!((c2, m2), (comp, comm));
    }

    #[test]
    fn push_slice_matches_push() {
        let mut a = OpDag::new(3);
        let mut b = OpDag::new(3);
        a.push(Op::Fec { block: 0 }, vec![1.0, 2.0, 3.0], vec![]);
        a.push(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, vec![0.5; 3], vec![0]);
        b.push_slice(Op::Fec { block: 0 }, &[1.0, 2.0, 3.0], &[]);
        b.push_slice(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, &[0.5; 3], &[0]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn forward_dep_rejected() {
        let mut dag = OpDag::new(1);
        dag.push_uniform(Op::Fec { block: 0 }, 1.0, vec![3]);
    }

    #[test]
    #[should_panic]
    fn wrong_duration_arity_rejected() {
        let mut dag = OpDag::new(4);
        dag.push(Op::Fec { block: 0 }, vec![1.0, 2.0], vec![]);
    }

    #[test]
    fn schedule_lowering_is_barrier_shaped() {
        let sched = Schedule {
            stages: vec![
                Stage::pair(
                    vec![inst(Op::Fec { block: 0 }, 2.0)],
                    vec![inst(Op::Trans { block: 1, part: 0 }, 1.0)],
                ),
                Stage::comm_only(vec![inst(
                    Op::A2a { block: 0, phase: A2aPhase::FwdCombine },
                    0.5,
                )]),
            ],
        };
        let dag = from_schedule(&sched, 3);
        dag.validate().unwrap();
        assert_eq!(dag.len(), 3);
        // Stage 0 ops have no deps; the stage-1 op depends on BOTH —
        // delivered through the compressed barrier range, not O(ops²)
        // explicit edges.
        assert!(deps(&dag, 0).is_empty());
        assert!(deps(&dag, 1).is_empty());
        assert_eq!(deps(&dag, 2), vec![0, 1]);
        // Uniform lowering replicates the scalar duration.
        assert_eq!(dag.dur(0), &[2.0; 3]);
    }

    #[test]
    fn empty_stages_do_not_break_barrier_chain() {
        let sched = Schedule {
            stages: vec![
                Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 1.0)]),
                Stage { comp: vec![], comm: vec![] },
                Stage::comp_only(vec![inst(Op::Fnec { block: 0 }, 1.0)]),
            ],
        };
        let dag = from_schedule(&sched, 2);
        dag.validate().unwrap();
        // The empty stage is skipped: node 1 still depends on node 0.
        assert_eq!(deps(&dag, 1), vec![0]);
    }

    #[test]
    fn custom_durations_flow_through() {
        let sched = Schedule {
            stages: vec![Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 2.0)])],
        };
        let dag = from_schedule_with(&sched, 2, |op, row| {
            row[0] = op.dur;
            row[1] = 2.0 * op.dur;
        });
        assert_eq!(dag.dur(0), &[2.0, 4.0]);
    }
}
