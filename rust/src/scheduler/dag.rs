//! Explicit operator dependency DAGs with **per-device** durations — the
//! input of the device-level discrete-event executor
//! ([`crate::sim::events`]).
//!
//! The barrier-stage [`Schedule`] collapses an iteration into one global
//! two-stream timeline: every op carries a single scalar duration (the
//! max over devices, pre-computed by the engine) and a hard barrier
//! separates consecutive stages.  That model cannot express stragglers,
//! per-device exposed communication, or heterogeneous clusters — exactly
//! the per-device phenomena the paper's §V timelines (Fig 7/8) reason
//! about.
//!
//! An [`OpDag`] keeps the operator vocabulary ([`Op`]) but
//!
//! * gives every node a **duration vector** (seconds per device), and
//! * replaces stage barriers with **explicit dependency edges**.
//!
//! Nodes are stored in issue order, which doubles as the per-stream FIFO
//! order on each device (one compute stream + one communication stream
//! per device, like the CUDA/NCCL pair the paper schedules onto).
//! Dependencies must point backwards (`dep < node index`), so a cycle is
//! unrepresentable by construction.
//!
//! Two builders produce DAGs:
//!
//! * [`from_schedule`] lowers a frozen [`Schedule`] into a
//!   **barrier-shaped** DAG (every op of stage *s* depends on every op of
//!   stage *s-1*, uniform durations).  Executing that DAG reproduces
//!   `Schedule::total_time()` and `Schedule::exposed_breakdown()`
//!   bit-for-bit — the equivalence gate of
//!   `rust/tests/integration_timeline.rs`.
//! * [`super::build_blockwise_dag`] emits Algorithm 2 directly as a DAG
//!   with true data dependencies (no cross-stream barriers), the relaxed
//!   form the barrier model over-constrains.

use super::{Op, OpInstance, Schedule, Stream};

/// One operator node: the op, its per-device durations, and the nodes
/// that must finish before it may start.
#[derive(Clone, Debug, PartialEq)]
pub struct DagNode {
    pub op: Op,
    /// Seconds the op occupies its stream on each device
    /// (length == [`OpDag::n_devices`]).
    pub dur: Vec<f64>,
    /// Prerequisite node indices, each strictly less than this node's own
    /// index (issue order is a topological order).
    pub deps: Vec<usize>,
}

/// A whole iteration as an operator dependency DAG over `n_devices`
/// device-local stream pairs.  (No `Default`: a zero-device DAG would
/// bypass [`OpDag::new`]'s `n_devices >= 1` invariant.)
#[derive(Clone, Debug, PartialEq)]
pub struct OpDag {
    pub n_devices: usize,
    nodes: Vec<DagNode>,
}

impl OpDag {
    pub fn new(n_devices: usize) -> Self {
        assert!(n_devices >= 1, "DAG needs at least one device");
        OpDag { n_devices, nodes: Vec::new() }
    }

    /// Append a node with per-device durations; returns its index.
    pub fn push(&mut self, op: Op, dur: Vec<f64>, deps: Vec<usize>) -> usize {
        assert_eq!(dur.len(), self.n_devices, "duration vector length for {op:?}");
        debug_assert!(
            dur.iter().all(|d| d.is_finite() && *d >= 0.0),
            "non-finite or negative duration for {op:?}"
        );
        let idx = self.nodes.len();
        for &d in &deps {
            assert!(d < idx, "dep {d} of node {idx} is not an earlier node");
        }
        self.nodes.push(DagNode { op, dur, deps });
        idx
    }

    /// Append a node whose duration is the same on every device.
    pub fn push_uniform(&mut self, op: Op, dur: f64, deps: Vec<usize>) -> usize {
        let d = self.n_devices;
        self.push(op, vec![dur; d], deps)
    }

    pub fn nodes(&self) -> &[DagNode] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Highest block id referenced by any node (None when empty).
    pub fn max_block(&self) -> Option<usize> {
        self.nodes.iter().map(|n| n.op.block()).max()
    }

    /// Structural invariants: dependency edges point backwards (which
    /// also proves acyclicity — issue order is a topological order),
    /// duration vectors span every device, and all durations are finite
    /// and non-negative.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.dur.len() != self.n_devices {
                return Err(format!(
                    "node {i} ({:?}): {} durations for {} devices",
                    n.op,
                    n.dur.len(),
                    self.n_devices
                ));
            }
            for (dev, &d) in n.dur.iter().enumerate() {
                if !d.is_finite() || d < 0.0 {
                    return Err(format!("node {i} ({:?}): bad duration {d} on device {dev}", n.op));
                }
            }
            for &dep in &n.deps {
                if dep >= i {
                    return Err(format!(
                        "node {i} ({:?}): dep {dep} not earlier (cycle or forward edge)",
                        n.op
                    ));
                }
            }
        }
        Ok(())
    }

    /// Sum of every node's worst-device duration — a sound upper bound on
    /// the executed makespan: the DES critical path
    /// ([`crate::sim::events::execute`]) walks predecessors with strictly
    /// decreasing node indices, so it visits each node at most once and
    /// charges it at most its worst device.  The planner's relaxed cost
    /// model ([`crate::scheduler::relaxed_makespan_bound`]) is built on
    /// this; `prop_planner_relaxed_bound_sound` pins both directions
    /// (sound on any costs, within 2x on homogeneous ones).
    pub fn serialized_bound(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.dur.iter().copied().fold(0.0f64, f64::max))
            .sum()
    }

    /// Total busy seconds per device and stream: `(comp, comm)` vectors.
    pub fn busy_per_device(&self) -> (Vec<f64>, Vec<f64>) {
        let mut comp = vec![0.0; self.n_devices];
        let mut comm = vec![0.0; self.n_devices];
        for n in &self.nodes {
            let acc = match n.op.stream() {
                Stream::Comp => &mut comp,
                Stream::Comm => &mut comm,
            };
            for (a, &d) in acc.iter_mut().zip(&n.dur) {
                *a += d;
            }
        }
        (comp, comm)
    }
}

/// Lower a barrier-stage [`Schedule`] into a barrier-shaped [`OpDag`]
/// with **uniform** per-device durations: every op of stage *s* depends
/// on every op of stage *s-1*, and each op takes its scalar duration on
/// all devices.  Executing the result on the DES reproduces the Stage
/// model's `total_time()` / `exposed_breakdown()` bit-for-bit (the
/// oracle-equivalence property; see `rust/tests/integration_timeline.rs`).
pub fn from_schedule(schedule: &Schedule, n_devices: usize) -> OpDag {
    from_schedule_with(schedule, n_devices, |op| vec![op.dur; n_devices])
}

/// Like [`from_schedule`], but per-device durations come from `dur_of`
/// (e.g. the engine's `*_per_device` costs, or slowdown-scaled vectors
/// for straggler scenarios).  The barrier shape is preserved; only the
/// durations refine.
pub fn from_schedule_with(
    schedule: &Schedule,
    n_devices: usize,
    mut dur_of: impl FnMut(&OpInstance) -> Vec<f64>,
) -> OpDag {
    let mut dag = OpDag::new(n_devices);
    let mut prev_stage: Vec<usize> = Vec::new();
    for stage in &schedule.stages {
        let mut this_stage = Vec::with_capacity(stage.comp.len() + stage.comm.len());
        for op in stage.comp.iter().chain(&stage.comm) {
            this_stage.push(dag.push(op.op, dur_of(op), prev_stage.clone()));
        }
        if !this_stage.is_empty() {
            prev_stage = this_stage;
        }
    }
    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{A2aPhase, Stage};

    fn inst(op: Op, dur: f64) -> OpInstance {
        OpInstance::new(op, dur)
    }

    #[test]
    fn push_orders_and_validates() {
        let mut dag = OpDag::new(2);
        let a = dag.push_uniform(Op::Fec { block: 0 }, 1.0, vec![]);
        let b = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.5, 0.7], vec![a]);
        assert_eq!((a, b), (0, 1));
        assert_eq!(dag.len(), 2);
        assert_eq!(dag.max_block(), Some(0));
        dag.validate().unwrap();
        let (comp, comm) = dag.busy_per_device();
        assert_eq!(comp, vec![1.0, 1.0]);
        assert_eq!(comm, vec![0.5, 0.7]);
    }

    #[test]
    #[should_panic]
    fn forward_dep_rejected() {
        let mut dag = OpDag::new(1);
        dag.push_uniform(Op::Fec { block: 0 }, 1.0, vec![3]);
    }

    #[test]
    #[should_panic]
    fn wrong_duration_arity_rejected() {
        let mut dag = OpDag::new(4);
        dag.push(Op::Fec { block: 0 }, vec![1.0, 2.0], vec![]);
    }

    #[test]
    fn schedule_lowering_is_barrier_shaped() {
        let sched = Schedule {
            stages: vec![
                Stage::pair(
                    vec![inst(Op::Fec { block: 0 }, 2.0)],
                    vec![inst(Op::Trans { block: 1, part: 0 }, 1.0)],
                ),
                Stage::comm_only(vec![inst(
                    Op::A2a { block: 0, phase: A2aPhase::FwdCombine },
                    0.5,
                )]),
            ],
        };
        let dag = from_schedule(&sched, 3);
        dag.validate().unwrap();
        assert_eq!(dag.len(), 3);
        // Stage 0 ops have no deps; the stage-1 op depends on BOTH.
        assert!(dag.nodes()[0].deps.is_empty());
        assert!(dag.nodes()[1].deps.is_empty());
        assert_eq!(dag.nodes()[2].deps, vec![0, 1]);
        // Uniform lowering replicates the scalar duration.
        assert_eq!(dag.nodes()[0].dur, vec![2.0; 3]);
    }

    #[test]
    fn custom_durations_flow_through() {
        let sched = Schedule {
            stages: vec![Stage::comp_only(vec![inst(Op::Fec { block: 0 }, 2.0)])],
        };
        let dag = from_schedule_with(&sched, 2, |op| vec![op.dur, 2.0 * op.dur]);
        assert_eq!(dag.nodes()[0].dur, vec![2.0, 4.0]);
    }
}
