//! End-to-end trainer: drives the AOT'd `train_step` artifact from rust.
//!
//! One step = build a token batch from the synthetic corpus, execute the
//! fused fwd+bwd+Adam HLO, carry the (params, m, v) literals to the next
//! step, and harvest the loss plus the per-layer expert-load histograms —
//! the real "input distributions" that feed a
//! [`crate::balancer::BalancerSession`] (and through its shared
//! [`Prophet`] the Pro-Prophet planner and the cluster simulator; see
//! examples/train_moe.rs).  The session owns the observe→score→drift
//! loop, so the trainer and the simulator run the exact same feedback
//! path instead of two hand-rolled copies.

use crate::balancer::{registry, BalancerSession, ProphetOptions};
use crate::config::TrainingConfig;
use crate::moe::LoadMatrix;
use crate::obs::{self, Labels, Recorder, SinkStats, Span, TelemetryHub};
use crate::prophet::Prophet;
use crate::runtime::{self, Artifact, Manifest, Runtime};
use crate::util::json::{self, Json};
use crate::workload::corpus::Corpus;
use crate::workload::Trace;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Result of one training step.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub step: usize,
    pub loss: f32,
    /// Per-layer expert load histograms (n_layers x n_experts).
    pub loads: Vec<Vec<u64>>,
    pub seconds: f64,
    /// Mean normalized-L1 error of the prophet forecasts this step's
    /// loads were compared against (None on the first step).
    pub forecast_error: Option<f64>,
    /// Layers whose drift detector fired this step.
    pub drift_layers: usize,
}

/// Whole-run record.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub preset: String,
    pub losses: Vec<f32>,
    pub step_seconds: Vec<f64>,
    /// loads[step][layer][expert].
    pub loads: Vec<Vec<Vec<u64>>>,
    /// Per-step mean forecast error (parallel to `losses` from step 2 on).
    pub forecast_errors: Vec<f64>,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    pub fn initial_loss(&self) -> f32 {
        self.losses.first().copied().unwrap_or(f32::NAN)
    }

    /// Mean over a trailing window (loss curves are noisy per-batch).
    pub fn mean_loss_tail(&self, window: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let w = window.min(n);
        self.losses[n - w..].iter().sum::<f32>() / w as f32
    }

    pub fn mean_step_seconds(&self) -> f64 {
        if self.step_seconds.is_empty() {
            return 0.0;
        }
        self.step_seconds.iter().sum::<f64>() / self.step_seconds.len() as f64
    }

    /// Mean prophet forecast error over the run (NaN before any forecast).
    pub fn mean_forecast_error(&self) -> f64 {
        if self.forecast_errors.is_empty() {
            return f64::NAN;
        }
        self.forecast_errors.iter().sum::<f64>() / self.forecast_errors.len() as f64
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("preset", json::s(&self.preset)),
            ("steps", json::num(self.losses.len() as f64)),
            (
                "losses",
                json::num_arr(&self.losses.iter().map(|&x| x as f64).collect::<Vec<_>>()),
            ),
            ("step_seconds", json::num_arr(&self.step_seconds)),
            ("mean_step_seconds", json::num(self.mean_step_seconds())),
            ("forecast_errors", json::num_arr(&self.forecast_errors)),
        ])
    }

    /// Convert observed per-layer loads into a simulator trace, spreading
    /// each layer's histogram over `n_devices` virtual DP shards (shards
    /// see near-identical data — exactly the DP assumption of EP).
    pub fn to_trace(&self, n_devices: usize) -> Trace {
        let n_layers = self.loads.first().map_or(0, Vec::len);
        let n_experts = self
            .loads
            .first()
            .and_then(|l| l.first())
            .map_or(0, Vec::len);
        let mut trace = Trace::new(n_layers, n_devices, n_experts);
        for step_loads in &self.loads {
            let layers: Vec<LoadMatrix> = step_loads
                .iter()
                .map(|hist| spread_histogram(hist, n_devices))
                .collect();
            trace.push(layers);
        }
        trace
    }
}

/// Spread an aggregate expert histogram over n devices (even split with
/// the remainder round-robined, preserving the total).
pub fn spread_histogram(hist: &[u64], n_devices: usize) -> LoadMatrix {
    let mut w = LoadMatrix::zeros(n_devices, hist.len());
    for (e, &count) in hist.iter().enumerate() {
        for d in 0..n_devices {
            w.set(d, e, crate::moe::even_split(count, n_devices, d));
        }
    }
    w
}

/// The trainer itself.
pub struct Trainer {
    pub manifest: Manifest,
    pub cfg: TrainingConfig,
    train_step: Artifact,
    /// Flat (params, m, v) literals carried across steps.
    state: Vec<xla::Literal>,
    corpus: Corpus,
    step: usize,
    /// Balancing session fed by every step's observed gate loads (spread
    /// over the manifest's expert-parallel virtual devices); owns the
    /// shared forecasting subsystem.
    session: BalancerSession,
    /// Telemetry sink when [`TrainingConfig::metrics_path`] is set; None
    /// keeps the zero-cost no-op recorder on every hot path.
    hub: Option<Arc<TelemetryHub>>,
    /// The recorder handed to the session (the hub above, or the no-op).
    rec: Arc<dyn Recorder>,
}

impl Trainer {
    pub fn new(cfg: TrainingConfig) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let dir = if cfg.artifacts_dir == "artifacts" {
            runtime::artifacts_dir()
        } else {
            std::path::PathBuf::from(&cfg.artifacts_dir)
        };
        let manifest = Manifest::load(&dir, &cfg.preset)?;
        let init = rt.load_tagged(&manifest, "init")?;
        let state = init.run(&[runtime::i32_scalar(cfg.seed as i32)])?;
        if state.len() != 3 * manifest.num_tensors {
            return Err(anyhow!(
                "init returned {} tensors, expected {}",
                state.len(),
                3 * manifest.num_tensors
            ));
        }
        let train_step = rt.load_tagged(&manifest, "train_step")?;
        let corpus = Corpus::new(manifest.vocab, 4, cfg.seed);
        let policy = registry::build("pro-prophet", &ProphetOptions::default())
            .expect("pro-prophet is always registered");
        let hub = cfg.metrics_path.as_ref().map(|_| {
            let h = Arc::new(TelemetryHub::with_max_events(cfg.metrics_max_events));
            h.set_meta("tool", json::s("train"));
            h.set_meta("preset", json::s(&cfg.preset));
            h.set_meta("seed", json::num(cfg.seed as f64));
            h
        });
        let rec: Arc<dyn Recorder> = match &hub {
            Some(h) => h.clone(),
            None => obs::noop_arc(),
        };
        let mut session =
            BalancerSession::with_recorder(policy, manifest.n_layers.max(1), rec.clone());
        // Warm-start the forecasting subsystem from a previously saved
        // prophet history (`store_path` of an earlier run): replay each
        // recorded iteration through the session's observe loop so
        // history, drift state and forecast scoring resume where the
        // last run stopped, instead of cold-starting the prophet.
        if let Some(path) = &cfg.resume_store {
            let recorded = Trace::load(std::path::Path::new(path))
                .map_err(|e| anyhow!("resume store: {e}"))?;
            if recorded.n_layers != manifest.n_layers.max(1)
                || recorded.n_experts != manifest.n_experts
            {
                return Err(anyhow!(
                    "resume store {path:?} records {} layers x {} experts, but preset {:?} trains {} layers x {} experts",
                    recorded.n_layers,
                    recorded.n_experts,
                    cfg.preset,
                    manifest.n_layers.max(1),
                    manifest.n_experts
                ));
            }
            for layers in &recorded.iterations {
                session.observe_iteration(layers);
            }
        }
        Ok(Trainer { manifest, cfg, train_step, state, corpus, step: 0, session, hub, rec })
    }

    /// Flush recorded metrics to [`TrainingConfig::metrics_path`].
    /// `Ok(None)` when telemetry is off.
    pub fn write_metrics(&self) -> Result<Option<(std::path::PathBuf, SinkStats)>> {
        match (&self.hub, &self.cfg.metrics_path) {
            (Some(hub), Some(path)) => {
                let p = std::path::PathBuf::from(path);
                let stats = hub.write_jsonl(&p)?;
                Ok(Some((p, stats)))
            }
            _ => Ok(None),
        }
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// The forecasting subsystem (history, per-layer forecasts, drift).
    pub fn prophet(&self) -> &Prophet {
        self.session
            .prophet()
            .expect("the trainer's pro-prophet policy always forecasts")
    }

    /// The balancing session driving the feedback loop.
    pub fn session(&self) -> &BalancerSession {
        &self.session
    }

    /// Execute one fused train step.
    pub fn step(&mut self) -> Result<StepResult> {
        let rec = self.rec.clone();
        rec.iteration_start(self.step);
        let sp = Span::enter(&*rec, "train.step", Labels::None);
        let result = self.step_inner();
        drop(sp);
        if rec.enabled() {
            if let Ok(r) = &result {
                rec.gauge("train.loss", Labels::None, r.loss as f64);
                rec.gauge("train.step_s", Labels::None, r.seconds);
            }
        }
        rec.iteration_end();
        result
    }

    fn step_inner(&mut self) -> Result<StepResult> {
        let man = &self.manifest;
        let start = std::time::Instant::now();
        self.step += 1;

        let tokens = self.corpus.batch(man.batch, man.seq_len);
        let tokens_lit = runtime::i32_literal(&tokens, &[man.batch, man.seq_len])?;
        let step_lit = runtime::f32_scalar(self.step as f32);

        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&step_lit);
        inputs.push(&tokens_lit);

        let mut outputs = self.train_step.run(&inputs)?;
        let n = man.num_tensors;
        if outputs.len() != 3 * n + 2 {
            return Err(anyhow!(
                "train_step returned {} outputs, expected {}",
                outputs.len(),
                3 * n + 2
            ));
        }
        let loads_lit = outputs.pop().unwrap();
        let loss_lit = outputs.pop().unwrap();
        self.state = outputs;

        let loss = runtime::scalar_f32(&loss_lit)?;
        let flat = runtime::to_f32_vec(&loads_lit)?;
        if flat.len() != man.n_layers * man.n_experts {
            return Err(anyhow!("bad loads shape: {}", flat.len()));
        }
        let loads: Vec<Vec<u64>> = (0..man.n_layers)
            .map(|l| {
                flat[l * man.n_experts..(l + 1) * man.n_experts]
                    .iter()
                    .map(|&x| x.round().max(0.0) as u64)
                    .collect()
            })
            .collect();

        // Feed the observed distributions through the balancing session:
        // each layer's histogram is spread over the EP virtual devices
        // (one expert per device, the paper's layout), then the session
        // scores outstanding forecasts, advances history and runs drift
        // detection — the same observe loop the simulator uses.
        // Spreading is independent per layer and fans out over scoped
        // threads (serial below the tiny-work threshold); observation
        // (which orders the history) stays sequential.
        let n_devices = man.n_experts.max(1);
        let work = n_devices * man.n_experts.max(1);
        let spread: Vec<LoadMatrix> = crate::util::threads::par_map(loads.len(), work, |l| {
            spread_histogram(&loads[l], n_devices)
        });
        let fb = if spread.is_empty() {
            crate::balancer::IterationFeedback::default()
        } else {
            self.session.observe_iteration(&spread)
        };

        Ok(StepResult {
            step: self.step,
            loss,
            loads,
            seconds: start.elapsed().as_secs_f64(),
            forecast_error: fb.mean_forecast_error(),
            drift_layers: fb.drift_layers,
        })
    }

    /// Run `steps` steps, invoking `on_step` after each (for logging).
    pub fn run<F: FnMut(&StepResult)>(
        &mut self,
        steps: usize,
        mut on_step: F,
    ) -> Result<TrainReport> {
        let mut report = TrainReport {
            preset: self.cfg.preset.clone(),
            ..Default::default()
        };
        for _ in 0..steps {
            let r = self.step()?;
            on_step(&r);
            report.losses.push(r.loss);
            report.step_seconds.push(r.seconds);
            if let Some(e) = r.forecast_error {
                report.forecast_errors.push(e);
            }
            report.loads.push(r.loads);
        }
        Ok(report)
    }

    /// Evaluate (forward-only) on a fresh batch, without touching state.
    pub fn eval(&mut self) -> Result<f32> {
        let rt = Runtime::cpu()?;
        let eval = rt.load_tagged(&self.manifest, "eval_step")?;
        let man = &self.manifest;
        let tokens = self.corpus.batch(man.batch, man.seq_len);
        let tokens_lit = runtime::i32_literal(&tokens, &[man.batch, man.seq_len])?;
        let mut inputs: Vec<&xla::Literal> =
            self.state[..man.num_tensors].iter().collect();
        inputs.push(&tokens_lit);
        let out = eval.run(&inputs)?;
        runtime::scalar_f32(&out[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_preserves_totals() {
        let w = spread_histogram(&[10, 3, 0, 7], 4);
        assert_eq!(w.distribution(), vec![10, 3, 0, 7]);
        assert_eq!(w.total_tokens(), 20);
        // Even-ish split.
        assert_eq!(w.get(0, 0), 3);
        assert_eq!(w.get(3, 0), 2);
    }

    #[test]
    fn report_stats() {
        let r = TrainReport {
            preset: "t".into(),
            losses: vec![4.0, 3.0, 2.0, 1.0],
            step_seconds: vec![0.1, 0.2, 0.3, 0.4],
            loads: vec![vec![vec![4, 0]]; 4],
            ..Default::default()
        };
        assert_eq!(r.initial_loss(), 4.0);
        assert_eq!(r.final_loss(), 1.0);
        assert!((r.mean_loss_tail(2) - 1.5).abs() < 1e-6);
        assert!((r.mean_step_seconds() - 0.25).abs() < 1e-12);
        let trace = r.to_trace(2);
        assert_eq!(trace.len(), 4);
        assert_eq!(trace.iterations[0][0].distribution(), vec![4, 0]);
    }

    #[test]
    fn forecast_error_stats() {
        let mut r = TrainReport::default();
        assert!(r.mean_forecast_error().is_nan());
        r.forecast_errors = vec![0.1, 0.3];
        assert!((r.mean_forecast_error() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn report_json_parses() {
        let r = TrainReport {
            preset: "t".into(),
            losses: vec![1.5],
            step_seconds: vec![0.01],
            loads: vec![],
            ..Default::default()
        };
        let j = r.to_json().to_string();
        assert!(crate::util::json::parse(&j).is_ok());
    }
}
