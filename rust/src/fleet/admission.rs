//! Admission control: which queued jobs enter the fleet this tick.
//!
//! Jobs queue from their `start` tick and are admitted only when the
//! [`crate::fleet::lease::LeaseBook`] can grant their full node ask and
//! the concurrent-job cap has room.  A job that cannot be admitted is
//! **deferred** — counted as backpressure, retried every tick, never an
//! error (the all-devices-down and cluster-full cases degrade to
//! waiting, not crashing).
//!
//! Two deterministic policies order the attempt:
//!
//! * [`AdmissionPolicy::Fifo`] — queue order (start tick, then spec
//!   order), with head-of-line blocking: the first job that does not fit
//!   stops the scan, so a big job is never starved by small ones slipping
//!   past it.
//! * [`AdmissionPolicy::SmallestFirst`] — smallest node ask first (ties
//!   by queue order), scanning past misfits: better packing, unbounded
//!   starvation risk for big jobs — the classic trade-off, exposed as a
//!   config axis.

/// Order in which queued jobs attempt admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    Fifo,
    SmallestFirst,
}

impl AdmissionPolicy {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "smallest_first" => Some(AdmissionPolicy::SmallestFirst),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::SmallestFirst => "smallest_first",
        }
    }

    /// Whether a failed grant stops the scan (head-of-line blocking).
    pub fn head_of_line_blocking(&self) -> bool {
        matches!(self, AdmissionPolicy::Fifo)
    }

    /// Deterministic attempt order over `(queue_pos, node_ask)` pairs:
    /// the returned indices point into `candidates`.
    pub fn order(&self, candidates: &[(usize, usize)]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..candidates.len()).collect();
        match self {
            AdmissionPolicy::Fifo => idx.sort_by_key(|&i| candidates[i].0),
            AdmissionPolicy::SmallestFirst => {
                idx.sort_by_key(|&i| (candidates[i].1, candidates[i].0))
            }
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for p in [AdmissionPolicy::Fifo, AdmissionPolicy::SmallestFirst] {
            assert_eq!(AdmissionPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::from_name("priority"), None);
    }

    #[test]
    fn fifo_orders_by_queue_position_and_blocks() {
        let p = AdmissionPolicy::Fifo;
        // (queue_pos, nodes): big job queued first stays first.
        let c = [(2usize, 1usize), (0, 8), (1, 2)];
        assert_eq!(p.order(&c), vec![1, 2, 0]);
        assert!(p.head_of_line_blocking());
    }

    #[test]
    fn smallest_first_orders_by_ask_then_position() {
        let p = AdmissionPolicy::SmallestFirst;
        let c = [(0usize, 4usize), (1, 1), (2, 1), (3, 2)];
        assert_eq!(p.order(&c), vec![1, 2, 3, 0]);
        assert!(!p.head_of_line_blocking());
    }
}
