//! Device leasing: the fleet's ownership ledger over whole nodes.
//!
//! The fleet leases **whole nodes**, never single GPUs: every cluster
//! preset packs `gpus_per_node` devices per node and the topology model
//! only distinguishes same-node from cross-node links, so any k-node
//! subset of an N-node cluster is exactly the k-node cluster of the same
//! preset.  That is what makes [`sub_cluster`] honest — a tenant priced
//! on its leased slice sees the same bandwidths it would see on a
//! dedicated cluster of that size.
//!
//! The [`LeaseBook`] is the single source of truth for who holds what:
//! grants carve the lowest-id free nodes, shrinks return the highest-id
//! held nodes first (so leases stay compact), and [`LeaseBook::validate`]
//! checks the disjointness + conservation invariant the property suite
//! leans on (no node leased twice, free + held == cluster).

use crate::cluster::ClusterSpec;
use std::collections::{BTreeMap, BTreeSet};

/// Ownership ledger: which job (by id) holds which global node ids.
#[derive(Clone, Debug)]
pub struct LeaseBook {
    n_nodes: usize,
    free: BTreeSet<usize>,
    held: BTreeMap<usize, Vec<usize>>,
}

impl LeaseBook {
    pub fn new(n_nodes: usize) -> Self {
        LeaseBook {
            n_nodes,
            free: (0..n_nodes).collect(),
            held: BTreeMap::new(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn free_nodes(&self) -> usize {
        self.free.len()
    }

    /// The sorted node ids `job` currently holds (empty slice if none).
    pub fn lease(&self, job: usize) -> &[usize] {
        self.held.get(&job).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Lease exactly `n` nodes to `job` (lowest free ids first).  `None`
    /// when fewer than `n` nodes are free — the caller defers admission;
    /// nothing is partially granted.
    pub fn grant(&mut self, job: usize, n: usize) -> Option<Vec<usize>> {
        assert!(
            !self.held.contains_key(&job),
            "job {job} already holds a lease; grow it instead"
        );
        if n == 0 || self.free.len() < n {
            return None;
        }
        let nodes: Vec<usize> = self.free.iter().copied().take(n).collect();
        for &g in &nodes {
            self.free.remove(&g);
        }
        self.held.insert(job, nodes.clone());
        Some(nodes)
    }

    /// Return all of `job`'s nodes to the pool; the number released.
    pub fn release(&mut self, job: usize) -> usize {
        let nodes = self.held.remove(&job).unwrap_or_default();
        let n = nodes.len();
        self.free.extend(nodes);
        n
    }

    /// Extend `job`'s lease by up to `extra` free nodes (lowest ids
    /// first); returns how many were actually added.
    pub fn grow(&mut self, job: usize, extra: usize) -> usize {
        let take = extra.min(self.free.len());
        if take == 0 || !self.held.contains_key(&job) {
            return 0;
        }
        let nodes: Vec<usize> = self.free.iter().copied().take(take).collect();
        for &g in &nodes {
            self.free.remove(&g);
        }
        let lease = self.held.get_mut(&job).expect("checked above");
        lease.extend(nodes);
        lease.sort_unstable();
        take
    }

    /// Give back up to `give_back` of `job`'s nodes (highest ids first,
    /// keeping at least one); returns how many were released.
    pub fn shrink(&mut self, job: usize, give_back: usize) -> usize {
        let Some(lease) = self.held.get_mut(&job) else {
            return 0;
        };
        let take = give_back.min(lease.len().saturating_sub(1));
        for _ in 0..take {
            let g = lease.pop().expect("len > 1 checked by take bound");
            self.free.insert(g);
        }
        take
    }

    /// The disjointness + conservation invariant: every node is either
    /// free or held by exactly one job, and nothing is out of range.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen: BTreeSet<usize> = self.free.clone();
        if seen.len() != self.free.len() {
            return Err("free pool contains duplicates".into());
        }
        for (&job, nodes) in &self.held {
            if nodes.is_empty() {
                return Err(format!("job {job} holds an empty lease"));
            }
            for &g in nodes {
                if g >= self.n_nodes {
                    return Err(format!("job {job} holds out-of-range node {g}"));
                }
                if !seen.insert(g) {
                    return Err(format!("node {g} is leased twice (job {job} overlaps)"));
                }
            }
        }
        if seen.len() != self.n_nodes {
            return Err(format!(
                "conservation violated: {} nodes accounted for, cluster has {}",
                seen.len(),
                self.n_nodes
            ));
        }
        Ok(())
    }
}

/// The cluster a lease's tenant actually runs on.  A full-cluster lease
/// returns the fleet cluster **verbatim** (name included) — that is the
/// degenerate-fleet oracle's precondition: a single job holding every
/// node prices on bit-identical inputs to a standalone `simulate_policy`
/// run.  A partial lease is the same preset at the leased node count,
/// with the static per-device slowdown vector sliced to the leased
/// nodes' devices (node `g` owns global devices `g*gpn..(g+1)*gpn`).
pub fn sub_cluster(fleet: &ClusterSpec, lease: &[usize]) -> ClusterSpec {
    if lease.len() == fleet.n_nodes {
        return fleet.clone();
    }
    let gpn = fleet.gpus_per_node;
    let device_slowdown = if fleet.device_slowdown.is_empty() {
        Vec::new()
    } else {
        lease
            .iter()
            .flat_map(|&g| (g * gpn..(g + 1) * gpn).map(|d| fleet.slowdown(d)))
            .collect()
    };
    ClusterSpec {
        name: format!("{}/lease{}", fleet.name, lease.len()),
        n_nodes: lease.len(),
        device_slowdown,
        ..fleet.clone()
    }
}

/// Global device ids covered by a lease, in lease order — index `i` of
/// the returned vector is local device `i` of the tenant's sub-cluster.
pub fn lease_devices(fleet: &ClusterSpec, lease: &[usize]) -> Vec<usize> {
    let gpn = fleet.gpus_per_node;
    lease
        .iter()
        .flat_map(|&g| g * gpn..(g + 1) * gpn)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn grant_release_round_trip() {
        let mut b = LeaseBook::new(4);
        assert_eq!(b.free_nodes(), 4);
        let l = b.grant(7, 2).unwrap();
        assert_eq!(l, vec![0, 1], "lowest free ids first");
        assert_eq!(b.lease(7), &[0, 1]);
        assert_eq!(b.free_nodes(), 2);
        b.validate().unwrap();
        // A second tenant gets the remaining nodes; a third is refused.
        assert_eq!(b.grant(9, 2).unwrap(), vec![2, 3]);
        assert!(b.grant(11, 1).is_none(), "no partial grants");
        assert_eq!(b.release(7), 2);
        assert_eq!(b.lease(7), &[] as &[usize]);
        // Released nodes are immediately grantable again.
        assert_eq!(b.grant(11, 1).unwrap(), vec![0]);
        b.validate().unwrap();
    }

    #[test]
    fn grow_and_shrink_keep_leases_compact() {
        let mut b = LeaseBook::new(6);
        b.grant(0, 2).unwrap();
        b.grant(1, 2).unwrap();
        assert_eq!(b.grow(0, 3), 2, "grow is best-effort up to the free pool");
        assert_eq!(b.lease(0), &[0, 1, 4, 5]);
        // Shrink returns highest ids and never empties a lease.
        assert_eq!(b.shrink(0, 10), 3);
        assert_eq!(b.lease(0), &[0]);
        assert_eq!(b.free_nodes(), 3);
        assert_eq!(b.shrink(0, 1), 0, "last node is never given back");
        // Grow on an unknown job is a no-op (it has no lease to extend).
        assert_eq!(b.grow(42, 1), 0);
        b.validate().unwrap();
    }

    #[test]
    fn sub_cluster_full_lease_is_verbatim() {
        let fleet = ClusterSpec::hpwnv(4);
        let sub = sub_cluster(&fleet, &[0, 1, 2, 3]);
        assert_eq!(sub, fleet, "full lease must clone the fleet cluster exactly");
        assert_eq!(sub.name, fleet.name);
    }

    #[test]
    fn sub_cluster_partial_lease_slices_slowdowns() {
        let fleet = ClusterSpec::hpwnv(4).with_slowdown(9, 3.0); // node 2, dev 1
        let sub = sub_cluster(&fleet, &[2, 3]);
        assert_eq!(sub.n_nodes, 2);
        assert_eq!(sub.n_devices(), 8);
        assert_eq!(sub.gpus_per_node, fleet.gpus_per_node);
        assert_eq!(sub.intra_bw, fleet.intra_bw);
        // Global device 9 is local device 1 of the [2, 3] lease.
        assert_eq!(sub.slowdown(1), 3.0);
        assert!(sub.device_slowdown.iter().filter(|&&s| s != 1.0).count() == 1);
        // Homogeneous fleet -> empty (not all-ones) local vector, so the
        // sub-cluster stays on the frozen homogeneous pricing path.
        let homo = sub_cluster(&ClusterSpec::hpwnv(4), &[1]);
        assert!(homo.device_slowdown.is_empty());
        assert!(!homo.is_heterogeneous());
        assert_eq!(lease_devices(&fleet, &[2, 3]), vec![8, 9, 10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn random_ops_preserve_disjointness() {
        // Property: any interleaving of grant/release/grow/shrink keeps
        // the book valid — no node leased twice, conservation holds.
        prop::Cases::new(prop::default_cases()).run(|rng| {
            let n_nodes = 1 + rng.below(12) as usize;
            let mut b = LeaseBook::new(n_nodes);
            let jobs = 1 + rng.below(5) as usize;
            for _ in 0..40 {
                let job = rng.below(jobs as u64) as usize;
                match rng.below(4) {
                    0 => {
                        if b.lease(job).is_empty() {
                            let want = 1 + rng.below(n_nodes as u64) as usize;
                            let granted = b.grant(job, want);
                            if let Some(g) = &granted {
                                assert_eq!(g.len(), want);
                            }
                        }
                    }
                    1 => {
                        b.release(job);
                    }
                    2 => {
                        b.grow(job, 1 + rng.below(3) as usize);
                    }
                    _ => {
                        b.shrink(job, 1 + rng.below(3) as usize);
                    }
                }
                b.validate().unwrap();
                let held: usize = (0..jobs).map(|j| b.lease(j).len()).sum();
                assert_eq!(held + b.free_nodes(), n_nodes);
            }
        });
    }
}
