//! Inference tenants: bursty request traffic over a leased slice.
//!
//! An inference job is an open-loop queueing system: a seeded
//! [`ArrivalGen`] pushes requests per tick, a FIFO queue absorbs bursts,
//! and each tick the job drains up to `batch_tokens` worth of requests
//! into one single-layer [`LoadMatrix`] (MoE decode: every batch routes
//! through one expert layer of the leased slice).  The batch is priced
//! by the same DES-backed step as training iterations; per-request
//! latency — queueing delay in ticks plus the priced service time — is
//! scored against the SLO.
//!
//! The queue also produces the **replica-demand signal** the fleet's
//! rebalancer consumes: [`InferenceState::pressure`] is queued work in
//! units of one tick's drain capacity, so `> 1` means the job is falling
//! behind (grow its lease) and `~0` means the lease is oversized
//! (shrink it).
//!
//! Determinism: arrivals are a pure function of `(process, seed)`, the
//! batch expert mix is drawn from the job's own PRNG stream, and the
//! expert popularity is a pure function of `(seed, n_experts)` — so a
//! lease resize (which changes the expert count) re-derives popularity
//! deterministically and same-seed runs stay byte-identical.

use crate::moe::LoadMatrix;
use crate::util::rng::Rng;
use crate::workload::arrivals::{ArrivalGen, ArrivalProcess};
use std::collections::VecDeque;

/// One queued inference request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Fleet tick the request arrived on.
    pub arrived: usize,
    /// Routing slots it contributes to its batch.
    pub tokens: u64,
}

/// Queueing + latency/SLO state of one inference job.
#[derive(Clone, Debug)]
pub struct InferenceState {
    arrivals: ArrivalGen,
    queue: VecDeque<Request>,
    rng: Rng,
    seed: u64,
    popularity: Vec<f64>,
    zipf_s: f64,
    pub tokens_per_req: u64,
    pub batch_tokens: u64,
    /// Latency objective in seconds.
    pub slo_s: f64,
    // --- accounting -----------------------------------------------------
    pub requests_arrived: u64,
    pub requests_completed: u64,
    pub slo_hits: u64,
    pub latency_sum_s: f64,
    pub latency_max_s: f64,
}

impl InferenceState {
    pub fn new(
        process: ArrivalProcess,
        seed: u64,
        tokens_per_req: u64,
        batch_tokens: u64,
        slo_s: f64,
        n_experts: usize,
        zipf_s: f64,
    ) -> Self {
        let mut s = InferenceState {
            arrivals: ArrivalGen::new(process, seed),
            queue: VecDeque::new(),
            rng: Rng::new(seed).split(0xF1EE7),
            seed,
            popularity: Vec::new(),
            zipf_s,
            tokens_per_req: tokens_per_req.max(1),
            batch_tokens: batch_tokens.max(1),
            slo_s,
            requests_arrived: 0,
            requests_completed: 0,
            slo_hits: 0,
            latency_sum_s: 0.0,
            latency_max_s: 0.0,
        };
        s.reseed_popularity(n_experts);
        s
    }

    /// Re-derive the expert popularity for a (new) expert count — a pure
    /// function of `(seed, n_experts)`, called at admission and after
    /// every lease resize.
    pub fn reseed_popularity(&mut self, n_experts: usize) {
        let mut r = Rng::new(self.seed).split(n_experts as u64);
        let mut ranks: Vec<usize> = (0..n_experts).collect();
        r.shuffle(&mut ranks);
        let h: f64 = (1..=n_experts).map(|k| (k as f64).powf(-self.zipf_s)).sum();
        let mut p = vec![0.0; n_experts];
        for (rank_pos, &e) in ranks.iter().enumerate() {
            p[e] = ((rank_pos + 1) as f64).powf(-self.zipf_s) / h;
        }
        self.popularity = p;
    }

    /// Draw this tick's arrivals into the queue; returns the count.
    pub fn arrive(&mut self, tick: usize) -> u64 {
        let n = self.arrivals.next_tick();
        for _ in 0..n {
            self.queue.push_back(Request { arrived: tick, tokens: self.tokens_per_req });
        }
        self.requests_arrived += n;
        n
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn queued_tokens(&self) -> u64 {
        self.queue.iter().map(|r| r.tokens).sum()
    }

    /// Replica-demand signal: queued work in units of one tick's drain
    /// capacity (`batch_tokens`).  `> 1` = falling behind, `~0` = idle.
    pub fn pressure(&self) -> f64 {
        self.queued_tokens() as f64 / self.batch_tokens as f64
    }

    /// Pop the next batch (FIFO, up to `batch_tokens`; always at least
    /// one request when the queue is non-empty, so an oversized request
    /// still makes progress).  Empty vec = nothing to serve this tick.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let mut batch = Vec::new();
        let mut tokens = 0u64;
        while let Some(r) = self.queue.front() {
            if !batch.is_empty() && tokens + r.tokens > self.batch_tokens {
                break;
            }
            tokens += r.tokens;
            batch.push(self.queue.pop_front().expect("front was Some"));
        }
        batch
    }

    /// Route a batch onto the leased slice: tokens split evenly across
    /// local devices (remainder to the lowest ids — the DP-shard split),
    /// each device's share drawn multinomially from the job's expert
    /// popularity.
    pub fn batch_matrix(&mut self, batch: &[Request], n_devices: usize) -> LoadMatrix {
        let n_experts = self.popularity.len();
        let total: u64 = batch.iter().map(|r| r.tokens).sum();
        let per = total / n_devices as u64;
        let rem = (total % n_devices as u64) as usize;
        let mut w = LoadMatrix::zeros(n_devices, n_experts);
        for d in 0..n_devices {
            let share = per + u64::from(d < rem);
            let counts = self.rng.multinomial(share, &self.popularity);
            for (e, &c) in counts.iter().enumerate() {
                w.set(d, e, c);
            }
        }
        w
    }

    /// Score a served batch: latency = queueing delay (whole ticks) plus
    /// the priced service time, against the SLO.
    pub fn complete_batch(&mut self, batch: &[Request], tick: usize, tick_s: f64, service_s: f64) {
        for r in batch {
            let latency = (tick - r.arrived) as f64 * tick_s + service_s;
            self.requests_completed += 1;
            if latency <= self.slo_s {
                self.slo_hits += 1;
            }
            self.latency_sum_s += latency;
            if latency > self.latency_max_s {
                self.latency_max_s = latency;
            }
        }
    }

    /// Fraction of completed requests inside the SLO (1.0 when nothing
    /// has completed — vacuously attained).
    pub fn slo_attainment(&self) -> f64 {
        if self.requests_completed == 0 {
            1.0
        } else {
            self.slo_hits as f64 / self.requests_completed as f64
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.requests_completed == 0 {
            0.0
        } else {
            self.latency_sum_s / self.requests_completed as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(rate: f64) -> InferenceState {
        InferenceState::new(
            ArrivalProcess::Poisson { rate },
            7,
            64,
            256,
            0.5,
            8,
            1.2,
        )
    }

    #[test]
    fn arrivals_queue_and_batches_drain_fifo() {
        let mut s = state(4.0);
        let mut arrived = 0;
        for t in 0..8 {
            arrived += s.arrive(t);
        }
        assert_eq!(arrived, s.requests_arrived);
        assert_eq!(s.queue_depth() as u64, arrived);
        assert_eq!(s.queued_tokens(), arrived * 64);
        let batch = s.take_batch();
        assert!(!batch.is_empty());
        assert!(batch.iter().map(|r| r.tokens).sum::<u64>() <= 256);
        // FIFO: the batch holds the oldest requests.
        let oldest = batch.iter().map(|r| r.arrived).max().unwrap();
        assert!(s.queue.iter().all(|r| r.arrived >= oldest));
    }

    #[test]
    fn oversized_request_still_makes_progress() {
        let mut s = state(0.0);
        s.queue.push_back(Request { arrived: 0, tokens: 10_000 });
        let batch = s.take_batch();
        assert_eq!(batch.len(), 1, "a request larger than the batch cap still serves");
        assert!(s.take_batch().is_empty());
    }

    #[test]
    fn batch_matrix_conserves_tokens() {
        let mut s = state(0.0);
        let batch = vec![
            Request { arrived: 0, tokens: 100 },
            Request { arrived: 1, tokens: 55 },
        ];
        let w = s.batch_matrix(&batch, 4);
        assert_eq!(w.n_devices(), 4);
        assert_eq!(w.n_experts(), 8);
        assert_eq!(w.total_tokens(), 155);
    }

    #[test]
    fn popularity_is_a_pure_function_of_seed_and_width() {
        let mut a = state(1.0);
        let b = state(1.0);
        assert_eq!(a.popularity, b.popularity);
        let before = a.popularity.clone();
        a.reseed_popularity(16);
        assert_eq!(a.popularity.len(), 16);
        a.reseed_popularity(8);
        assert_eq!(a.popularity, before, "resize back re-derives identical popularity");
        let sum: f64 = a.popularity.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_and_slo_accounting() {
        let mut s = state(0.0);
        let batch = vec![
            Request { arrived: 0, tokens: 64 }, // waited 4 ticks
            Request { arrived: 4, tokens: 64 }, // served same tick
        ];
        // tick_s = 0.1, service 0.05: latencies 0.45 and 0.05 vs slo 0.5.
        s.complete_batch(&batch, 4, 0.1, 0.05);
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.slo_hits, 2);
        assert!((s.slo_attainment() - 1.0).abs() < 1e-12);
        assert!((s.mean_latency_s() - 0.25).abs() < 1e-12);
        assert!((s.latency_max_s - 0.45).abs() < 1e-12);
        // A slow service blows the SLO for the waiting request.
        let late = vec![Request { arrived: 0, tokens: 64 }];
        s.complete_batch(&late, 5, 0.1, 0.2);
        assert_eq!(s.requests_completed, 3);
        assert_eq!(s.slo_hits, 2);
        assert!(s.slo_attainment() < 1.0);
    }

    #[test]
    fn pressure_tracks_queue_vs_capacity() {
        let mut s = state(0.0);
        assert_eq!(s.pressure(), 0.0);
        for _ in 0..8 {
            s.queue.push_back(Request { arrived: 0, tokens: 64 });
        }
        // 512 queued tokens / 256 batch = 2 ticks behind.
        assert!((s.pressure() - 2.0).abs() < 1e-12);
    }
}
