//! Fleet: multi-job cluster simulation on top of [`crate::balancer`].
//!
//! One [`Fleet`] run owns a [`ClusterSpec`] and time-steps a set of
//! tenant jobs over it:
//!
//! * **Leasing** ([`lease`]) — each admitted job holds a disjoint slice
//!   of whole nodes; its `BalancerSession` + prophet run entirely over
//!   that slice, priced by the existing DES on the sliced sub-cluster.
//! * **Admission** ([`admission`]) — jobs queue from their `start` tick
//!   and enter when their full node ask fits; misfits are deferred
//!   (counted backpressure), never crashed.
//! * **Training tenants** — fixed-size jobs running a captured workload
//!   trace, one iteration per tick, through the exact single-iteration
//!   step the simulator uses (`sim::price_and_observe`): a one-job fleet
//!   holding the whole cluster reproduces `simulate_policy` bit-for-bit
//!   (the degenerate-fleet oracle).
//! * **Inference tenants** ([`inference`]) — elastic jobs driven by
//!   seeded Poisson / ON-OFF-bursty arrivals, batching queued requests
//!   into single-layer iterations, scoring per-request latency against
//!   an SLO and exposing queue pressure as the replica-demand signal.
//! * **Rebalancing** — every `rebalance_interval` ticks the fleet
//!   resizes inference leases toward demand (FlexMoE-style), moving at
//!   most `migration_budget` nodes per event, in a deterministic order.
//! * **Fleet-wide faults** — one [`FaultTimeline`] indexed by tick spans
//!   the whole cluster; each tenant sees the slice covering its lease,
//!   so one failing device degrades every job leasing its node.  A
//!   tenant whose entire slice is down is **parked** for the tick
//!   (see satellite: `Placement::fail_over` all-down is a typed error).
//!
//! Everything is deterministic: same config + seed produce a
//! byte-identical [`FleetReport`] serialization.

pub mod admission;
pub mod inference;
pub mod lease;

pub use admission::AdmissionPolicy;
pub use lease::{lease_devices, sub_cluster, LeaseBook};

use crate::balancer::{BalancerSession, ProphetOptions};
use crate::cluster::ClusterSpec;
use crate::config::{toml, ModelSpec};
use crate::faults::{FaultTimeline, FaultView};
use crate::moe::LoadMatrix;
use crate::obs::{Labels, Recorder};
use crate::perfmodel::PerfModel;
use crate::sim::{checkpoint, price_and_observe, Engine, PriceState, SimReport};
use crate::util::json::{self, Json};
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::{Trace, WorkloadConfig, WorkloadGen};
use inference::InferenceState;
use std::sync::Arc;

/// Schema tag of a serialized [`FleetReport`].
pub const FLEET_SCHEMA: &str = "pro-prophet-fleet/v1";

/// What kind of tenant a [`JobSpec`] describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Train,
    Infer,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Train => "train",
            JobKind::Infer => "infer",
        }
    }
}

/// One tenant, parsed from a `[fleet] jobs` spec string.
///
/// Spec grammar (comma-free, whitespace-separated `key=value` pairs,
/// like fault-event specs):
///
/// ```text
/// train name=alpha nodes=2 model=s k=1 tokens=8192 iters=24 policy=pro-prophet start=0 seed=11
/// infer name=serve nodes=1 min_nodes=1 max_nodes=2 model=s rate=3 slo_ms=400
///       burst_on=4 burst_off=6 burst_factor=4 tokens_per_req=64 batch_tokens=2048
///       policy=pro-prophet start=0 seed=13
/// ```
///
/// An inference spec without `burst_*` keys is a plain Poisson stream.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub kind: JobKind,
    /// Node ask at admission (train: for the whole run).
    pub nodes: usize,
    /// Elastic bounds (inference only; train pins both to `nodes`).
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Table-III model preset name (`s|m|l|ds|dm`).
    pub model: String,
    pub k: usize,
    /// Train: tokens per iteration across the lease.
    pub tokens: u64,
    /// Train: iterations to run before completing.
    pub iters: usize,
    /// Balancing-policy registry name.
    pub policy: String,
    /// First tick the job may be admitted.
    pub start: usize,
    pub seed: u64,
    // --- inference knobs -------------------------------------------------
    /// Mean requests per tick.
    pub rate: f64,
    /// ON/OFF burst cycle (both 0 = plain Poisson).
    pub burst_on: usize,
    pub burst_off: usize,
    pub burst_factor: f64,
    pub tokens_per_req: u64,
    pub batch_tokens: u64,
    pub slo_ms: f64,
}

impl JobSpec {
    /// Parse one spec string (see the type docs for the grammar).
    pub fn parse(spec: &str) -> Result<JobSpec, String> {
        let mut words = spec.split_whitespace();
        let kind = match words.next() {
            Some("train") => JobKind::Train,
            Some("infer") => JobKind::Infer,
            Some(other) => return Err(format!("unknown job kind `{other}` in `{spec}`")),
            None => return Err("empty job spec".into()),
        };
        let mut job = JobSpec {
            name: String::new(),
            kind,
            nodes: 1,
            min_nodes: 0,
            max_nodes: 0,
            model: "s".into(),
            k: 1,
            tokens: 8192,
            iters: 16,
            policy: "pro-prophet".into(),
            start: 0,
            seed: 42,
            rate: 2.0,
            burst_on: 0,
            burst_off: 0,
            burst_factor: 1.0,
            tokens_per_req: 64,
            batch_tokens: 2048,
            slo_ms: 500.0,
        };
        for word in words {
            let (key, value) = word
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got `{word}` in `{spec}`"))?;
            let us = || {
                value
                    .parse::<usize>()
                    .map_err(|_| format!("`{key}={value}`: not a non-negative integer"))
            };
            let fl = || {
                value
                    .parse::<f64>()
                    .map_err(|_| format!("`{key}={value}`: not a number"))
            };
            match key {
                "name" => job.name = value.to_string(),
                "nodes" => job.nodes = us()?,
                "min_nodes" => job.min_nodes = us()?,
                "max_nodes" => job.max_nodes = us()?,
                "model" => job.model = value.to_string(),
                "k" => job.k = us()?,
                "tokens" => job.tokens = us()? as u64,
                "iters" => job.iters = us()?,
                "policy" => job.policy = value.to_string(),
                "start" => job.start = us()?,
                "seed" => job.seed = us()? as u64,
                "rate" => job.rate = fl()?,
                "burst_on" => job.burst_on = us()?,
                "burst_off" => job.burst_off = us()?,
                "burst_factor" => job.burst_factor = fl()?,
                "tokens_per_req" => job.tokens_per_req = us()? as u64,
                "batch_tokens" => job.batch_tokens = us()? as u64,
                "slo_ms" => job.slo_ms = fl()?,
                _ => return Err(format!("unknown job key `{key}` in `{spec}`")),
            }
        }
        if job.min_nodes == 0 {
            job.min_nodes = if kind == JobKind::Infer { 1 } else { job.nodes };
        }
        if job.max_nodes == 0 {
            job.max_nodes = job.nodes;
        }
        if kind == JobKind::Train {
            job.min_nodes = job.nodes;
            job.max_nodes = job.nodes;
        }
        Ok(job)
    }

    /// The arrival process an inference spec describes.
    pub fn arrival_process(&self) -> ArrivalProcess {
        if self.burst_on > 0 || self.burst_off > 0 {
            ArrivalProcess::OnOffBursty {
                rate: self.rate,
                on_ticks: self.burst_on,
                off_ticks: self.burst_off,
                burst_factor: self.burst_factor,
            }
        } else {
            ArrivalProcess::Poisson { rate: self.rate }
        }
    }

    fn validate(&self, cluster: &ClusterSpec) -> Result<(), String> {
        let who = format!("job `{}`", self.name);
        if self.name.is_empty() {
            return Err("every fleet job needs name=...".into());
        }
        if self.nodes == 0 {
            return Err(format!("{who}: nodes must be >= 1"));
        }
        if self.nodes > cluster.n_nodes {
            return Err(format!(
                "{who}: asks {} nodes, cluster has {}",
                self.nodes, cluster.n_nodes
            ));
        }
        if !(self.min_nodes >= 1 && self.min_nodes <= self.nodes && self.nodes <= self.max_nodes)
        {
            return Err(format!(
                "{who}: need 1 <= min_nodes ({}) <= nodes ({}) <= max_nodes ({})",
                self.min_nodes, self.nodes, self.max_nodes
            ));
        }
        if self.max_nodes > cluster.n_nodes {
            return Err(format!(
                "{who}: max_nodes {} exceeds the cluster's {}",
                self.max_nodes, cluster.n_nodes
            ));
        }
        if ModelSpec::by_name(&self.model, cluster.gpus_per_node, 1, 1).is_none() {
            return Err(format!("{who}: unknown model `{}`", self.model));
        }
        if !crate::balancer::registry::is_known(&self.policy) {
            return Err(format!(
                "{who}: unknown policy `{}` (known: {})",
                self.policy,
                crate::balancer::registry::names().join(", ")
            ));
        }
        match self.kind {
            JobKind::Train => {
                if self.iters == 0 {
                    return Err(format!("{who}: iters must be >= 1"));
                }
                if self.tokens == 0 {
                    return Err(format!("{who}: tokens must be >= 1"));
                }
            }
            JobKind::Infer => {
                self.arrival_process()
                    .validate()
                    .map_err(|e| format!("{who}: {e}"))?;
                if self.slo_ms <= 0.0 || !self.slo_ms.is_finite() {
                    return Err(format!("{who}: slo_ms must be finite and > 0"));
                }
            }
        }
        Ok(())
    }
}

/// The `[fleet]` table: the tick clock, admission/rebalancing knobs and
/// the tenant list.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Fleet ticks to simulate.
    pub ticks: usize,
    /// Wall-clock seconds one tick represents (queueing-delay unit for
    /// inference latency; pricing inside a tick is still the DES).
    pub tick_s: f64,
    /// Concurrent-tenant cap (admission backpressure axis).
    pub max_concurrent: usize,
    pub admission: AdmissionPolicy,
    /// Rebalance every this many ticks (0 = never).
    pub rebalance_interval: usize,
    /// Max nodes moved per rebalance event.
    pub migration_budget: usize,
    pub jobs: Vec<JobSpec>,
}

impl FleetConfig {
    /// Parse the `[fleet]` table out of a config file's [`toml::Table`];
    /// `Ok(None)` when the file has no `[fleet]` table at all.
    pub fn from_table(t: &toml::Table, cluster: &ClusterSpec) -> Result<Option<Self>, String> {
        if !t.keys().any(|k| k == "fleet.jobs" || k.starts_with("fleet.")) {
            return Ok(None);
        }
        let admission_name = t.str_or("fleet.admission", "fifo");
        let admission = AdmissionPolicy::from_name(&admission_name).ok_or_else(|| {
            format!("unknown fleet.admission {admission_name:?} (known: fifo, smallest_first)")
        })?;
        let jobs = match t.get("fleet.jobs") {
            None => return Err("[fleet] needs jobs = [\"train ...\", \"infer ...\"]".into()),
            Some(toml::Value::Arr(vals)) => {
                let mut jobs = Vec::new();
                for v in vals {
                    let spec = v
                        .as_str()
                        .ok_or_else(|| "fleet.jobs entries must be strings".to_string())?;
                    jobs.push(JobSpec::parse(spec).map_err(|e| format!("fleet.jobs: {e}"))?);
                }
                jobs
            }
            Some(_) => return Err("fleet.jobs must be an array of job specs".into()),
        };
        let cfg = FleetConfig {
            ticks: t.usize_or("fleet.ticks", 32),
            tick_s: t.f64_or("fleet.tick_s", 0.25),
            max_concurrent: t.usize_or("fleet.max_concurrent", jobs.len().max(1)),
            admission,
            rebalance_interval: t.usize_or("fleet.rebalance_interval", 4),
            migration_budget: t.usize_or("fleet.migration_budget", 1),
            jobs,
        };
        cfg.validate(cluster)?;
        Ok(Some(cfg))
    }

    pub fn validate(&self, cluster: &ClusterSpec) -> Result<(), String> {
        if self.ticks == 0 {
            return Err("fleet.ticks must be >= 1".into());
        }
        if !(self.tick_s.is_finite() && self.tick_s > 0.0) {
            return Err(format!("fleet.tick_s must be finite and > 0, got {}", self.tick_s));
        }
        if self.max_concurrent == 0 {
            return Err("fleet.max_concurrent must be >= 1".into());
        }
        if self.jobs.is_empty() {
            return Err("[fleet] needs at least one job".into());
        }
        for job in &self.jobs {
            job.validate(cluster)?;
        }
        for (i, a) in self.jobs.iter().enumerate() {
            if self.jobs[..i].iter().any(|b| b.name == a.name) {
                return Err(format!("duplicate fleet job name `{}`", a.name));
            }
        }
        Ok(())
    }
}

/// Growth/shrink thresholds of the demand-driven rebalancer: a job more
/// than one full tick behind wants nodes; one at under a quarter tick of
/// queued work can give one up.
const GROW_PRESSURE: f64 = 1.0;
const SHRINK_PRESSURE: f64 = 0.25;

/// Live state of one admitted tenant.
struct JobRuntime {
    spec: usize,
    admitted_tick: usize,
    completed_tick: Option<usize>,
    /// Sorted global node ids (mirrors the lease book).
    lease: Vec<usize>,
    cluster: ClusterSpec,
    model: ModelSpec,
    pm: PerfModel,
    session: BalancerSession,
    /// Per-tenant DES scratch + incremental re-pricing cache (reset on
    /// resize: a new lease means a new session and cluster).
    price: PriceState,
    heterogeneous: bool,
    /// Train: the captured workload, one iteration per tick.
    trace: Option<Trace>,
    next_iter: usize,
    /// Inference queue/latency state.
    infer: Option<InferenceState>,
    /// Per-iteration results, simulator-shaped (the degenerate oracle
    /// compares this verbatim against `simulate_policy`).
    sim: SimReport,
    busy_s: f64,
    parked_ticks: usize,
    idle_ticks: usize,
    tokens_processed: u64,
}

impl JobRuntime {
    /// Build a tenant's whole pricing stack over its leased slice.
    fn new(
        spec_idx: usize,
        spec: &JobSpec,
        fleet_cluster: &ClusterSpec,
        lease: Vec<usize>,
        popts: &ProphetOptions,
        rec: Arc<dyn Recorder>,
        tick: usize,
    ) -> Result<Self, String> {
        let cluster = sub_cluster(fleet_cluster, &lease);
        let d = cluster.n_devices();
        // Repo convention: experts per layer == device count.
        let model = ModelSpec::by_name(&spec.model, d, spec.k, spec.tokens)
            .ok_or_else(|| format!("job `{}`: unknown model `{}`", spec.name, spec.model))?;
        let (n_layers, trace, infer) = match spec.kind {
            JobKind::Train => {
                let mut wcfg =
                    WorkloadConfig::paper_default(model.n_layers, d, d, spec.tokens * spec.k as u64);
                wcfg.seed = spec.seed;
                let mut gen = WorkloadGen::new(wcfg);
                (model.n_layers, Some(Trace::capture(&mut gen, spec.iters)), None)
            }
            JobKind::Infer => {
                let state = InferenceState::new(
                    spec.arrival_process(),
                    spec.seed,
                    spec.tokens_per_req,
                    spec.batch_tokens,
                    spec.slo_ms / 1000.0,
                    d,
                    1.2,
                );
                (1, None, Some(state))
            }
        };
        let policy = crate::balancer::registry::build(&spec.policy, popts)
            .ok_or_else(|| format!("job `{}`: unknown policy `{}`", spec.name, spec.policy))?;
        let session = BalancerSession::with_recorder(policy, n_layers, rec);
        let pm = PerfModel::new(&model, &cluster);
        let heterogeneous = cluster.is_heterogeneous();
        let sim = SimReport { policy: session.policy_name(), ..Default::default() };
        Ok(JobRuntime {
            spec: spec_idx,
            admitted_tick: tick,
            completed_tick: None,
            lease,
            cluster,
            model,
            pm,
            session,
            price: PriceState::new(true),
            heterogeneous,
            trace,
            next_iter: 0,
            infer,
            sim,
            busy_s: 0.0,
            parked_ticks: 0,
            idle_ticks: 0,
            tokens_processed: 0,
        })
    }

    /// Slice the fleet-wide fault view down to this tenant's lease,
    /// mirroring the simulator's `fault_view_for` semantics: with a
    /// non-empty timeline the session ALWAYS sees the (possibly
    /// all-clear) health mask; the returned view is `Some` only when a
    /// fault actually distorts this slice's pricing.
    fn local_fault_view(
        &mut self,
        fleet_cluster: &ClusterSpec,
        fleet_view: &Option<FaultView>,
        timeline_active: bool,
    ) -> Option<FaultView> {
        if !timeline_active {
            return None;
        }
        let devs = lease_devices(fleet_cluster, &self.lease);
        let (down, slowdown): (Vec<bool>, Vec<f64>) = match fleet_view {
            Some(v) => devs.iter().map(|&g| (v.down[g], v.slowdown[g])).unzip(),
            None => {
                self.session.set_device_health(&vec![false; devs.len()]);
                return None;
            }
        };
        self.session.set_device_health(&down);
        let distorted = down.iter().any(|&d| d)
            || slowdown
                .iter()
                .enumerate()
                .any(|(i, &s)| s != self.cluster.slowdown(i));
        if distorted {
            Some(FaultView { slowdown, down })
        } else {
            None
        }
    }

    /// Capture final policy counters into the embedded [`SimReport`].
    fn finalize_counters(&mut self) {
        let c = self.session.counters();
        self.sim.plans_run = c.plans_run;
        self.sim.plans_reused = c.plans_reused;
        self.sim.drift_replans = c.drift_replans;
    }
}

/// Fleet-level churn and backpressure counters.
#[derive(Clone, Debug, Default)]
pub struct FleetCounters {
    pub admitted: u64,
    pub deferred_admissions: u64,
    pub parked_ticks: u64,
    pub lease_grants: u64,
    pub lease_releases: u64,
    /// Nodes moved by the rebalancer (grow + shrink).
    pub lease_migrations: u64,
    /// Rebalance events that moved at least one node.
    pub rebalances: u64,
}

/// Per-tenant slice of a [`FleetReport`].
#[derive(Clone, Debug)]
pub struct JobReport {
    pub name: String,
    pub kind: JobKind,
    pub policy: String,
    pub admitted_tick: Option<usize>,
    pub completed_tick: Option<usize>,
    /// Lease size at completion (or end of run).
    pub lease_nodes: usize,
    pub iterations: usize,
    pub busy_s: f64,
    pub parked_ticks: usize,
    pub idle_ticks: usize,
    pub tokens_processed: u64,
    /// Simulator-shaped per-iteration results over the leased slice.
    pub sim: SimReport,
    // --- inference only --------------------------------------------------
    pub requests_arrived: u64,
    pub requests_completed: u64,
    pub queue_depth_end: usize,
    pub slo_attainment: f64,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
}

/// Whole-run fleet outcome: per-job reports plus cluster-level
/// utilization/churn.  Serializes deterministically ([`Self::to_json`],
/// schema [`FLEET_SCHEMA`]) — the byte-identity contract the property
/// suite and the CI smoke diff.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub ticks: usize,
    pub tick_s: f64,
    pub n_devices: usize,
    pub counters: FleetCounters,
    /// Sum over ticks of devices that priced work that tick.
    pub active_device_ticks: u64,
    pub jobs: Vec<JobReport>,
}

impl FleetReport {
    /// Fraction of device-ticks that did useful work.
    pub fn utilization(&self) -> f64 {
        if self.n_devices == 0 || self.ticks == 0 {
            return 0.0;
        }
        self.active_device_ticks as f64 / (self.n_devices * self.ticks) as f64
    }

    pub fn job(&self, name: &str) -> Option<&JobReport> {
        self.jobs.iter().find(|j| j.name == name)
    }

    pub fn to_json(&self) -> Json {
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                json::obj(vec![
                    ("name", json::s(&j.name)),
                    ("kind", json::s(j.kind.name())),
                    ("policy", json::s(&j.policy)),
                    (
                        "admitted_tick",
                        j.admitted_tick.map_or(Json::Null, |t| json::num(t as f64)),
                    ),
                    (
                        "completed_tick",
                        j.completed_tick.map_or(Json::Null, |t| json::num(t as f64)),
                    ),
                    ("lease_nodes", json::num(j.lease_nodes as f64)),
                    ("iterations", json::num(j.iterations as f64)),
                    ("busy_s", json::num(j.busy_s)),
                    ("parked_ticks", json::num(j.parked_ticks as f64)),
                    ("idle_ticks", json::num(j.idle_ticks as f64)),
                    ("tokens_processed", json::num(j.tokens_processed as f64)),
                    ("requests_arrived", json::num(j.requests_arrived as f64)),
                    ("requests_completed", json::num(j.requests_completed as f64)),
                    ("queue_depth_end", json::num(j.queue_depth_end as f64)),
                    ("slo_attainment", json::num(j.slo_attainment)),
                    ("mean_latency_s", json::num(j.mean_latency_s)),
                    ("max_latency_s", json::num(j.max_latency_s)),
                    ("sim", checkpoint::report_to_json(&j.sim)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema", json::s(FLEET_SCHEMA)),
            ("ticks", json::num(self.ticks as f64)),
            ("tick_s", json::num(self.tick_s)),
            ("n_devices", json::num(self.n_devices as f64)),
            ("utilization", json::num(self.utilization())),
            ("active_device_ticks", json::num(self.active_device_ticks as f64)),
            ("admitted", json::num(self.counters.admitted as f64)),
            (
                "deferred_admissions",
                json::num(self.counters.deferred_admissions as f64),
            ),
            ("parked_ticks", json::num(self.counters.parked_ticks as f64)),
            ("lease_grants", json::num(self.counters.lease_grants as f64)),
            ("lease_releases", json::num(self.counters.lease_releases as f64)),
            ("lease_migrations", json::num(self.counters.lease_migrations as f64)),
            ("rebalances", json::num(self.counters.rebalances as f64)),
            ("jobs", json::arr(jobs)),
        ])
    }
}

/// The fleet coordinator.  Construct with [`Fleet::new`], step to the
/// end with [`Fleet::run`] (or drive tick-by-tick via [`Fleet::step`]
/// + [`Fleet::into_report`] for tests).
pub struct Fleet<'a> {
    cfg: &'a FleetConfig,
    cluster: &'a ClusterSpec,
    popts: &'a ProphetOptions,
    faults: &'a FaultTimeline,
    rec: Arc<dyn Recorder>,
    book: LeaseBook,
    /// One slot per spec: `None` until admitted; kept after completion.
    runtimes: Vec<Option<JobRuntime>>,
    admitted: Vec<bool>,
    counters: FleetCounters,
    active_device_ticks: u64,
    tick: usize,
}

impl<'a> Fleet<'a> {
    pub fn new(
        cfg: &'a FleetConfig,
        cluster: &'a ClusterSpec,
        popts: &'a ProphetOptions,
        faults: &'a FaultTimeline,
        rec: Arc<dyn Recorder>,
    ) -> Result<Self, String> {
        cfg.validate(cluster)?;
        if !faults.is_empty() && faults.n_devices() != cluster.n_devices() {
            return Err(format!(
                "fault timeline is for {} devices, fleet cluster has {}",
                faults.n_devices(),
                cluster.n_devices()
            ));
        }
        Ok(Fleet {
            cfg,
            cluster,
            popts,
            faults,
            rec,
            book: LeaseBook::new(cluster.n_nodes),
            runtimes: cfg.jobs.iter().map(|_| None).collect(),
            admitted: vec![false; cfg.jobs.len()],
            counters: FleetCounters::default(),
            active_device_ticks: 0,
            tick: 0,
        })
    }

    /// Run the whole configured horizon and report.
    pub fn run(
        cfg: &FleetConfig,
        cluster: &ClusterSpec,
        popts: &ProphetOptions,
        faults: &FaultTimeline,
        rec: Arc<dyn Recorder>,
    ) -> Result<FleetReport, String> {
        let mut fleet = Fleet::new(cfg, cluster, popts, faults, rec)?;
        for _ in 0..cfg.ticks {
            fleet.step()?;
        }
        Ok(fleet.into_report())
    }

    /// Live leases as `(spec index, leased node ids)` pairs — the
    /// invariant surface integration tests assert over while stepping
    /// tick by tick (no node may appear under two jobs at once).
    pub fn leases(&self) -> Vec<(usize, Vec<usize>)> {
        (0..self.runtimes.len())
            .filter(|&i| self.running(i))
            .map(|i| (i, self.book.lease(i).to_vec()))
            .collect()
    }

    /// Number of [`Fleet::step`] calls completed so far.
    pub fn current_tick(&self) -> usize {
        self.tick
    }

    fn running(&self, i: usize) -> bool {
        self.runtimes[i]
            .as_ref()
            .is_some_and(|r| r.completed_tick.is_none())
    }

    fn running_count(&self) -> usize {
        (0..self.runtimes.len()).filter(|&i| self.running(i)).count()
    }

    /// Admit queued jobs that fit, in policy order.
    fn admit(&mut self) -> Result<(), String> {
        // Candidates: not yet admitted, start tick reached.  Queue
        // position = arrival order (start tick, then spec order) —
        // stable and deterministic.
        let mut eligible: Vec<usize> = (0..self.cfg.jobs.len())
            .filter(|&i| !self.admitted[i] && self.cfg.jobs[i].start <= self.tick)
            .collect();
        eligible.sort_by_key(|&i| (self.cfg.jobs[i].start, i));
        let candidates: Vec<(usize, usize)> = eligible
            .iter()
            .enumerate()
            .map(|(pos, &i)| (pos, self.cfg.jobs[i].nodes))
            .collect();
        let spec_of = eligible;
        for pos in self.cfg.admission.order(&candidates) {
            let spec_idx = spec_of[pos];
            let spec = &self.cfg.jobs[spec_idx];
            let fits = self.running_count() < self.cfg.max_concurrent
                && self.book.free_nodes() >= spec.nodes;
            if !fits {
                self.counters.deferred_admissions += 1;
                if self.rec.enabled() {
                    self.rec.counter("fleet.deferred", Labels::None, 1);
                }
                if self.cfg.admission.head_of_line_blocking() {
                    break;
                }
                continue;
            }
            let lease = self
                .book
                .grant(spec_idx, spec.nodes)
                .expect("free_nodes >= nodes was just checked");
            self.counters.lease_grants += 1;
            self.counters.admitted += 1;
            let rt = JobRuntime::new(
                spec_idx,
                spec,
                self.cluster,
                lease,
                self.popts,
                self.rec.clone(),
                self.tick,
            )?;
            if self.rec.enabled() {
                self.rec.counter("fleet.admitted", Labels::None, 1);
                self.rec.gauge(
                    "fleet.job_lease_nodes",
                    Labels::one("job", spec_idx as i64),
                    rt.lease.len() as f64,
                );
            }
            self.runtimes[spec_idx] = Some(rt);
            self.admitted[spec_idx] = true;
        }
        debug_assert!(self.book.validate().is_ok());
        Ok(())
    }

    /// Resize inference leases toward demand: shrink the idle, grow the
    /// overloaded, at most `migration_budget` nodes moved per event, in
    /// a deterministic (pressure, spec-order) order.
    fn rebalance(&mut self) -> Result<(), String> {
        let mut budget = self.cfg.migration_budget;
        if budget == 0 {
            return Ok(());
        }
        // (spec idx, pressure) of running inference tenants.
        let mut infer: Vec<(usize, f64)> = Vec::new();
        for i in 0..self.runtimes.len() {
            if !self.running(i) {
                continue;
            }
            let rt = self.runtimes[i].as_ref().expect("running implies Some");
            if let Some(state) = &rt.infer {
                infer.push((i, state.pressure()));
            }
        }
        let mut moved = 0u64;
        // Shrink phase first, so freed nodes are available to growers in
        // the same event.  Lowest pressure first; ties by spec order.
        let mut shrinkers: Vec<usize> = infer
            .iter()
            .filter(|&&(i, p)| {
                p < SHRINK_PRESSURE
                    && self.runtimes[i].as_ref().expect("running").lease.len()
                        > self.cfg.jobs[i].min_nodes
            })
            .map(|&(i, _)| i)
            .collect();
        shrinkers.sort_by(|&a, &b| {
            let (pa, pb) = (infer.iter().find(|x| x.0 == a).expect("member").1,
                            infer.iter().find(|x| x.0 == b).expect("member").1);
            pa.partial_cmp(&pb).expect("pressure is finite").then(a.cmp(&b))
        });
        for i in shrinkers {
            if budget == 0 {
                break;
            }
            if self.book.shrink(i, 1) == 1 {
                budget -= 1;
                moved += 1;
                self.resize_job(i)?;
            }
        }
        // Grow phase: highest pressure first; ties by spec order.
        let mut growers: Vec<usize> = infer
            .iter()
            .filter(|&&(i, p)| {
                p > GROW_PRESSURE
                    && self.runtimes[i].as_ref().expect("running").lease.len()
                        < self.cfg.jobs[i].max_nodes
            })
            .map(|&(i, _)| i)
            .collect();
        growers.sort_by(|&a, &b| {
            let (pa, pb) = (infer.iter().find(|x| x.0 == a).expect("member").1,
                            infer.iter().find(|x| x.0 == b).expect("member").1);
            pb.partial_cmp(&pa).expect("pressure is finite").then(a.cmp(&b))
        });
        for i in growers {
            if budget == 0 {
                break;
            }
            if self.book.grow(i, 1) == 1 {
                budget -= 1;
                moved += 1;
                self.resize_job(i)?;
            }
        }
        if moved > 0 {
            self.counters.lease_migrations += moved;
            self.counters.rebalances += 1;
            if self.rec.enabled() {
                self.rec.counter("lease.migrations", Labels::None, moved);
                self.rec.counter("lease.rebalances", Labels::None, 1);
            }
        }
        debug_assert!(self.book.validate().is_ok());
        Ok(())
    }

    /// Rebuild a resized tenant's pricing stack over its new lease.
    /// Queue, arrivals and latency accounting carry over; the session
    /// and expert popularity are re-derived for the new width (expert
    /// count tracks device count), seeded deterministically.
    fn resize_job(&mut self, i: usize) -> Result<(), String> {
        let spec = &self.cfg.jobs[i];
        let lease = self.book.lease(i).to_vec();
        let rt = self.runtimes[i].as_mut().expect("resize of a running job");
        rt.lease = lease;
        rt.cluster = sub_cluster(self.cluster, &rt.lease);
        let d = rt.cluster.n_devices();
        rt.model = ModelSpec::by_name(&spec.model, d, spec.k, spec.tokens)
            .ok_or_else(|| format!("job `{}`: unknown model `{}`", spec.name, spec.model))?;
        rt.pm = PerfModel::new(&rt.model, &rt.cluster);
        rt.heterogeneous = rt.cluster.is_heterogeneous();
        let policy = crate::balancer::registry::build(&spec.policy, self.popts)
            .ok_or_else(|| format!("job `{}`: unknown policy `{}`", spec.name, spec.policy))?;
        rt.session = BalancerSession::with_recorder(policy, 1, self.rec.clone());
        rt.price.reset();
        if let Some(state) = &mut rt.infer {
            state.reseed_popularity(d);
        }
        if self.rec.enabled() {
            self.rec.gauge(
                "fleet.job_lease_nodes",
                Labels::one("job", i as i64),
                rt.lease.len() as f64,
            );
        }
        Ok(())
    }

    /// Advance the fleet by one tick: admit, step every tenant under the
    /// tick's fault view, then (on the interval) rebalance leases.
    pub fn step(&mut self) -> Result<(), String> {
        let tick = self.tick;
        self.rec.iteration_start(tick);
        self.admit()?;

        let timeline_active = !self.faults.is_empty();
        let fleet_view = if timeline_active {
            self.faults.effective(tick, self.cluster)
        } else {
            None
        };

        let mut active_this_tick = 0u64;
        for i in 0..self.runtimes.len() {
            if !self.running(i) {
                continue;
            }
            let rec = self.rec.clone();
            let rt = self.runtimes[i].as_mut().expect("running implies Some");
            let view = rt.local_fault_view(self.cluster, &fleet_view, timeline_active);
            let all_down = view.as_ref().is_some_and(FaultView::all_down);

            // Inference traffic keeps arriving whatever the slice's
            // health — that is what makes parking/degradation visible in
            // the queue and the SLO numbers.
            let mut batch = Vec::new();
            if let Some(state) = &mut rt.infer {
                let n = state.arrive(tick);
                if rec.enabled() {
                    if n > 0 {
                        rec.counter("fleet.requests_arrived", Labels::None, n);
                    }
                    rec.gauge(
                        "fleet.job_queue",
                        Labels::one("job", i as i64),
                        state.queue_depth() as f64,
                    );
                }
                if !all_down {
                    batch = state.take_batch();
                }
            }

            if all_down {
                // Every device in the slice is down: nothing can run.
                // Park the tenant for the tick — degradation, not error
                // (satellite: all-down fail_over is a typed refusal).
                rt.parked_ticks += 1;
                self.counters.parked_ticks += 1;
                if rec.enabled() {
                    rec.counter("fleet.parked", Labels::None, 1);
                }
                continue;
            }

            let stepped = match rt.trace.as_ref() {
                // --- training tenant: one trace iteration per tick ----
                Some(trace) => {
                    let layers = &trace.iterations[rt.next_iter];
                    let eng = Engine::new(&rt.cluster, &rt.pm);
                    let it = price_and_observe(
                        &eng,
                        rt.heterogeneous,
                        &mut rt.session,
                        &view,
                        layers,
                        &*rec,
                        &mut rt.price,
                    );
                    rt.busy_s += it.time;
                    rt.tokens_processed += layers.iter().map(LoadMatrix::total_tokens).sum::<u64>()
                        / trace.n_layers.max(1) as u64;
                    if rec.enabled() {
                        rec.gauge("fleet.job_iter_time_s", Labels::one("job", i as i64), it.time);
                    }
                    rt.sim.iters.push(it);
                    rt.next_iter += 1;
                    if rt.next_iter >= trace.len() {
                        rt.completed_tick = Some(tick);
                        rt.finalize_counters();
                        let released = self.book.release(i);
                        self.counters.lease_releases += u64::from(released > 0);
                        if rec.enabled() {
                            rec.counter("fleet.completed", Labels::None, 1);
                        }
                    }
                    true
                }
                // --- inference tenant: price the drained batch --------
                None => {
                    if batch.is_empty() {
                        rt.idle_ticks += 1;
                        false
                    } else {
                        let state = rt.infer.as_mut().expect("infer job has state");
                        let w = state.batch_matrix(&batch, rt.cluster.n_devices());
                        let layers = [w];
                        let eng = Engine::new(&rt.cluster, &rt.pm);
                        let it = price_and_observe(
                            &eng,
                            rt.heterogeneous,
                            &mut rt.session,
                            &view,
                            &layers,
                            &*rec,
                            &mut rt.price,
                        );
                        let state = rt.infer.as_mut().expect("infer job has state");
                        state.complete_batch(&batch, tick, self.cfg.tick_s, it.time);
                        rt.busy_s += it.time;
                        rt.tokens_processed += layers[0].total_tokens();
                        if rec.enabled() {
                            rec.counter(
                                "fleet.requests_completed",
                                Labels::None,
                                batch.len() as u64,
                            );
                            rec.gauge(
                                "fleet.job_iter_time_s",
                                Labels::one("job", i as i64),
                                it.time,
                            );
                            let state = rt.infer.as_ref().expect("infer job has state");
                            rec.gauge(
                                "fleet.job_slo_attainment",
                                Labels::one("job", i as i64),
                                state.slo_attainment(),
                            );
                            rec.gauge(
                                "fleet.job_mean_latency_s",
                                Labels::one("job", i as i64),
                                state.mean_latency_s(),
                            );
                        }
                        rt.sim.iters.push(it);
                        true
                    }
                }
            };
            if stepped {
                let rt = self.runtimes[i].as_ref().expect("still Some");
                active_this_tick += rt.cluster.n_devices() as u64;
            }
        }
        self.active_device_ticks += active_this_tick;
        if self.rec.enabled() {
            self.rec.gauge(
                "fleet.utilization",
                Labels::None,
                active_this_tick as f64 / self.cluster.n_devices().max(1) as f64,
            );
        }

        if self.cfg.rebalance_interval > 0
            && tick > 0
            && tick % self.cfg.rebalance_interval == 0
        {
            self.rebalance()?;
        }

        self.rec.iteration_end();
        self.tick += 1;
        Ok(())
    }

    /// Consume the fleet into its report (finalizing still-running
    /// tenants' policy counters).
    pub fn into_report(mut self) -> FleetReport {
        let mut jobs = Vec::with_capacity(self.cfg.jobs.len());
        for (i, spec) in self.cfg.jobs.iter().enumerate() {
            let job = match self.runtimes[i].as_mut() {
                None => JobReport {
                    name: spec.name.clone(),
                    kind: spec.kind,
                    policy: spec.policy.clone(),
                    admitted_tick: None,
                    completed_tick: None,
                    lease_nodes: 0,
                    iterations: 0,
                    busy_s: 0.0,
                    parked_ticks: 0,
                    idle_ticks: 0,
                    tokens_processed: 0,
                    sim: SimReport::default(),
                    requests_arrived: 0,
                    requests_completed: 0,
                    queue_depth_end: 0,
                    slo_attainment: 1.0,
                    mean_latency_s: 0.0,
                    max_latency_s: 0.0,
                },
                Some(rt) => {
                    if rt.completed_tick.is_none() {
                        rt.finalize_counters();
                    }
                    let (arrived, completed, depth, slo, mean_l, max_l) = match &rt.infer {
                        Some(s) => (
                            s.requests_arrived,
                            s.requests_completed,
                            s.queue_depth(),
                            s.slo_attainment(),
                            s.mean_latency_s(),
                            s.latency_max_s,
                        ),
                        None => (0, 0, 0, 1.0, 0.0, 0.0),
                    };
                    JobReport {
                        name: spec.name.clone(),
                        kind: spec.kind,
                        policy: rt.sim.policy.clone(),
                        admitted_tick: Some(rt.admitted_tick),
                        completed_tick: rt.completed_tick,
                        lease_nodes: rt.lease.len(),
                        iterations: rt.sim.iters.len(),
                        busy_s: rt.busy_s,
                        parked_ticks: rt.parked_ticks,
                        idle_ticks: rt.idle_ticks,
                        tokens_processed: rt.tokens_processed,
                        sim: rt.sim.clone(),
                        requests_arrived: arrived,
                        requests_completed: completed,
                        queue_depth_end: depth,
                        slo_attainment: slo,
                        mean_latency_s: mean_l,
                        max_latency_s: max_l,
                    }
                }
            };
            jobs.push(job);
        }
        FleetReport {
            ticks: self.tick,
            tick_s: self.cfg.tick_s,
            n_devices: self.cluster.n_devices(),
            counters: self.counters,
            active_device_ticks: self.active_device_ticks,
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    fn train_spec(name: &str, nodes: usize, iters: usize, start: usize) -> String {
        format!("train name={name} nodes={nodes} model=s tokens=8192 iters={iters} start={start} seed=11 policy=deepspeed")
    }

    fn cfg_of(jobs: Vec<String>, ticks: usize) -> FleetConfig {
        FleetConfig {
            ticks,
            tick_s: 0.25,
            max_concurrent: 4,
            admission: AdmissionPolicy::Fifo,
            rebalance_interval: 4,
            migration_budget: 1,
            jobs: jobs.iter().map(|s| JobSpec::parse(s).unwrap()).collect(),
        }
    }

    fn run_fleet(cfg: &FleetConfig, cluster: &ClusterSpec) -> FleetReport {
        Fleet::run(
            cfg,
            cluster,
            &ProphetOptions::default(),
            &FaultTimeline::empty(),
            obs::noop_arc(),
        )
        .unwrap()
    }

    #[test]
    fn job_spec_parses_and_defaults() {
        let j = JobSpec::parse("train name=a nodes=2 model=m iters=8 seed=3").unwrap();
        assert_eq!(j.kind, JobKind::Train);
        assert_eq!((j.nodes, j.min_nodes, j.max_nodes), (2, 2, 2));
        assert_eq!(j.policy, "pro-prophet");
        let j = JobSpec::parse(
            "infer name=s nodes=1 max_nodes=3 rate=2.5 burst_on=3 burst_off=5 burst_factor=4",
        )
        .unwrap();
        assert_eq!(j.kind, JobKind::Infer);
        assert_eq!((j.min_nodes, j.max_nodes), (1, 3));
        assert!(matches!(j.arrival_process(), ArrivalProcess::OnOffBursty { .. }));
        let plain = JobSpec::parse("infer name=p nodes=1 rate=1.5").unwrap();
        assert!(matches!(plain.arrival_process(), ArrivalProcess::Poisson { rate } if rate == 1.5));
        assert!(JobSpec::parse("sleep name=z").is_err());
        assert!(JobSpec::parse("train name=z warp=9").is_err());
        assert!(JobSpec::parse("train name=z nodes=x").is_err());
    }

    #[test]
    fn fleet_config_from_table_and_validation() {
        let t = toml::parse(
            "[fleet]\nticks = 10\njobs = [\"train name=a nodes=1 iters=4\", \"infer name=b nodes=1 rate=1\"]",
        )
        .unwrap();
        let cluster = ClusterSpec::hpwnv(2);
        let cfg = FleetConfig::from_table(&t, &cluster).unwrap().unwrap();
        assert_eq!(cfg.ticks, 10);
        assert_eq!(cfg.jobs.len(), 2);
        assert_eq!(cfg.admission, AdmissionPolicy::Fifo);
        // No [fleet] table at all -> None, not an error.
        let none = FleetConfig::from_table(&toml::parse("iterations = 5").unwrap(), &cluster);
        assert!(none.unwrap().is_none());
        // Oversized ask, duplicate names, unknown admission are rejected.
        let bad = toml::parse("[fleet]\njobs = [\"train name=a nodes=9 iters=1\"]").unwrap();
        assert!(FleetConfig::from_table(&bad, &cluster).is_err());
        let dup = toml::parse(
            "[fleet]\njobs = [\"train name=a nodes=1 iters=1\", \"train name=a nodes=1 iters=1\"]",
        )
        .unwrap();
        assert!(FleetConfig::from_table(&dup, &cluster)
            .unwrap_err()
            .contains("duplicate"));
        let badp = toml::parse(
            "[fleet]\nadmission = \"bribery\"\njobs = [\"train name=a nodes=1 iters=1\"]",
        )
        .unwrap();
        assert!(FleetConfig::from_table(&badp, &cluster).is_err());
    }

    #[test]
    fn single_train_job_runs_to_completion() {
        let cluster = ClusterSpec::hpwnv(2);
        let cfg = cfg_of(vec![train_spec("solo", 2, 4, 0)], 8);
        let r = run_fleet(&cfg, &cluster);
        let j = r.job("solo").unwrap();
        assert_eq!(j.admitted_tick, Some(0));
        assert_eq!(j.completed_tick, Some(3), "4 iterations, one per tick");
        assert_eq!(j.iterations, 4);
        assert!(j.busy_s > 0.0);
        assert_eq!(j.sim.iters.len(), 4);
        assert_eq!(r.counters.admitted, 1);
        assert_eq!(r.counters.lease_grants, 1);
        assert_eq!(r.counters.lease_releases, 1);
        // 2 nodes * 4 gpus * 4 active ticks.
        assert_eq!(r.active_device_ticks, 32);
        assert!(r.utilization() > 0.0 && r.utilization() <= 1.0);
    }

    #[test]
    fn admission_defers_until_nodes_free() {
        // Two 2-node jobs on a 2-node cluster: the second waits for the
        // first to finish, deferrals are counted, both complete.
        let cluster = ClusterSpec::hpwnv(2);
        let cfg = cfg_of(
            vec![train_spec("first", 2, 3, 0), train_spec("second", 2, 3, 0)],
            12,
        );
        let r = run_fleet(&cfg, &cluster);
        let a = r.job("first").unwrap();
        let b = r.job("second").unwrap();
        assert_eq!(a.admitted_tick, Some(0));
        assert_eq!(a.completed_tick, Some(2));
        assert_eq!(
            b.admitted_tick,
            Some(3),
            "second admits the tick after the lease frees"
        );
        assert_eq!(b.completed_tick, Some(5));
        assert!(r.counters.deferred_admissions >= 3);
        assert_eq!(r.counters.admitted, 2);
    }

    #[test]
    fn max_concurrent_caps_admission() {
        let cluster = ClusterSpec::hpwnv(2);
        let mut cfg = cfg_of(
            vec![train_spec("a", 1, 2, 0), train_spec("b", 1, 2, 0)],
            8,
        );
        cfg.max_concurrent = 1;
        let r = run_fleet(&cfg, &cluster);
        let a = r.job("a").unwrap();
        let b = r.job("b").unwrap();
        assert_eq!(a.admitted_tick, Some(0));
        assert!(b.admitted_tick.unwrap() > a.completed_tick.unwrap());
    }

    #[test]
    fn inference_job_serves_and_reports_slo() {
        let cluster = ClusterSpec::hpwnv(1);
        let cfg = cfg_of(
            vec!["infer name=serve nodes=1 rate=3 tokens_per_req=64 batch_tokens=1024 slo_ms=2000 seed=5 policy=deepspeed".into()],
            16,
        );
        let r = run_fleet(&cfg, &cluster);
        let j = r.job("serve").unwrap();
        assert_eq!(j.kind, JobKind::Infer);
        assert!(j.requests_arrived > 0);
        assert!(j.requests_completed > 0);
        assert!(j.requests_completed <= j.requests_arrived);
        assert!(j.slo_attainment >= 0.0 && j.slo_attainment <= 1.0);
        assert!(j.mean_latency_s >= 0.0);
        assert!(j.max_latency_s >= j.mean_latency_s);
        assert!(j.iterations > 0);
        assert_eq!(j.completed_tick, None, "inference tenants run forever");
    }

    #[test]
    fn rebalancer_grows_a_pressured_tenant() {
        // A bursty tenant allowed up to 2 nodes on a 2-node cluster with
        // heavy traffic: pressure builds, the rebalancer grants the free
        // node, churn counters record it.
        let cluster = ClusterSpec::hpwnv(2);
        let mut cfg = cfg_of(
            vec!["infer name=hot nodes=1 max_nodes=2 rate=60 tokens_per_req=256 batch_tokens=512 slo_ms=100 seed=5 policy=deepspeed".into()],
            12,
        );
        cfg.rebalance_interval = 2;
        let r = run_fleet(&cfg, &cluster);
        let j = r.job("hot").unwrap();
        assert_eq!(j.lease_nodes, 2, "demand must grow the lease");
        assert!(r.counters.lease_migrations >= 1);
        assert!(r.counters.rebalances >= 1);
    }

    #[test]
    fn same_seed_reports_are_byte_identical() {
        let cluster = ClusterSpec::hpwnv(2);
        let cfg = cfg_of(
            vec![
                train_spec("t", 1, 5, 0),
                "infer name=s nodes=1 rate=4 burst_on=3 burst_off=3 burst_factor=3 seed=9 policy=deepspeed"
                    .into(),
            ],
            10,
        );
        let a = run_fleet(&cfg, &cluster).to_json().to_string();
        let b = run_fleet(&cfg, &cluster).to_json().to_string();
        assert_eq!(a, b);
        assert!(a.contains(FLEET_SCHEMA));
    }

    #[test]
    fn fleet_wide_fault_parks_and_recovers() {
        // Down both devices-bearing nodes' GPUs for a window: the tenant
        // parks (no crash), then resumes and completes after recovery.
        let cluster = ClusterSpec::hpwnv(1);
        let specs: Vec<String> = (0..4).map(|d| format!("down dev={d} start=2")).collect();
        let mut all: Vec<String> = specs;
        all.extend((0..4).map(|d| format!("recover dev={d} start=4")));
        let faults = FaultTimeline::parse_specs(
            &all.iter().map(String::as_str).collect::<Vec<_>>(),
            4,
        )
        .unwrap();
        let cfg = cfg_of(vec![train_spec("t", 1, 4, 0)], 10);
        let r = Fleet::run(
            &cfg,
            &cluster,
            &ProphetOptions::default(),
            &faults,
            obs::noop_arc(),
        )
        .unwrap();
        let j = r.job("t").unwrap();
        assert_eq!(j.parked_ticks, 2, "ticks 2 and 3 are all-down");
        assert_eq!(j.iterations, 4, "the job still completes after recovery");
        assert_eq!(j.completed_tick, Some(5), "2 parked ticks push completion from 3 to 5");
        assert_eq!(r.counters.parked_ticks, 2);
    }

    #[test]
    fn partial_fault_degrades_only_the_leasing_tenant() {
        // Two 1-node tenants; device 5 (node 1) slowed 8x for a window.
        // Only the tenant leasing node 1 sees DES-priced (distorted)
        // iterations there; the node-0 tenant is untouched bit-for-bit.
        let cluster = ClusterSpec::hpwnv(2);
        let faults = FaultTimeline::parse_specs(
            &["transient dev=5 factor=8 start=1 dur=2"],
            8,
        )
        .unwrap();
        let cfg = cfg_of(
            vec![train_spec("a", 1, 4, 0), train_spec("b", 1, 4, 0)],
            8,
        );
        let faulted = Fleet::run(
            &cfg,
            &cluster,
            &ProphetOptions::default(),
            &faults,
            obs::noop_arc(),
        )
        .unwrap();
        let clean = run_fleet(&cfg, &cluster);
        // Tenant a (nodes granted lowest-first -> node 0) is unaffected.
        let (fa, ca) = (faulted.job("a").unwrap(), clean.job("a").unwrap());
        for (x, y) in fa.sim.iters.iter().zip(&ca.sim.iters) {
            assert_eq!(x.time.to_bits(), y.time.to_bits());
        }
        // Tenant b leases node 1 (global devs 4..8); local dev 1 slows.
        let (fb, cb) = (faulted.job("b").unwrap(), clean.job("b").unwrap());
        assert!(fb.sim.iters[1].time > cb.sim.iters[1].time);
        assert_eq!(fb.sim.iters[1].straggler, 1, "global dev 5 is local dev 1");
        assert_eq!(
            fb.sim.iters[0].time.to_bits(),
            cb.sim.iters[0].time.to_bits(),
            "outside the window tenant b is clean too"
        );
    }
}
