//! Locality of input distributions (paper §II "Locality", Fig 4):
//! adjacent iterations route tokens almost identically.
//!
//! This module holds the locality *metrics* (similarity / correlation)
//! that Fig 4 and the drift detector quantify.  Forecasting itself lives
//! in [`crate::prophet`]: the old `LocalityPredictor` EMA was absorbed
//! into `prophet::predictors::Ema`, one member of the predictor family
//! the online ensemble selects from.

use crate::util::stats;

/// Similarity of two distributions in [0, 1]: 1 − normalized L1 distance.
/// This is the quantity Fig 4 visualizes across adjacent iterations
/// (integer-count façade over [`crate::metrics::similarity_f64`], the
/// repo's single similarity core).
pub fn similarity(a: &[u64], b: &[u64]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    crate::metrics::similarity_f64(&af, &bf)
}

/// Pearson correlation between adjacent distributions (alternative
/// locality metric used in reports).
pub fn correlation(a: &[u64], b: &[u64]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    stats::pearson(&af, &bf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn similarity_identical_is_one() {
        assert!((similarity(&[5, 3, 2], &[5, 3, 2]) - 1.0).abs() < 1e-12);
        // Scale invariance (distributions are normalized).
        assert!((similarity(&[5, 3, 2], &[10, 6, 4]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn similarity_disjoint_is_zero() {
        assert!(similarity(&[10, 0], &[0, 10]) < 1e-12);
    }

    #[test]
    fn similarity_empty_edge() {
        assert_eq!(similarity(&[0, 0], &[0, 0]), 1.0);
        assert_eq!(similarity(&[1, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn correlation_of_similar_distributions_high() {
        let a = [500, 300, 120, 80];
        let b = [510, 290, 115, 85];
        assert!(correlation(&a, &b) > 0.99);
    }
}
