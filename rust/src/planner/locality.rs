//! Locality of input distributions (paper §II "Locality", Fig 4).
//!
//! Adjacent iterations route tokens almost identically; the predictor
//! exploits that to (a) forecast the next iteration's distribution so the
//! Plan primitive can run one iteration early (§V-A), and (b) quantify
//! locality for Fig 4 and the replan-frequency policy.

use crate::util::stats;

/// Exponential-moving-average distribution predictor.
#[derive(Clone, Debug)]
pub struct LocalityPredictor {
    ema: Option<Vec<f64>>,
    last: Option<Vec<f64>>,
    /// EMA smoothing: 1.0 = "predict last observed" (pure locality).
    pub beta: f64,
    pub observations: usize,
}

impl LocalityPredictor {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..=1.0).contains(&beta));
        LocalityPredictor { ema: None, last: None, beta, observations: 0 }
    }

    /// Feed the observed distribution of the current iteration.
    pub fn observe(&mut self, dist: &[u64]) {
        let xs: Vec<f64> = dist.iter().map(|&x| x as f64).collect();
        self.ema = Some(match self.ema.take() {
            None => xs.clone(),
            Some(prev) => prev
                .iter()
                .zip(&xs)
                .map(|(p, x)| (1.0 - self.beta) * p + self.beta * x)
                .collect(),
        });
        self.last = Some(xs);
        self.observations += 1;
    }

    /// Predicted distribution for the NEXT iteration (None until the first
    /// observation).
    pub fn predict(&self) -> Option<&[f64]> {
        self.ema.as_deref()
    }

    /// Prediction error of the latest observation vs what we would have
    /// predicted before it (mean absolute percentage, 0 = perfect).
    pub fn last_error(&self) -> Option<f64> {
        match (&self.ema, &self.last) {
            (Some(_), Some(_last)) if self.observations >= 2 => {
                // ema already ingested `last`; reconstruct prior prediction.
                None // reconstructing is ambiguous; use `similarity` instead
            }
            _ => None,
        }
    }
}

/// Similarity of two distributions in [0, 1]: 1 − normalized L1 distance.
/// This is the quantity Fig 4 visualizes across adjacent iterations.
pub fn similarity(a: &[u64], b: &[u64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ta: f64 = a.iter().map(|&x| x as f64).sum();
    let tb: f64 = b.iter().map(|&x| x as f64).sum();
    if ta == 0.0 || tb == 0.0 {
        return if ta == tb { 1.0 } else { 0.0 };
    }
    let l1: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 / ta - y as f64 / tb).abs())
        .sum();
    1.0 - 0.5 * l1
}

/// Pearson correlation between adjacent distributions (alternative
/// locality metric used in reports).
pub fn correlation(a: &[u64], b: &[u64]) -> f64 {
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    stats::pearson(&af, &bf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn similarity_identical_is_one() {
        assert!((similarity(&[5, 3, 2], &[5, 3, 2]) - 1.0).abs() < 1e-12);
        // Scale invariance (distributions are normalized).
        assert!((similarity(&[5, 3, 2], &[10, 6, 4]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn similarity_disjoint_is_zero() {
        assert!(similarity(&[10, 0], &[0, 10]) < 1e-12);
    }

    #[test]
    fn similarity_empty_edge() {
        assert_eq!(similarity(&[0, 0], &[0, 0]), 1.0);
        assert_eq!(similarity(&[1, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn predictor_beta_one_tracks_last() {
        let mut p = LocalityPredictor::new(1.0);
        p.observe(&[10, 20, 30]);
        p.observe(&[40, 50, 60]);
        assert_eq!(p.predict().unwrap(), &[40.0, 50.0, 60.0]);
    }

    #[test]
    fn predictor_smooths() {
        let mut p = LocalityPredictor::new(0.5);
        p.observe(&[100, 0]);
        p.observe(&[0, 100]);
        let pred = p.predict().unwrap();
        assert!((pred[0] - 50.0).abs() < 1e-9);
        assert!((pred[1] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn predictor_none_before_observation() {
        let p = LocalityPredictor::new(0.9);
        assert!(p.predict().is_none());
    }

    #[test]
    fn correlation_of_similar_distributions_high() {
        let a = [500, 300, 120, 80];
        let b = [510, 290, 115, 85];
        assert!(correlation(&a, &b) > 0.99);
    }
}
