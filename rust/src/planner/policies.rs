//! Baseline placement policies the paper compares the planner against.
//!
//! * [`top_k_to_all`] — the "top2"/"top3" simple dynamic policies of the
//!   ablation (Fig 15): replicate the k heaviest experts to every device.
//! * [`fastermoe_shadowing`] — FasterMoE's dynamic shadowing: replicate an
//!   expert globally while its load exceeds the break-even point of the
//!   shadowing cost model (He et al., PPoPP'22), coarse-grained and
//!   evaluated on the whole-cluster average load.

use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;

/// Replicate the `k` heaviest experts onto all devices (Fig 15 policies).
pub fn top_k_to_all(w: &LoadMatrix, k: usize) -> Placement {
    let mut order: Vec<usize> = (0..w.n_experts()).collect();
    order.sort_by_key(|&e| std::cmp::Reverse(w.expert_load(e)));
    let mut p = Placement::identity(w.n_experts(), w.n_devices());
    for &e in order.iter().take(k) {
        p.replicate_to_all(e);
    }
    p
}

/// FasterMoE-style dynamic shadowing.
///
/// Experts are considered in descending load order; expert `e` is
/// "shadowed" (replicated to all devices) while doing so still reduces the
/// modeled makespan: shadowing trades `load_e`'s A2A + centralized compute
/// for a broadcast of its parameters and an even spread of its compute.
/// Unlike Pro-Prophet, the transfer always targets ALL devices and the
/// decision ignores per-device token origins — the coarseness the paper's
/// §VI-A attributes FasterMoE's extra runtime overhead to.
pub fn fastermoe_shadowing(w: &LoadMatrix, pm: &PerfModel) -> Placement {
    let mut order: Vec<usize> = (0..w.n_experts()).collect();
    order.sort_by_key(|&e| std::cmp::Reverse(w.expert_load(e)));

    let mut p = Placement::identity(w.n_experts(), w.n_devices());
    let mut t_best = fastermoe_cost(w, pm, &p, 0);
    let mut shadowed = 0usize;
    for &e in &order {
        if w.expert_load(e) == 0 {
            break;
        }
        let mut cand = p.clone();
        cand.replicate_to_all(e);
        let t_cand = fastermoe_cost(w, pm, &cand, shadowed + 1);
        if t_cand < t_best {
            p = cand;
            t_best = t_cand;
            shadowed += 1;
        } else {
            break; // loads are sorted: no lighter expert will help either
        }
    }
    p
}

/// FasterMoE's own cost view: balanced compute after shadowing, but the
/// parameter/gradient movement is a coarse blocking broadcast to ALL
/// devices (params forward + grads backward).
fn fastermoe_cost(w: &LoadMatrix, pm: &PerfModel, p: &Placement, shadowed: usize) -> f64 {
    let routed = w.route(p);
    4.0 * pm.t_a2a(&routed.r) + 3.0 * pm.t_fec(&routed.h)
        + 2.0 * pm.t_trans_coarse(shadowed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;

    fn skew4() -> LoadMatrix {
        LoadMatrix::from_rows(vec![
            vec![700, 150, 100, 74],
            vec![720, 140, 90, 74],
            vec![710, 160, 80, 74],
            vec![690, 150, 110, 74],
        ])
    }

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &ClusterSpec::hpwnv(1))
    }

    #[test]
    fn top_k_selects_heaviest() {
        let p = top_k_to_all(&skew4(), 2);
        // Experts 0 and 1 are the heaviest.
        assert_eq!(p.replicas(0).len(), 4);
        assert_eq!(p.replicas(1).len(), 4);
        assert_eq!(p.replicas(2).len(), 1);
        assert_eq!(p.transferred_experts(), vec![0, 1]);
    }

    #[test]
    fn top_zero_is_identity() {
        assert!(top_k_to_all(&skew4(), 0).is_identity());
    }

    #[test]
    fn shadowing_improves_skewed_load() {
        let w = skew4();
        let pm = pm();
        let p = fastermoe_shadowing(&w, &pm);
        let ident = Placement::identity(4, 4);
        let t_shadow = pm.layer_time_blocking(&w.route(&p), &p);
        let t_ident = pm.layer_time_blocking(&w.route(&ident), &ident);
        assert!(t_shadow <= t_ident);
        // The dominant expert must be shadowed.
        assert_eq!(p.replicas(0).len(), 4);
    }

    #[test]
    fn shadowing_leaves_balanced_load_alone() {
        let w = LoadMatrix::from_rows(vec![vec![256; 4]; 4]);
        let p = fastermoe_shadowing(&w, &pm());
        assert!(p.is_identity());
    }

    #[test]
    fn shadowing_is_all_or_nothing_per_expert() {
        let p = fastermoe_shadowing(&skew4(), &pm());
        for e in p.transferred_experts() {
            assert_eq!(
                p.replicas(e).len(),
                4,
                "FasterMoE shadowing always broadcasts to every device"
            );
        }
    }
}
