//! Algorithm 1: the locality-based greedy search for a
//! communication-efficient lightweight expert placement.
//!
//! The search space has 2^(D·E) placements; the greedy strategy instead
//! (paper §IV-C):
//!
//! 1. estimates the layer time without any placement (`T_output`);
//! 2. repeatedly picks the heaviest not-yet-selected expert and replicates
//!    it to every device except the `n` holding the fewest of its inputs
//!    (BottomK);
//! 3. re-routes, re-estimates with the performance model, and remembers
//!    the best prefix (`cnt`);
//! 4. stops when the load satisfies the Eq 7 balance condition, or when
//!    the heaviest device repeats (`Used` check), or when every expert has
//!    been selected;
//! 5. returns the placement built from the best prefix `L[0..cnt]`.
//!
//! The search must be cheap enough to run online, off the critical path
//! (paper Table I "Search": low milliseconds).  Candidate evaluation
//! therefore runs on the incremental router ([`RoutingState`]): each
//! selection applies an O(D) delta and replays a pre-sorted batch list
//! instead of re-routing the whole O(D·E) matrix, and all scratch lives
//! in a reusable [`SearchScratch`] so the steady-state search is
//! allocation-free.  [`greedy_search_reference`] preserves the original
//! full-re-route implementation; `prop_greedy_matches_reference` gates
//! the two on bit-identical results, and `bench_plan_cost` measures the
//! gap (BENCH_plan.json / EXPERIMENTS.md §Perf).

use super::PlannerConfig;
use crate::moe::{LoadMatrix, Placement, RoutingState};
use crate::perfmodel::PerfModel;

/// Outcome of one greedy search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub placement: Placement,
    /// Estimated layer time of the returned placement.
    pub t_est: f64,
    /// Estimated layer time of the identity placement (the baseline the
    /// search improved on).
    pub t_identity: f64,
    /// Number of candidate placements evaluated.
    pub evaluated: usize,
    /// Selected experts, in greedy order (the paper's L[0..cnt]).
    pub selected: Vec<usize>,
}

/// Reusable buffers for [`greedy_search_with`].  A long-lived scratch
/// (e.g. inside [`super::Planner`]) makes repeated searches over
/// same-shaped matrices allocation-free.
#[derive(Clone, Debug, Default)]
pub struct SearchScratch {
    routing: RoutingState,
    /// BottomK exclusion list of the current selection.
    nb: Vec<usize>,
    /// Device-ordering buffer backing the BottomK selection.
    dev_order: Vec<usize>,
    used_devices: Vec<bool>,
    in_l: Vec<bool>,
    selected: Vec<usize>,
}

impl SearchScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Devices holding the fewest inputs for `expert` (the BottomK of Alg 1),
/// written into `nb` using the reusable `order` buffer.
///
/// Each expert is selected (and therefore BottomK'd) at most once per
/// search, so sorting lazily here costs at most one D-element sort per
/// SELECTED expert — strictly less work than pre-sorting all E orderings
/// up front — while the reused buffers keep it allocation-free.
fn bottom_k_into(
    w: &LoadMatrix,
    expert: usize,
    n: usize,
    order: &mut Vec<usize>,
    nb: &mut Vec<usize>,
) {
    order.clear();
    order.extend(0..w.n_devices());
    order.sort_unstable_by_key(|&d| (w.get(d, expert), d));
    nb.clear();
    nb.extend_from_slice(&order[..n.min(w.n_devices())]);
}

/// Greedy search on the incremental router, with caller-provided scratch.
pub fn greedy_search_with(
    w: &LoadMatrix,
    pm: &PerfModel,
    cfg: &PlannerConfig,
    scratch: &mut SearchScratch,
) -> SearchResult {
    let n_experts = w.n_experts();
    let n_devices = w.n_devices();
    let total = w.total_tokens();
    let overlap = cfg.use_overlap_model;
    let n_exclude = if cfg.n_exclude == super::AUTO_EXCLUDE {
        n_devices / 2
    } else {
        cfg.n_exclude.min(n_devices.saturating_sub(1))
    };

    // Candidate pricing: the frozen Eq 1–8 scalar model, or — on a
    // heterogeneous cluster — one of two straggler-aware estimates.
    // `device_aware` (default) prices the weighted per-device compute
    // bottleneck and routes replicas by projected finish time; it takes
    // precedence over `slack_aware`, whose worst-scalar relaxed estimate
    // charges EVERY candidate the straggler's rate (the mispricing this
    // knob fixes).  The slack estimate is overlap-shaped (Eq 8 with
    // scaled compute), so it only ever replaces the overlapped model: a
    // blocking-Eq-6 config (planner ablation arms) keeps its pricing
    // even when slack_aware leaks in.  On homogeneous clusters all
    // estimates are bit-identical and the weighted evaluator is never
    // invoked, so neither knob can perturb frozen decisions
    // (prop_greedy_matches_reference randomizes both to pin exactly
    // that).
    let dev_aware = cfg.device_aware && pm.is_heterogeneous();
    let slack = cfg.slack_aware && overlap && pm.is_heterogeneous();
    let price = |max_h: u64, wmax_h: f64, max_r: u64, s: usize, n: usize| -> f64 {
        if dev_aware {
            pm.layer_time_sn_weighted(wmax_h, max_r, s, n, overlap)
        } else if slack {
            pm.layer_time_sn_relaxed(max_h, max_r, s, n)
        } else {
            pm.layer_time_sn_from_maxes(max_h, max_r, s, n, overlap)
        }
    };
    // One routing pass: frozen unweighted evaluate, or the weighted one
    // (identical batch replay, finish-time replica scan) when dev-aware.
    let eval = |rs: &mut RoutingState| -> (crate::moe::EvalStats, f64) {
        if dev_aware {
            let ws = rs.evaluate_weighted(&pm.device_slowdown);
            (
                crate::moe::EvalStats { max_h: ws.max_h, min_h: ws.min_h, max_r: ws.max_r },
                ws.weighted_max_h,
            )
        } else {
            let s = rs.evaluate();
            (s, s.max_h as f64)
        }
    };

    let rs = &mut scratch.routing;
    rs.init(w);
    let (mut stats, mut wmax) = eval(rs);
    let t_identity = price(stats.max_h, wmax, stats.max_r, 0, 0);
    let mut t_output = t_identity;

    scratch.used_devices.clear();
    scratch.used_devices.resize(n_devices, false);
    scratch.in_l.clear();
    scratch.in_l.resize(n_experts, false);
    scratch.selected.clear();
    let dist = w.distribution_slice();
    let mut cnt = 0usize;
    let mut evaluated = 0usize;

    loop {
        // Balanced already? (Eq 7)
        let spread = (stats.max_h - stats.min_h) as f64;
        if spread < cfg.alpha * total as f64 / n_experts as f64 {
            break;
        }
        // Heaviest device; bail if we have seen it before (Alg 1 line 7).
        // Dev-aware: "heaviest" is the device that FINISHES last
        // (`H_d · slowdown_d`) — relieving a loaded straggler beats
        // relieving a faster device with more raw tokens.  Both argmaxes
        // take the LAST maximum on ties (max_by_key / max_by contract),
        // so a uniform slowdown leaves the choice unchanged.
        let heaviest_dev = if dev_aware {
            rs.h()
                .iter()
                .enumerate()
                .map(|(d, &h)| (d, h as f64 * pm.slowdown(d)))
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(d, _)| d)
                .unwrap_or(0)
        } else {
            rs.h()
                .iter()
                .enumerate()
                .max_by_key(|&(_, &h)| h)
                .map(|(d, _)| d)
                .unwrap_or(0)
        };
        if scratch.used_devices[heaviest_dev] {
            break;
        }
        scratch.used_devices[heaviest_dev] = true;

        // Heaviest unselected expert (prefer one homed on the heaviest
        // device, since shedding its load is what relieves that device).
        let candidate_expert = (0..n_experts)
            .filter(|&e| !scratch.in_l[e])
            .max_by_key(|&e| {
                let home_bonus = u64::from(w.home(e) == heaviest_dev);
                (home_bonus, dist[e], std::cmp::Reverse(e))
            });
        let Some(expert) = candidate_expert else { break };
        scratch.in_l[expert] = true;

        bottom_k_into(w, expert, n_exclude, &mut scratch.dev_order, &mut scratch.nb);
        // Memory constraint: devices without replica headroom are excluded
        // too (the optimizer states stay home, but params+grads must fit).
        if let Some(mem) = &cfg.memory {
            for d in mem.full_devices(rs.placement()) {
                if !scratch.nb.contains(&d) {
                    scratch.nb.push(d);
                }
            }
        }
        // Device-health mask: never widen a replica set onto a down
        // device (the session's failover handles pre-existing homes).
        if let Some(mask) = &cfg.device_mask {
            for (d, &dn) in mask.iter().enumerate() {
                if dn && !scratch.nb.contains(&d) {
                    scratch.nb.push(d);
                }
            }
        }
        rs.apply_replicate_except(w, expert, &scratch.nb);
        scratch.selected.push(expert);

        // Re-route and evaluate (Alg 1 lines 15-20).
        (stats, wmax) = eval(rs);
        let s = scratch.selected.len();
        let t_changed = price(stats.max_h, wmax, stats.max_r, s, n_exclude);
        evaluated += 1;
        if t_changed < t_output {
            t_output = t_changed;
            cnt = s;
        }
        if s == n_experts {
            break;
        }
        // Step budget exhausted: degrade gracefully to the best prefix
        // found so far instead of running Algorithm 1 to termination.
        if cfg.step_budget.is_some_and(|b| evaluated >= b) {
            break;
        }
    }

    // Keep the best prefix L[0..cnt] by unwinding the excess deltas
    // (Alg 1 line 22 rebuilt from scratch; undo reaches the same state).
    for _ in cnt..scratch.selected.len() {
        rs.undo(w);
    }
    let best = rs.placement().clone();
    debug_assert!(best.validate().is_ok());
    SearchResult {
        placement: best,
        t_est: t_output,
        t_identity,
        evaluated,
        selected: scratch.selected[..cnt].to_vec(),
    }
}

/// Greedy search with one-shot scratch (see [`greedy_search_with`] for the
/// allocation-free form the planner uses).
pub fn greedy_search(w: &LoadMatrix, pm: &PerfModel, cfg: &PlannerConfig) -> SearchResult {
    greedy_search_with(w, pm, cfg, &mut SearchScratch::new())
}

/// Devices holding the fewest inputs for `expert` (allocating form, kept
/// for the reference implementation).
fn bottom_k(w: &LoadMatrix, expert: usize, n: usize) -> Vec<usize> {
    let mut devs: Vec<usize> = (0..w.n_devices()).collect();
    devs.sort_by_key(|&d| (w.get(d, expert), d));
    devs.truncate(n.min(w.n_devices()));
    devs
}

/// The pre-incremental implementation: full `w.route()` re-evaluation per
/// candidate.  Kept (compiled, not test-gated) as the equivalence oracle
/// for the property tests AND as the "old" side of `bench_plan_cost`'s
/// old-vs-new plans/sec measurement.  Must never be called on a hot path.
pub fn greedy_search_reference(
    w: &LoadMatrix,
    pm: &PerfModel,
    cfg: &PlannerConfig,
) -> SearchResult {
    let n_experts = w.n_experts();
    let n_devices = w.n_devices();
    let total = w.total_tokens();
    let overlap = cfg.use_overlap_model;
    let n_exclude = if cfg.n_exclude == super::AUTO_EXCLUDE {
        n_devices / 2
    } else {
        cfg.n_exclude.min(n_devices.saturating_sub(1))
    };

    let identity = Placement::identity(n_experts, n_devices);
    let mut routed = w.route(&identity);
    let t_identity = pm.layer_time_sn(&routed, 0, 0, overlap);
    let mut t_output = t_identity;

    let mut placement = identity;
    let mut selected: Vec<usize> = Vec::new();
    let mut bottoms: Vec<Vec<usize>> = Vec::new();
    let mut used_devices = vec![false; n_devices];
    let mut in_l = vec![false; n_experts];
    let mut cnt = 0usize;
    let mut evaluated = 0usize;

    loop {
        // Balanced already? (Eq 7)
        if routed.is_balanced(cfg.alpha, total, n_experts) {
            break;
        }
        // Heaviest device; bail if we have seen it before (Alg 1 line 7).
        let heaviest_dev = routed
            .h
            .iter()
            .enumerate()
            .max_by_key(|&(_, &h)| h)
            .map(|(d, _)| d)
            .unwrap_or(0);
        if used_devices[heaviest_dev] {
            break;
        }
        used_devices[heaviest_dev] = true;

        // Heaviest unselected expert (prefer one homed on the heaviest
        // device, since shedding its load is what relieves that device).
        let candidate_expert = (0..n_experts)
            .filter(|&e| !in_l[e])
            .max_by_key(|&e| {
                let home_bonus = u64::from(w.home(e) == heaviest_dev);
                (home_bonus, w.expert_load(e), std::cmp::Reverse(e))
            });
        let Some(expert) = candidate_expert else { break };
        in_l[expert] = true;

        let mut nb = bottom_k(w, expert, n_exclude);
        // Memory constraint: devices without replica headroom are excluded
        // too (the optimizer states stay home, but params+grads must fit).
        if let Some(mem) = &cfg.memory {
            for d in mem.full_devices(&placement) {
                if !nb.contains(&d) {
                    nb.push(d);
                }
            }
        }
        placement.replicate_except(expert, &nb);
        selected.push(expert);
        bottoms.push(nb);

        // Re-route and evaluate (Alg 1 lines 15-20).
        routed = w.route(&placement);
        let s = selected.len();
        let t_changed = pm.layer_time_sn(&routed, s, n_exclude, overlap);
        evaluated += 1;
        if t_changed < t_output {
            t_output = t_changed;
            cnt = s;
        }
        if s == n_experts {
            break;
        }
    }

    // Rebuild the best prefix L[0..cnt] (Alg 1 line 22).
    let mut best = Placement::identity(n_experts, n_devices);
    for i in 0..cnt {
        best.replicate_except(selected[i], &bottoms[i]);
    }
    debug_assert!(best.validate().is_ok());
    SearchResult {
        placement: best,
        t_est: t_output,
        t_identity,
        evaluated,
        selected: selected[..cnt].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;

    fn pm(e: usize) -> PerfModel {
        PerfModel::new(
            &ModelSpec::moe_gpt_s(e, 1, 4096),
            &ClusterSpec::hpwnv(e.div_ceil(4)),
        )
    }

    fn assert_same_result(a: &SearchResult, b: &SearchResult) {
        assert_eq!(a.placement, b.placement, "placements differ");
        assert_eq!(a.selected, b.selected, "selections differ");
        assert_eq!(a.evaluated, b.evaluated, "evaluation counts differ");
        assert_eq!(a.t_est.to_bits(), b.t_est.to_bits(), "t_est differs");
        assert_eq!(
            a.t_identity.to_bits(),
            b.t_identity.to_bits(),
            "t_identity differs"
        );
    }

    #[test]
    fn never_worse_than_identity() {
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let r = greedy_search(&w, &pm(4), &PlannerConfig::default());
        assert!(r.t_est <= r.t_identity + 1e-15);
        assert!(r.placement.validate().is_ok());
        assert_same_result(&r, &greedy_search_reference(&w, &pm(4), &PlannerConfig::default()));
    }

    #[test]
    fn balanced_load_returns_identity() {
        let w = LoadMatrix::from_rows(vec![vec![256; 4]; 4]);
        let r = greedy_search(&w, &pm(4), &PlannerConfig::default());
        assert!(r.placement.is_identity());
        assert_eq!(r.evaluated, 0);
    }

    #[test]
    fn heavy_expert_gets_replicated() {
        // Expert 0 holds ~70% of tokens; the search must select it.
        let w = LoadMatrix::from_rows(vec![
            vec![700, 100, 100, 124],
            vec![720, 90, 100, 114],
            vec![710, 110, 90, 114],
            vec![690, 100, 110, 124],
        ]);
        let r = greedy_search(&w, &pm(4), &PlannerConfig::default());
        assert!(
            r.selected.contains(&0),
            "expert 0 should be selected, got {:?}",
            r.selected
        );
        assert!(r.placement.replicas(0).len() > 1);
        assert!(r.t_est < r.t_identity);
    }

    #[test]
    fn bottom_k_excludes_lightest_devices() {
        let w = LoadMatrix::from_rows(vec![
            vec![100, 0],
            vec![5, 0],
            vec![50, 0],
            vec![1, 0],
        ]);
        assert_eq!(bottom_k(&w, 0, 2), vec![3, 1]);
        assert_eq!(bottom_k(&w, 0, 0), Vec::<usize>::new());
        // n larger than D saturates.
        assert_eq!(bottom_k(&w, 0, 99).len(), 4);
        // The scratch-based form agrees.
        let (mut order, mut nb) = (Vec::new(), Vec::new());
        bottom_k_into(&w, 0, 2, &mut order, &mut nb);
        assert_eq!(nb, vec![3, 1]);
        bottom_k_into(&w, 0, 99, &mut order, &mut nb);
        assert_eq!(nb.len(), 4);
    }

    #[test]
    fn n_exclude_limits_replicas() {
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cfg = PlannerConfig { n_exclude: 2, ..Default::default() };
        let r = greedy_search(&w, &pm(4), &cfg);
        for &e in &r.selected {
            assert!(r.placement.replicas(e).len() <= 4 - 2 + 1); // +home slack
        }
    }

    #[test]
    fn terminates_on_pathological_inputs() {
        // All tokens to one expert from one device.
        let mut w = LoadMatrix::zeros(8, 8);
        w.set(0, 0, 100_000);
        let r = greedy_search(&w, &pm(8), &PlannerConfig::default());
        assert!(r.evaluated <= 8);
        assert!(r.placement.validate().is_ok());
        assert_same_result(&r, &greedy_search_reference(&w, &pm(8), &PlannerConfig::default()));

        // Zero tokens entirely.
        let w0 = LoadMatrix::zeros(4, 4);
        let r0 = greedy_search(&w0, &pm(4), &PlannerConfig::default());
        assert!(r0.placement.is_identity());
    }

    #[test]
    fn overlap_model_changes_accounting_not_validity() {
        let w = LoadMatrix::from_rows(vec![
            vec![500, 200, 150, 174],
            vec![520, 180, 170, 154],
            vec![480, 220, 140, 184],
            vec![500, 200, 160, 164],
        ]);
        for overlap in [false, true] {
            let cfg = PlannerConfig { use_overlap_model: overlap, ..Default::default() };
            let r = greedy_search(&w, &pm(4), &cfg);
            assert!(r.placement.validate().is_ok());
            assert!(r.t_est <= r.t_identity + 1e-15);
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // Two searches through ONE scratch must match fresh-scratch runs,
        // including across different shapes.
        let w1 = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let mut w2 = LoadMatrix::zeros(8, 8);
        w2.set(0, 0, 100_000);
        w2.set(3, 5, 40_000);
        let cfg = PlannerConfig::default();
        let mut scratch = SearchScratch::new();
        let a1 = greedy_search_with(&w1, &pm(4), &cfg, &mut scratch);
        let a2 = greedy_search_with(&w2, &pm(8), &cfg, &mut scratch);
        let a3 = greedy_search_with(&w1, &pm(4), &cfg, &mut scratch);
        assert_same_result(&a1, &greedy_search(&w1, &pm(4), &cfg));
        assert_same_result(&a2, &greedy_search(&w2, &pm(8), &cfg));
        assert_same_result(&a1, &a3);
    }

    #[test]
    fn slack_aware_is_inert_on_homogeneous_clusters() {
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cfg = PlannerConfig { slack_aware: true, ..Default::default() };
        let r = greedy_search(&w, &pm(4), &cfg);
        let reference = greedy_search_reference(&w, &pm(4), &PlannerConfig::default());
        assert_same_result(&r, &reference);
    }

    #[test]
    fn slack_aware_search_valid_on_straggler_cluster() {
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cluster = ClusterSpec::hpwnv(1).with_slowdown(0, 3.0);
        let pm_het = PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &cluster);
        // device_aware outranks slack_aware; force the scalar path to
        // test it in isolation.
        let cfg = PlannerConfig { slack_aware: true, device_aware: false, ..Default::default() };
        let r = greedy_search(&w, &pm_het, &cfg);
        assert!(r.placement.validate().is_ok());
        assert!(r.t_est <= r.t_identity + 1e-15);
        // The estimates come from the slack model: reproducible from the
        // returned placement.
        let routed = w.route(&r.placement);
        let t = pm_het.layer_time_sn_relaxed(
            routed.h.iter().copied().max().unwrap_or(0),
            routed.r.iter().copied().max().unwrap_or(0),
            r.selected.len(),
            2, // AUTO_EXCLUDE on 4 devices
        );
        assert!((t - r.t_est).abs() <= 1e-9 * t.max(1.0) + 1e-12);
    }

    #[test]
    fn device_aware_is_inert_on_homogeneous_clusters() {
        // The gate is `pm.is_heterogeneous()`: with it closed the default
        // config (device_aware: true) must stay bit-identical to the
        // frozen reference — the weighted evaluator is never invoked.
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cfg = PlannerConfig::default();
        assert!(cfg.device_aware, "device awareness is the default");
        let r = greedy_search(&w, &pm(4), &cfg);
        assert_same_result(&r, &greedy_search_reference(&w, &pm(4), &cfg));
        let off = PlannerConfig { device_aware: false, ..Default::default() };
        assert_same_result(&r, &greedy_search(&w, &pm(4), &off));
    }

    #[test]
    fn device_aware_matches_slack_on_uniform_slowdown() {
        // Uniform slowdown u: every product (H_d + tokens)·u and H_d·u is
        // exact in f64 (small integers, u = 2.5 = 5/2), multiplication by
        // a positive constant is strictly monotone, and both argmaxes
        // take the last maximum — so the dev-aware search makes the SAME
        // choices as the worst-scalar slack path and
        // layer_time_sn_weighted(max_h·u, ..) is bit-identical to
        // layer_time_sn_relaxed(max_h, ..).  Pins the "weighted estimate
        // degenerates to the scalar one when no device differs" contract.
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cluster = ClusterSpec::hpwnv(1).with_slowdowns(vec![2.5; 4]);
        let pm_u = PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &cluster);
        assert!(pm_u.is_heterogeneous());
        let dev = greedy_search(&w, &pm_u, &PlannerConfig::default());
        let scalar_cfg =
            PlannerConfig { device_aware: false, slack_aware: true, ..Default::default() };
        let scalar = greedy_search(&w, &pm_u, &scalar_cfg);
        assert_same_result(&dev, &scalar);
    }

    #[test]
    fn device_aware_search_valid_on_straggler_cluster() {
        // Sibling of slack_aware_search_valid_on_straggler_cluster for
        // the default dev-aware path: the search stays sound on a 3x
        // straggler and its estimate never exceeds the identity's.
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let cluster = ClusterSpec::hpwnv(1).with_slowdown(0, 3.0);
        let pm_het = PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &cluster);
        let r = greedy_search(&w, &pm_het, &PlannerConfig::default());
        assert!(r.placement.validate().is_ok());
        assert!(r.t_est <= r.t_identity + 1e-15);
        // Deterministic, and scratch-reusable like every other mode.
        let mut scratch = SearchScratch::new();
        let again = greedy_search_with(&w, &pm_het, &PlannerConfig::default(), &mut scratch);
        assert_same_result(&r, &again);
    }

    #[test]
    fn device_mask_blocks_new_replicas_on_down_devices() {
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        let mask = vec![false, true, false, true];
        let cfg = PlannerConfig {
            device_mask: Some(mask.clone()),
            ..Default::default()
        };
        let r = greedy_search(&w, &pm(4), &cfg);
        assert!(r.placement.validate().is_ok());
        for e in 0..4 {
            for d in r.placement.replicas(e).iter() {
                // A down device may only appear as the expert's own home
                // (failover is the session's job); never as a new replica.
                assert!(!mask[d] || d == r.placement.home(e), "expert {e} replica on down {d}");
            }
        }
        // A default (None) mask stays bit-identical to the reference.
        let plain = greedy_search(&w, &pm(4), &PlannerConfig::default());
        assert_same_result(&plain, &greedy_search_reference(&w, &pm(4), &PlannerConfig::default()));
    }

    #[test]
    fn step_budget_truncates_deterministically() {
        let mut w = LoadMatrix::zeros(8, 8);
        for d in 0..8 {
            for e in 0..8 {
                w.set(d, e, if e < 2 { 800 } else { 40 });
            }
        }
        let unbounded = greedy_search(&w, &pm(8), &PlannerConfig::default());
        assert!(unbounded.evaluated >= 2, "test needs a multi-step search");
        let cfg = PlannerConfig { step_budget: Some(1), ..Default::default() };
        let budgeted = greedy_search(&w, &pm(8), &cfg);
        assert_eq!(budgeted.evaluated, 1);
        assert!(budgeted.placement.validate().is_ok());
        assert!(budgeted.t_est <= budgeted.t_identity + 1e-15);
        // Deterministic: same budget, same result.
        let again = greedy_search(&w, &pm(8), &cfg);
        assert_same_result(&budgeted, &again);
        // A budget at least as large as the unbounded search is inert.
        let loose = PlannerConfig {
            step_budget: Some(unbounded.evaluated),
            ..Default::default()
        };
        assert_same_result(&unbounded, &greedy_search(&w, &pm(8), &loose));
    }

    #[test]
    fn memory_constrained_search_matches_reference() {
        use crate::moe::MemoryModel;
        let w = LoadMatrix::from_rows(vec![
            vec![900, 50, 30, 44],
            vec![800, 100, 60, 64],
            vec![850, 70, 40, 64],
            vec![900, 60, 20, 44],
        ]);
        // Room for roughly one extra replica per device.
        let mem = MemoryModel::new(4e6, 1.0, 12, 100e6);
        let cfg = PlannerConfig { memory: Some(mem), ..Default::default() };
        let r = greedy_search(&w, &pm(4), &cfg);
        assert_same_result(&r, &greedy_search_reference(&w, &pm(4), &cfg));
    }
}
