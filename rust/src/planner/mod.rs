//! Pro-Prophet planner (paper §IV): searches for a communication-efficient
//! lightweight expert placement with a locality-based greedy algorithm.

pub mod greedy;
pub mod locality;
pub mod policies;

pub use greedy::{
    greedy_search, greedy_search_reference, greedy_search_with, SearchResult, SearchScratch,
};

use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::prophet::DriftDetector;
use std::sync::Arc;

/// Sentinel for [`PlannerConfig::n_exclude`]: resolve `n` to D/2 at search
/// time (replicate a selected expert to the top half of devices by its
/// token count — the "necessary devices" of the paper's Fig 6).
pub const AUTO_EXCLUDE: usize = usize::MAX;

/// Planner knobs (paper Algorithm 1 inputs + locality settings).
#[derive(Clone, Debug, PartialEq)]
pub struct PlannerConfig {
    /// `n`: number of devices a selected expert is NOT transferred to
    /// (the BottomK exclusion of Algorithm 1).  [`AUTO_EXCLUDE`] = D/2.
    pub n_exclude: usize,
    /// `alpha`: balance tolerance of Eq 7.
    pub alpha: f64,
    /// Re-run the greedy search every this many iterations, reusing the
    /// cached placement in between (the locality-based frequency
    /// reduction of §IV-C).
    pub replan_interval: usize,
    /// Evaluate candidates with the scheduler-aware Eq 8 instead of the
    /// blocking Eq 6 (the planner/scheduler combination of §V-C).
    pub use_overlap_model: bool,
    /// Rank candidates with the slack-aware relaxed estimate
    /// ([`crate::perfmodel::PerfModel::layer_time_sn_relaxed`]) when the
    /// cluster is heterogeneous — the cost model of
    /// `ScheduleKind::DagRelaxed` policies.  On homogeneous clusters the
    /// slack estimate is bit-identical to the Eq-8 overlapped model, so
    /// frozen planning decisions are unaffected either way; only a
    /// straggler makes this knob change placements.
    pub slack_aware: bool,
    /// Rank candidates on per-device finish times when the cluster is
    /// heterogeneous: the routing sweep picks replicas by projected
    /// finish time ([`crate::moe::RoutingState::evaluate_weighted`]), the
    /// heaviest device is the one finishing *last* (`H_d · slowdown_d`),
    /// and pricing charges the weighted compute bottleneck
    /// ([`crate::perfmodel::PerfModel::layer_time_sn_weighted`]) instead
    /// of the worst-scalar `max_slowdown()` approximation — so a
    /// candidate that piles tokens onto a 2× straggler no longer ranks
    /// identically to one that routes around it.  Takes precedence over
    /// `slack_aware` (it is the strictly more informed estimate).  On
    /// homogeneous clusters the gate (`pm.is_heterogeneous()`) never
    /// opens, so every pre-existing path stays bit-identical to the
    /// frozen reference; default **true**.
    pub device_aware: bool,
    /// Optional device-memory model: devices without replica headroom are
    /// excluded from placements (see moe::memory).
    pub memory: Option<crate::moe::MemoryModel>,
    /// Device-health mask (`true` = down): the search never places NEW
    /// replicas on masked devices.  Home replicas of experts homed on a
    /// down device are the balancer session's failover problem — the
    /// search only ever widens replica sets.  `None` (default) leaves
    /// the search bit-identical to a maskless build.
    pub device_mask: Option<Vec<bool>>,
    /// Deterministic step budget: stop the greedy loop after evaluating
    /// this many candidate placements, returning the best prefix found
    /// so far (graceful degradation under a replan deadline).  `None`
    /// (default) keeps Algorithm 1's own termination — bit-identical.
    pub step_budget: Option<usize>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            n_exclude: AUTO_EXCLUDE,
            alpha: 0.25,
            replan_interval: 1,
            use_overlap_model: true,
            slack_aware: false,
            device_aware: true,
            memory: None,
            device_mask: None,
            step_budget: None,
        }
    }
}

/// Stateful planner: wraps the greedy search with the locality-driven
/// replanning schedule and bookkeeping for reports.
#[derive(Clone, Debug)]
pub struct Planner {
    pub cfg: PlannerConfig,
    /// Cache reuse hands out a shared handle instead of deep-cloning the
    /// placement (E bitsets) on every iteration between replans.
    cached: Option<Arc<Placement>>,
    iters_since_plan: usize,
    pub plans_run: usize,
    pub plans_reused: usize,
    /// Replans forced by drift detection (plan_with_drift_check).
    pub drift_replans: usize,
    /// Distribution the cached placement was planned for.
    planned_dist: Option<Vec<u64>>,
    /// Shared drift machinery (prophet subsystem); lazily armed by
    /// [`Planner::plan_with_drift_check`].
    drift: Option<DriftDetector>,
    /// Wall-clock seconds spent inside greedy_search (the real Plan cost).
    pub search_seconds: f64,
    /// Candidate placements the greedy search evaluated, summed over
    /// every search (the telemetry layer reports candidates/search).
    pub candidates_evaluated: usize,
    /// Reusable search buffers (incremental routing state, BottomK
    /// ordering): steady-state planning allocates nothing.
    scratch: SearchScratch,
}

impl Planner {
    pub fn new(cfg: PlannerConfig) -> Self {
        Planner {
            cfg,
            cached: None,
            iters_since_plan: 0,
            plans_run: 0,
            plans_reused: 0,
            drift_replans: 0,
            planned_dist: None,
            drift: None,
            search_seconds: 0.0,
            candidates_evaluated: 0,
            scratch: SearchScratch::new(),
        }
    }

    /// Produce a placement for the upcoming iteration given the observed
    /// (or prophet-forecast, see [`crate::prophet::Prophet::forecast_matrix`])
    /// load matrix.
    pub fn plan(&mut self, w: &LoadMatrix, pm: &PerfModel) -> Arc<Placement> {
        if let Some(cached) = &self.cached {
            if self.iters_since_plan < self.cfg.replan_interval
                && cached.n_experts() == w.n_experts()
            {
                self.iters_since_plan += 1;
                self.plans_reused += 1;
                return Arc::clone(cached);
            }
        }
        let start = std::time::Instant::now();
        let result = greedy_search_with(w, pm, &self.cfg, &mut self.scratch);
        self.search_seconds += start.elapsed().as_secs_f64();
        self.candidates_evaluated += result.evaluated;
        self.plans_run += 1;
        self.iters_since_plan = 1;
        let placement = Arc::new(result.placement);
        self.cached = Some(Arc::clone(&placement));
        placement
    }

    /// Drop the cache (e.g. when the predictor detects a distribution
    /// shift larger than the locality assumption tolerates).
    pub fn invalidate(&mut self) {
        self.cached = None;
        self.iters_since_plan = 0;
    }

    /// Locality-aware planning with drift detection: reuse the cached
    /// placement only while the observed distribution stays within
    /// `min_similarity` of the one it was planned for (Fig 4 locality can
    /// break at workload boundaries; a similarity drop forces a replan
    /// regardless of the replan interval).  Detection is delegated to the
    /// shared [`crate::prophet::DriftDetector`] (threshold-only here — the
    /// per-call threshold argument keeps the legacy API; cooldown-based
    /// suppression lives in the prophet-driven policy loop).
    pub fn plan_with_drift_check(
        &mut self,
        w: &LoadMatrix,
        pm: &PerfModel,
        min_similarity: f64,
    ) -> Arc<Placement> {
        let dist = w.distribution();
        let det = self
            .drift
            .get_or_insert_with(|| DriftDetector::new(min_similarity, 0));
        det.threshold = min_similarity;
        if let Some(prev) = &self.planned_dist {
            if det.check_counts(prev, &dist) {
                self.invalidate();
                self.drift_replans += 1;
            }
        }
        let had_cache = self.cached.is_some();
        let p = self.plan(w, pm);
        if !had_cache || self.iters_since_plan == 1 {
            self.planned_dist = Some(dist);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelSpec;

    fn skewed_w() -> LoadMatrix {
        LoadMatrix::from_rows(vec![
            vec![600, 100, 100, 224],
            vec![600, 100, 100, 224],
            vec![600, 100, 100, 224],
            vec![600, 100, 100, 224],
        ])
    }

    fn pm() -> PerfModel {
        PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &ClusterSpec::hpwnv(1))
    }

    #[test]
    fn caching_respects_replan_interval() {
        let cfg = PlannerConfig { replan_interval: 4, ..Default::default() };
        let mut planner = Planner::new(cfg);
        let w = skewed_w();
        let pm = pm();
        for _ in 0..8 {
            planner.plan(&w, &pm);
        }
        assert_eq!(planner.plans_run, 2);
        assert_eq!(planner.plans_reused, 6);
    }

    #[test]
    fn invalidate_forces_replan() {
        let cfg = PlannerConfig { replan_interval: 100, ..Default::default() };
        let mut planner = Planner::new(cfg);
        let w = skewed_w();
        let pm = pm();
        planner.plan(&w, &pm);
        planner.invalidate();
        planner.plan(&w, &pm);
        assert_eq!(planner.plans_run, 2);
    }

    #[test]
    fn drift_check_forces_replan() {
        let cfg = PlannerConfig { replan_interval: 100, ..Default::default() };
        let mut planner = Planner::new(cfg);
        let pm = pm();
        let w1 = skewed_w();
        planner.plan_with_drift_check(&w1, &pm, 0.9);
        // Same distribution: reuse.
        planner.plan_with_drift_check(&w1, &pm, 0.9);
        assert_eq!(planner.plans_run, 1);
        // Violent shift: expert 3 suddenly dominates.
        let w2 = LoadMatrix::from_rows(vec![
            vec![50, 100, 100, 774],
            vec![50, 100, 100, 774],
            vec![50, 100, 100, 774],
            vec![50, 100, 100, 774],
        ]);
        planner.plan_with_drift_check(&w2, &pm, 0.9);
        assert_eq!(planner.drift_replans, 1);
        assert_eq!(planner.plans_run, 2);
    }

    #[test]
    fn memory_constraint_blocks_full_devices() {
        use crate::moe::MemoryModel;
        // Devices with zero replica headroom: placement must stay identity
        // no matter how skewed the load is.
        let mem = MemoryModel::new(4e6, 0.35, 12, 100e6);
        let cfg = PlannerConfig { memory: Some(mem), ..Default::default() };
        let mut planner = Planner::new(cfg);
        let p = planner.plan(&skewed_w(), &pm());
        assert!(p.is_identity(), "no device has headroom: {:?}", p.replica_counts());
    }

    #[test]
    fn planned_placement_is_valid() {
        let mut planner = Planner::new(PlannerConfig::default());
        let p = planner.plan(&skewed_w(), &pm());
        assert!(p.validate().is_ok());
    }
}
