//! Threaded expert-parallel coordinator: a leader routes real tokens to
//! "virtual devices" (one OS thread + one PJRT executable each) according
//! to an expert placement; channels play the role of the interconnect.
//!
//! This exercises the same code path as the paper's system — gate →
//! dispatch (A2A) → per-device expert FFN → combine — with REAL tensors
//! flowing through the AOT'd Pallas kernels, and reports per-device load
//! and busy time so the effect of a placement is observable end to end
//! (examples/ep_demo.rs).
//!
//! tokio is unavailable offline; std::thread + mpsc channels implement the
//! same leader/worker topology.

use crate::moe::Placement;
use crate::runtime::{self, Manifest, Runtime};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A chunk of tokens for one expert on one device.
struct Task {
    seq: usize,
    expert: usize,
    rows: usize,
    /// Row-major (rows, d_model), padded by the worker to capacity.
    data: Vec<f32>,
}

struct TaskResult {
    seq: usize,
    device: usize,
    rows: usize,
    data: Vec<f32>,
    busy_seconds: f64,
}

enum ToWorker {
    Run(Task),
    Stop,
}

struct Worker {
    tx: Sender<ToWorker>,
    handle: JoinHandle<Result<()>>,
}

/// Per-expert FFN weights in host form (extracted from the init artifact).
#[derive(Clone)]
pub struct ExpertWeights {
    pub w1: Vec<f32>, // (d, f)
    pub b1: Vec<f32>, // (f)
    pub w2: Vec<f32>, // (f, d)
    pub b2: Vec<f32>, // (d)
}

/// The EP cluster: one worker thread per virtual device.
pub struct EpCluster {
    pub manifest: Manifest,
    workers: Vec<Worker>,
    results_rx: Receiver<TaskResult>,
    n_devices: usize,
}

/// Outcome of one EP iteration.
#[derive(Clone, Debug)]
pub struct EpIterationReport {
    pub wall_seconds: f64,
    pub per_device_busy: Vec<f64>,
    pub per_device_tokens: Vec<u64>,
    /// max/mean busy ratio — 1.0 is perfectly balanced.
    pub imbalance: f64,
    /// Output rows in token order (T x d_model).
    pub output: Vec<f32>,
}

impl EpCluster {
    /// Spawn `n_devices` workers, each with its own PJRT client, the
    /// expert-FFN executable, and the weights of ALL experts (replicas are
    /// routing decisions; which device computes which expert is up to the
    /// placement the leader applies).
    pub fn new(manifest: Manifest, weights: Vec<ExpertWeights>) -> Result<EpCluster> {
        let n_devices = manifest.n_experts; // paper: one expert per device
        if weights.len() != manifest.n_experts {
            return Err(anyhow!("need one weight set per expert"));
        }
        let (results_tx, results_rx) = channel::<TaskResult>();
        let mut workers = Vec::with_capacity(n_devices);
        for device in 0..n_devices {
            let (tx, rx) = channel::<ToWorker>();
            let res_tx = results_tx.clone();
            let man = manifest.clone();
            let wts = weights.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ep-worker-{device}"))
                .spawn(move || worker_main(device, man, wts, rx, res_tx))
                .map_err(|e| anyhow!("spawn worker {device}: {e}"))?;
            workers.push(Worker { tx, handle });
        }
        Ok(EpCluster { manifest, workers, results_rx, n_devices })
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Run one MoE-layer iteration: tokens (T, d_model) with per-token
    /// expert assignment `assignment` (top-1 for the demo), routed under
    /// `placement`.  Tokens whose expert is replicated are spread evenly
    /// over the replica devices; otherwise they go to the expert's home.
    pub fn run_iteration(
        &self,
        x: &[f32],
        assignment: &[usize],
        placement: &Placement,
    ) -> Result<EpIterationReport> {
        let d_model = self.manifest.d_model;
        let t = assignment.len();
        if x.len() != t * d_model {
            return Err(anyhow!("x has {} values, want {}", x.len(), t * d_model));
        }
        let capacity = self.manifest.capacity.max(1);
        let start = std::time::Instant::now();

        // Group token indices by expert.
        let n_experts = self.manifest.n_experts;
        let mut by_expert: Vec<Vec<usize>> = vec![vec![]; n_experts];
        for (i, &e) in assignment.iter().enumerate() {
            if e >= n_experts {
                return Err(anyhow!("token {i} routed to bogus expert {e}"));
            }
            by_expert[e].push(i);
        }

        // Dispatch: split each expert's queue over its replica devices in
        // capacity-sized chunks (the A2A of the real system).
        let mut seq = 0usize;
        let mut sent: Vec<(usize, Vec<usize>)> = Vec::new(); // seq -> token ids
        let mut per_device_tokens = vec![0u64; self.n_devices];
        for (e, tokens) in by_expert.iter().enumerate() {
            if tokens.is_empty() {
                continue;
            }
            let replicas: Vec<usize> = placement.replicas(e).iter().collect();
            let targets = if replicas.is_empty() {
                vec![placement.home(e)]
            } else {
                replicas
            };
            // Even split across targets.
            let per = tokens.len().div_ceil(targets.len());
            for (ti, chunk_tokens) in tokens.chunks(per).enumerate() {
                let dev = targets[ti % targets.len()];
                // Capacity-sized sub-chunks per device.
                for sub in chunk_tokens.chunks(capacity) {
                    let mut data = Vec::with_capacity(sub.len() * d_model);
                    for &tok in sub {
                        data.extend_from_slice(&x[tok * d_model..(tok + 1) * d_model]);
                    }
                    per_device_tokens[dev] += sub.len() as u64;
                    self.workers[dev]
                        .tx
                        .send(ToWorker::Run(Task {
                            seq,
                            expert: e,
                            rows: sub.len(),
                            data,
                        }))
                        .map_err(|_| anyhow!("worker {dev} died"))?;
                    sent.push((seq, sub.to_vec()));
                    seq += 1;
                }
            }
        }

        // Combine: gather results back into token order.
        let mut output = vec![0.0f32; t * d_model];
        let mut per_device_busy = vec![0.0f64; self.n_devices];
        for _ in 0..sent.len() {
            let r = self
                .results_rx
                .recv()
                .map_err(|_| anyhow!("result channel closed"))?;
            per_device_busy[r.device] += r.busy_seconds;
            let (_, token_ids) = sent
                .iter()
                .find(|(s, _)| *s == r.seq)
                .ok_or_else(|| anyhow!("unknown seq {}", r.seq))?;
            for (row, &tok) in token_ids.iter().enumerate().take(r.rows) {
                output[tok * d_model..(tok + 1) * d_model]
                    .copy_from_slice(&r.data[row * d_model..(row + 1) * d_model]);
            }
        }

        let max_busy = per_device_busy.iter().copied().fold(0.0, f64::max);
        let mean_busy = per_device_busy.iter().sum::<f64>()
            / per_device_busy.len().max(1) as f64;
        Ok(EpIterationReport {
            wall_seconds: start.elapsed().as_secs_f64(),
            per_device_busy,
            per_device_tokens,
            imbalance: if mean_busy > 0.0 { max_busy / mean_busy } else { 1.0 },
            output,
        })
    }

    /// Stop all workers and join.
    pub fn shutdown(self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Stop);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
    }
}

fn worker_main(
    device: usize,
    man: Manifest,
    weights: Vec<ExpertWeights>,
    rx: Receiver<ToWorker>,
    tx: Sender<TaskResult>,
) -> Result<()> {
    // Each worker owns a full PJRT client: process-isolation stand-in.
    let rt = Runtime::cpu()?;
    let ffn = rt.load_tagged(&man, "expert_ffn")?;
    let (d, f, c) = (man.d_model, man.d_ff, man.capacity.max(1));

    // Pre-build weight literals per expert.
    let mut wlits = Vec::with_capacity(weights.len());
    for w in &weights {
        wlits.push((
            runtime::f32_literal(&w.w1, &[d, f])?,
            runtime::f32_literal(&w.b1, &[f])?,
            runtime::f32_literal(&w.w2, &[f, d])?,
            runtime::f32_literal(&w.b2, &[d])?,
        ));
    }

    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Stop => break,
            ToWorker::Run(task) => {
                let begin = std::time::Instant::now();
                // Pad to the artifact's fixed (capacity, d) shape.
                let mut padded = vec![0.0f32; c * d];
                padded[..task.data.len()].copy_from_slice(&task.data);
                let x = runtime::f32_literal(&padded, &[c, d])?;
                let (w1, b1, w2, b2) = &wlits[task.expert];
                let out = ffn.run(&[&x, w1, b1, w2, b2])?;
                let full = runtime::to_f32_vec(&out[0])?;
                let result = TaskResult {
                    seq: task.seq,
                    device,
                    rows: task.rows,
                    data: full[..task.rows * d].to_vec(),
                    busy_seconds: begin.elapsed().as_secs_f64(),
                };
                if tx.send(result).is_err() {
                    break;
                }
            }
        }
    }
    Ok(())
}

/// Extract layer-`layer` expert weights from a flat init state.
pub fn extract_expert_weights(
    man: &Manifest,
    state: &[xla::Literal],
    layer: usize,
) -> Result<Vec<ExpertWeights>> {
    let (d, f, e) = (man.d_model, man.d_ff, man.n_experts);
    let idx = |suffix: &str| -> Result<usize> {
        man.layer_tensor_index(layer, suffix)
            .ok_or_else(|| anyhow!("layer {layer} tensor {suffix} missing"))
    };
    let w1 = runtime::to_f32_vec(&state[idx("w1")?])?;
    let b1 = runtime::to_f32_vec(&state[idx("b1")?])?;
    let w2 = runtime::to_f32_vec(&state[idx("w2")?])?;
    let b2 = runtime::to_f32_vec(&state[idx("b2")?])?;
    let mut out = Vec::with_capacity(e);
    for i in 0..e {
        out.push(ExpertWeights {
            w1: w1[i * d * f..(i + 1) * d * f].to_vec(),
            b1: b1[i * f..(i + 1) * f].to_vec(),
            w2: w2[i * f * d..(i + 1) * f * d].to_vec(),
            b2: b2[i * d..(i + 1) * d].to_vec(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // EpCluster needs built artifacts + a PJRT client; covered by
    // rust/tests/integration_runtime.rs.  Here we test the pure routing
    // bookkeeping helpers indirectly through Placement semantics.
    use crate::moe::Placement;

    #[test]
    fn replica_targets_nonempty() {
        let p = Placement::identity(4, 4);
        for e in 0..4 {
            assert!(!p.replicas(e).is_empty());
        }
    }
}
