//! The planner's analytic performance model (paper §IV-B, Eq 1–6, and the
//! scheduler-aware variant of §V-C, Eq 8).
//!
//! Estimates the execution time of one MoE layer under a lightweight
//! expert placement, from:
//!
//! * `R` — tokens received per device (A2A volume),
//! * `H` — tokens computed per device (expert FFN),
//! * `s`, `n` — number of transferred experts and excluded devices,
//! * cluster constants `B̄` (average bandwidth) and `t` (compute
//!   throughput).
//!
//! Fig 13 of the paper validates this model at <5% mean error against the
//! real system; our fig13 bench validates it against the discrete-event
//! simulator and `integration_runtime` against real PJRT timings.

use crate::cluster::ClusterSpec;
use crate::config::ModelSpec;
use crate::moe::{Placement, RoutedLoad};

/// Penalty of a coarse-grained, non-chunked, blocking parameter transfer
/// relative to the pipelined chunked collective Pro-Prophet issues
/// (calibrated so the FasterMoE baseline reproduces the paper's Table I
/// load-balancing overhead band of ~30-37%).
pub const COARSE_FACTOR: f64 = 2.0;

/// All constants the per-layer estimate needs, pre-derived from a
/// (model, cluster) pair.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub n_devices: usize,
    pub n_experts: usize,
    /// size(input): bytes of one token activation row.
    pub token_bytes: f64,
    /// size(e_j.params) == size(e_j.grads): bytes of one expert.
    pub expert_bytes: f64,
    /// B̄: average pairwise bandwidth, bytes/s.
    pub avg_bw: f64,
    /// t: expert-FFN compute throughput, tokens/s per device.
    pub tokens_per_s: f64,
    /// Forward / backward time of the non-MoE half of a block (FNEC/BNEC),
    /// seconds — static, estimated before training (paper §V-B).
    pub t_fnec: f64,
    pub t_bnec: f64,
    /// Cost of one run of the greedy search (the Plan primitive).  Charged
    /// to baselines that search on the critical path; measured values can
    /// be plugged in via [`PerfModel::with_plan_time`].
    pub t_plan: f64,
    /// Per-device compute slowdown factors, mirrored from
    /// [`ClusterSpec::device_slowdown`] (empty = homogeneous).  The
    /// Eq 1–6/8 estimates deliberately ignore them (frozen semantics);
    /// only the slack-aware relaxed estimate
    /// ([`PerfModel::layer_time_sn_relaxed`]) reads them.
    pub device_slowdown: Vec<f64>,
}

impl PerfModel {
    pub fn new(model: &ModelSpec, cluster: &ClusterSpec) -> Self {
        let d = cluster.n_devices();
        let tokens_per_device = model.tokens_per_device(d) as f64;
        let eff_flops = cluster.gpu_tflops * 1e12 * cluster.mfu;
        let t_fnec = tokens_per_device * model.non_moe_flops_per_token() / eff_flops;
        // Empirically backward ≈ 2x forward (the paper's Eq 3 assumption).
        let t_bnec = 2.0 * t_fnec;
        // Analytic Plan cost: the greedy search is O(E·D) work on the CPU;
        // ~1 µs per (expert, device) cell keeps it in the low-millisecond
        // range the paper's Table I "Search" column reports.
        let e = model.n_experts;
        let t_plan = 1.0e-6 * (e * d) as f64 + 2.0e-4;
        PerfModel {
            n_devices: d,
            n_experts: e,
            token_bytes: model.token_bytes(),
            expert_bytes: model.expert_param_bytes(),
            avg_bw: cluster.avg_bandwidth(),
            tokens_per_s: cluster.tokens_per_sec(model.ffn_flops_per_token()),
            t_fnec,
            t_bnec,
            t_plan,
            device_slowdown: cluster.device_slowdown.clone(),
        }
    }

    pub fn with_plan_time(mut self, t_plan: f64) -> Self {
        self.t_plan = t_plan;
        self
    }

    /// Whether any device deviates from the homogeneous baseline
    /// (mirrors [`ClusterSpec::is_heterogeneous`]).
    pub fn is_heterogeneous(&self) -> bool {
        self.device_slowdown.iter().any(|&s| s != 1.0)
    }

    /// Worst per-device compute slowdown (1.0 when homogeneous).
    pub fn max_slowdown(&self) -> f64 {
        self.device_slowdown.iter().copied().fold(1.0, f64::max)
    }

    /// Slowdown factor of device `d` (1.0 when the vector is empty or
    /// shorter than `d` — a missing entry means "nominal speed").
    pub fn slowdown(&self, d: usize) -> f64 {
        self.device_slowdown.get(d).copied().unwrap_or(1.0)
    }

    /// The same model with its device-health view replaced — how the
    /// planner consumes a *forecast* slowdown vector (the DES keeps
    /// pricing on the true effective engine; only the candidate-ranking
    /// view changes).
    pub fn with_device_slowdown(&self, v: Vec<f64>) -> PerfModel {
        let mut pm = self.clone();
        pm.device_slowdown = v;
        pm
    }

    // --- primitive costs ---------------------------------------------------

    /// Eq 1: T_A2A(R) = max_i R_i * size(input) / B̄.
    pub fn t_a2a(&self, r: &[u64]) -> f64 {
        let max_r = r.iter().copied().max().unwrap_or(0) as f64;
        max_r * self.token_bytes / self.avg_bw
    }

    /// Eq 2: T_FEC(H) = max_i H_i / t.
    pub fn t_fec(&self, h: &[u64]) -> f64 {
        let max_h = h.iter().copied().max().unwrap_or(0) as f64;
        max_h / self.tokens_per_s
    }

    /// Eq 3: T_BEC(H) = 2 * max_i H_i / t.
    pub fn t_bec(&self, h: &[u64]) -> f64 {
        2.0 * self.t_fec(h)
    }

    /// Eq 4: T_Trans(s, n) = s (D - n) size(params) / (D B̄).
    pub fn t_trans_sn(&self, s: usize, n: usize) -> f64 {
        let d = self.n_devices as f64;
        s as f64 * (d - n as f64).max(0.0) * self.expert_bytes / (d * self.avg_bw)
    }

    /// Eq 5: T_Agg(s, n) — same volume as Trans (gradients mirror params).
    pub fn t_agg_sn(&self, s: usize, n: usize) -> f64 {
        self.t_trans_sn(s, n)
    }

    /// Trans cost of the COARSE transfer prior systems use (FasterMoE-style
    /// shadowing, top-k-to-all): a broadcast of the full parameters to ALL
    /// devices with no sub-operator chunking and a blocking launch — the
    /// "heavy communication of model states" of the paper's §I-(1).
    /// Modeled as the collective cost at n = 0 times [`COARSE_FACTOR`].
    pub fn t_trans_coarse(&self, s: usize) -> f64 {
        COARSE_FACTOR * self.t_trans_sn(s, 0)
    }

    /// Placement-general Trans cost: each selected expert contributes its
    /// replica count (= D - n_e in the paper's notation).
    pub fn t_trans(&self, p: &Placement) -> f64 {
        let d = self.n_devices as f64;
        let copies: usize = p
            .transferred_experts()
            .iter()
            .map(|&e| p.replicas(e).len())
            .sum();
        copies as f64 * self.expert_bytes / (d * self.avg_bw)
    }

    pub fn t_agg(&self, p: &Placement) -> f64 {
        self.t_trans(p)
    }

    // --- whole-layer estimates ----------------------------------------------

    /// Eq 6: blocking execution of one MoE layer under a placement.
    /// 4 A2A (2 fwd + 2 bwd), 3 FEC-equivalents (1 fwd + 2 bwd), plus the
    /// un-overlapped Trans and Agg primitives.
    pub fn layer_time_blocking(&self, routed: &RoutedLoad, p: &Placement) -> f64 {
        4.0 * self.t_a2a(&routed.r)
            + 3.0 * self.t_fec(&routed.h)
            + self.t_trans(p)
            + self.t_agg(p)
    }

    /// Eq 8: scheduler-aware estimate — Trans hides under FEC + FNEC and
    /// Agg under BEC + BNEC; only the overflow is paid.
    pub fn layer_time_overlapped(&self, routed: &RoutedLoad, p: &Placement) -> f64 {
        let t_fec = self.t_fec(&routed.h);
        let t_bec = self.t_bec(&routed.h);
        let p_trans = (self.t_trans(p) - t_fec - self.t_fnec).max(0.0);
        let p_agg = (self.t_agg(p) - t_bec - self.t_bnec).max(0.0);
        4.0 * self.t_a2a(&routed.r) + 3.0 * t_fec + p_trans + p_agg
    }

    /// Estimate under the (s, n) aggregate form the greedy search uses.
    pub fn layer_time_sn(
        &self,
        routed: &RoutedLoad,
        s: usize,
        n: usize,
        overlapped: bool,
    ) -> f64 {
        self.layer_time_sn_from_maxes(routed.max_h(), routed.max_r(), s, n, overlapped)
    }

    /// Delta-friendly form of [`PerfModel::layer_time_sn`]: Eq 1–3 only
    /// ever read max(H) and max(R), so an incremental router that tracks
    /// the maxima (see [`crate::moe::RoutingState::evaluate`]) can price a
    /// candidate without materializing the H/R vectors.  Same arithmetic,
    /// bit-identical result.
    pub fn layer_time_sn_from_maxes(
        &self,
        max_h: u64,
        max_r: u64,
        s: usize,
        n: usize,
        overlapped: bool,
    ) -> f64 {
        let t_fec = max_h as f64 / self.tokens_per_s;
        let t_a2a = max_r as f64 * self.token_bytes / self.avg_bw;
        let a2a = 4.0 * t_a2a + 3.0 * t_fec;
        if overlapped {
            let t_bec = 2.0 * t_fec;
            let p_trans = (self.t_trans_sn(s, n) - t_fec - self.t_fnec).max(0.0);
            let p_agg = (self.t_agg_sn(s, n) - t_bec - self.t_bnec).max(0.0);
            a2a + p_trans + p_agg
        } else {
            a2a + self.t_trans_sn(s, n) + self.t_agg_sn(s, n)
        }
    }

    /// Slack-aware per-candidate estimate for
    /// [`crate::balancer::ScheduleKind::DagRelaxed`] policies: the Eq-8
    /// overlapped form with the expert-compute terms scaled by the
    /// cluster's worst [`PerfModel::device_slowdown`] factor — the
    /// critical path of the relaxed DAG runs through the slowest device's
    /// expert compute, which both costs more (the `3·t_fec` term) and
    /// hides more transfer (the subtracted FEC/BEC windows).  The static
    /// non-MoE windows (`t_fnec`/`t_bnec`, §V-B) are deliberately NOT
    /// scaled: inflating them would let a transfer-dominated candidate's
    /// estimate DROP as the straggler gets slower (the window subtraction
    /// outgrowing the `3·t_fec` charge); with them fixed the derivative
    /// in `slow` is `3·t_fec' − t_fec'·[trans exposed] − 2·t_fec'·[agg
    /// exposed] >= 0`, so the estimate is monotone non-decreasing in the
    /// slowdown (property-tested).
    ///
    /// On a homogeneous cluster (`max_slowdown() == 1.0`) this is
    /// **bit-identical** to `layer_time_sn_from_maxes(.., true)` — the
    /// slack path cannot perturb frozen planning decisions
    /// (property-tested in `prop_slack_estimate_frozen_when_homogeneous`).
    /// The whole-iteration upper bound the DES validates against is
    /// [`crate::scheduler::relaxed_makespan_bound`]; this per-candidate
    /// form is the O(1) ranking model the greedy search can afford to
    /// call per selection step.
    pub fn layer_time_sn_relaxed(&self, max_h: u64, max_r: u64, s: usize, n: usize) -> f64 {
        let slow = self.max_slowdown();
        let t_fec = max_h as f64 * slow / self.tokens_per_s;
        let t_a2a = max_r as f64 * self.token_bytes / self.avg_bw;
        let a2a = 4.0 * t_a2a + 3.0 * t_fec;
        let t_bec = 2.0 * t_fec;
        let p_trans = (self.t_trans_sn(s, n) - t_fec - self.t_fnec).max(0.0);
        let p_agg = (self.t_agg_sn(s, n) - t_bec - self.t_bnec).max(0.0);
        a2a + p_trans + p_agg
    }

    /// Per-device-aware estimate: [`PerfModel::layer_time_sn_from_maxes`]
    /// with the expert-compute bottleneck taken as the *weighted* maximum
    /// `wmax_h = max_d H_d · slowdown_d` (slowdown-seconds of work on the
    /// device that finishes last) instead of the raw token maximum — the
    /// fix for heterogeneous candidate mispricing: a candidate that piles
    /// tokens onto a 2× straggler now prices strictly above one that
    /// routes the same tokens to a nominal device, where the scalar
    /// `max_slowdown()` form ([`PerfModel::layer_time_sn_relaxed`])
    /// charged both identically.
    ///
    /// Every other term is byte-for-byte the frozen arithmetic, so:
    ///
    /// * uniform slowdown `u` on every device ⇒ `wmax_h = max_h·u` (f64
    ///   multiplication by a positive constant is monotone) and the
    ///   overlapped form is **bit-identical** to `layer_time_sn_relaxed`;
    /// * homogeneous cluster (`u = 1.0`) ⇒ bit-identical to
    ///   `layer_time_sn_from_maxes` (the planner never calls this there —
    ///   the gate is `is_heterogeneous()` — but the identity is what the
    ///   property tests pin).
    pub fn layer_time_sn_weighted(
        &self,
        wmax_h: f64,
        max_r: u64,
        s: usize,
        n: usize,
        overlapped: bool,
    ) -> f64 {
        let t_fec = wmax_h / self.tokens_per_s;
        let t_a2a = max_r as f64 * self.token_bytes / self.avg_bw;
        let a2a = 4.0 * t_a2a + 3.0 * t_fec;
        if overlapped {
            let t_bec = 2.0 * t_fec;
            let p_trans = (self.t_trans_sn(s, n) - t_fec - self.t_fnec).max(0.0);
            let p_agg = (self.t_agg_sn(s, n) - t_bec - self.t_bnec).max(0.0);
            a2a + p_trans + p_agg
        } else {
            a2a + self.t_trans_sn(s, n) + self.t_agg_sn(s, n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::LoadMatrix;

    fn setup() -> (ModelSpec, ClusterSpec, PerfModel) {
        let m = ModelSpec::moe_gpt_s(4, 1, 4096);
        let c = ClusterSpec::hpwnv(1);
        let pm = PerfModel::new(&m, &c);
        (m, c, pm)
    }

    #[test]
    fn a2a_is_max_over_devices() {
        let (_, _, pm) = setup();
        let t1 = pm.t_a2a(&[100, 0, 0, 0]);
        let t2 = pm.t_a2a(&[100, 100, 100, 100]);
        assert!((t1 - t2).abs() < 1e-15, "A2A is bottlenecked by max R_i");
        assert!(pm.t_a2a(&[200, 0, 0, 0]) > t1);
        assert_eq!(pm.t_a2a(&[]), 0.0);
    }

    #[test]
    fn bec_is_twice_fec() {
        let (_, _, pm) = setup();
        let h = [50, 10, 10, 10];
        assert!((pm.t_bec(&h) - 2.0 * pm.t_fec(&h)).abs() < 1e-18);
    }

    #[test]
    fn trans_eq4_literal() {
        let (_, _, pm) = setup();
        // s=2 experts to (D-n)=3 of 4 devices.
        let expect = 2.0 * 3.0 * pm.expert_bytes / (4.0 * pm.avg_bw);
        assert!((pm.t_trans_sn(2, 1) - expect).abs() < 1e-15);
        assert_eq!(pm.t_trans_sn(0, 0), 0.0);
        assert!((pm.t_agg_sn(2, 1) - pm.t_trans_sn(2, 1)).abs() < 1e-18);
    }

    #[test]
    fn placement_trans_matches_sn_form() {
        let (_, _, pm) = setup();
        let mut p = Placement::identity(4, 4);
        // Replicate expert 0 to all but one device: |replicas| = 3 = D - n
        // with n = 1.
        p.replicate_except(0, &[3]);
        assert!((pm.t_trans(&p) - pm.t_trans_sn(1, 1)).abs() < 1e-15);
    }

    #[test]
    fn balanced_load_is_faster() {
        let (_, _, pm) = setup();
        let skew = LoadMatrix::from_rows(vec![
            vec![700, 100, 100, 100],
            vec![700, 100, 100, 100],
            vec![700, 100, 100, 100],
            vec![700, 100, 100, 100],
        ]);
        let ident = Placement::identity(4, 4);
        let t_skew = pm.layer_time_blocking(&skew.route(&ident), &ident);
        // Shadow expert 0 everywhere: load balances, some trans cost.
        let mut p = Placement::identity(4, 4);
        p.replicate_to_all(0);
        let t_bal = pm.layer_time_blocking(&skew.route(&p), &p);
        assert!(
            t_bal < t_skew,
            "balancing should win on a heavily skewed load: {t_bal} vs {t_skew}"
        );
    }

    #[test]
    fn overlap_never_slower_than_blocking() {
        let (_, _, pm) = setup();
        let w = LoadMatrix::from_rows(vec![
            vec![500, 200, 200, 124],
            vec![400, 300, 200, 124],
            vec![600, 100, 200, 124],
            vec![500, 200, 200, 124],
        ]);
        for spec in 0..3u32 {
            let mut p = Placement::identity(4, 4);
            if spec >= 1 {
                p.replicate_to_all(0);
            }
            if spec >= 2 {
                p.replicate_except(1, &[2]);
            }
            let routed = w.route(&p);
            assert!(
                pm.layer_time_overlapped(&routed, &p)
                    <= pm.layer_time_blocking(&routed, &p) + 1e-15
            );
        }
    }

    #[test]
    fn eq8_fully_hidden_when_small() {
        let (_, _, pm) = setup();
        let w = LoadMatrix::from_rows(vec![vec![4000, 1000, 1000, 1000]; 4]);
        let mut p = Placement::identity(4, 4);
        p.replicate_to_all(0);
        let routed = w.route(&p);
        // If Trans < FEC + FNEC, overlapped == pure compute/comm time.
        let base = 4.0 * pm.t_a2a(&routed.r) + 3.0 * pm.t_fec(&routed.h);
        if pm.t_trans(&p) <= pm.t_fec(&routed.h) + pm.t_fnec {
            assert!((pm.layer_time_overlapped(&routed, &p) - base).abs() < 1e-15);
        }
    }

    #[test]
    fn sn_form_matches_general_form() {
        let (_, _, pm) = setup();
        let w = LoadMatrix::from_rows(vec![vec![500, 100, 100, 100]; 4]);
        let mut p = Placement::identity(4, 4);
        p.replicate_except(0, &[3]);
        let routed = w.route(&p);
        let a = pm.layer_time_sn(&routed, 1, 1, false);
        let b = pm.layer_time_blocking(&routed, &p);
        assert!((a - b).abs() < 1e-15);
        let ao = pm.layer_time_sn(&routed, 1, 1, true);
        let bo = pm.layer_time_overlapped(&routed, &p);
        assert!((ao - bo).abs() < 1e-15);
    }

    #[test]
    fn sn_from_maxes_is_bit_identical() {
        let (_, _, pm) = setup();
        let routed = RoutedLoad {
            h: vec![530, 210, 377, 512],
            r: vec![12, 300, 7, 0],
            sent: vec![0, 0, 0, 319],
        };
        for overlapped in [false, true] {
            for (s, n) in [(0, 0), (1, 1), (3, 2)] {
                let a = pm.layer_time_sn(&routed, s, n, overlapped);
                let b = pm.layer_time_sn_from_maxes(530, 300, s, n, overlapped);
                assert_eq!(a.to_bits(), b.to_bits(), "s={s} n={n} ov={overlapped}");
            }
        }
    }

    #[test]
    fn slack_estimate_matches_overlapped_when_homogeneous() {
        let (_, _, pm) = setup();
        assert!(!pm.is_heterogeneous());
        assert_eq!(pm.max_slowdown(), 1.0);
        for (max_h, max_r, s, n) in [(530u64, 300u64, 0usize, 0usize), (1200, 40, 2, 1), (64, 64, 3, 2)]
        {
            let frozen = pm.layer_time_sn_from_maxes(max_h, max_r, s, n, true);
            let slack = pm.layer_time_sn_relaxed(max_h, max_r, s, n);
            assert_eq!(frozen.to_bits(), slack.to_bits(), "h={max_h} r={max_r} s={s} n={n}");
        }
    }

    #[test]
    fn slack_estimate_sees_the_straggler() {
        let m = ModelSpec::moe_gpt_s(4, 1, 4096);
        let c = ClusterSpec::hpwnv(1);
        let pm_homo = PerfModel::new(&m, &c);
        let pm_het = PerfModel::new(&m, &c.clone().with_slowdown(2, 2.5));
        assert!(pm_het.is_heterogeneous());
        assert_eq!(pm_het.max_slowdown(), 2.5);
        // The frozen estimates ignore the slowdown entirely...
        let frozen_h = pm_het.layer_time_sn_from_maxes(500, 100, 1, 1, true);
        let frozen_o = pm_homo.layer_time_sn_from_maxes(500, 100, 1, 1, true);
        assert_eq!(frozen_h.to_bits(), frozen_o.to_bits());
        // ...while the slack-aware one charges the slow device's compute.
        let slack = pm_het.layer_time_sn_relaxed(500, 100, 1, 1);
        assert!(
            slack > pm_homo.layer_time_sn_relaxed(500, 100, 1, 1),
            "slack estimate must grow with the straggler"
        );
    }

    #[test]
    fn weighted_estimate_bit_identical_when_uniform() {
        let (_, _, pm) = setup();
        // Homogeneous: wmax_h == max_h as f64, both branches reproduce
        // the frozen from_maxes form bit-for-bit.
        for overlapped in [false, true] {
            for (max_h, max_r, s, n) in [(530u64, 300u64, 0usize, 0usize), (1200, 40, 2, 1)] {
                let frozen = pm.layer_time_sn_from_maxes(max_h, max_r, s, n, overlapped);
                let weighted = pm.layer_time_sn_weighted(max_h as f64, max_r, s, n, overlapped);
                assert_eq!(frozen.to_bits(), weighted.to_bits(), "ov={overlapped}");
            }
        }
        // Uniform heterogeneous slowdown: the overlapped weighted form
        // with wmax_h = max_h·u is bit-identical to the worst-scalar
        // relaxed estimate (same t_fec expression, same tail).
        let m = ModelSpec::moe_gpt_s(4, 1, 4096);
        let c = ClusterSpec::hpwnv(1);
        let pm_u = PerfModel::new(&m, &c.clone().with_slowdowns(vec![2.5; 4]));
        for (max_h, max_r, s, n) in [(500u64, 100u64, 1usize, 1usize), (64, 64, 3, 2)] {
            let relaxed = pm_u.layer_time_sn_relaxed(max_h, max_r, s, n);
            let weighted = pm_u.layer_time_sn_weighted(max_h as f64 * 2.5, max_r, s, n, true);
            assert_eq!(relaxed.to_bits(), weighted.to_bits(), "h={max_h} r={max_r}");
        }
    }

    #[test]
    fn weighted_estimate_separates_straggler_candidates() {
        // The mispricing this PR fixes: same raw max_h, but one candidate
        // bottlenecks on the 2.5x straggler and the other on a nominal
        // device — the scalar relaxed form prices them identically, the
        // weighted form strictly separates them.
        let m = ModelSpec::moe_gpt_s(4, 1, 4096);
        let c = ClusterSpec::hpwnv(1).with_slowdown(2, 2.5);
        let pm = PerfModel::new(&m, &c);
        let on_straggler = pm.layer_time_sn_weighted(500.0 * 2.5, 100, 1, 1, true);
        let on_nominal = pm.layer_time_sn_weighted(500.0 * 1.0, 100, 1, 1, true);
        assert!(on_straggler > on_nominal);
        let scalar = pm.layer_time_sn_relaxed(500, 100, 1, 1);
        assert_eq!(scalar.to_bits(), on_straggler.to_bits(), "scalar charges ALL candidates the straggler rate");
    }

    #[test]
    fn slowdown_accessor_and_forecast_swap() {
        let m = ModelSpec::moe_gpt_s(4, 1, 4096);
        let pm = PerfModel::new(&m, &ClusterSpec::hpwnv(1));
        assert_eq!(pm.slowdown(0), 1.0);
        assert_eq!(pm.slowdown(99), 1.0, "out of range means nominal");
        let fc = pm.with_device_slowdown(vec![1.0, 1.0, 2.0, 1.0]);
        assert!(fc.is_heterogeneous());
        assert_eq!(fc.slowdown(2), 2.0);
        assert_eq!(fc.tokens_per_s, pm.tokens_per_s, "only the health view changes");
        assert!(!pm.is_heterogeneous(), "original untouched");
    }

    #[test]
    fn fnec_scales_with_model_width() {
        let c = ClusterSpec::hpwnv(1);
        let s = PerfModel::new(&ModelSpec::moe_gpt_s(4, 1, 4096), &c);
        let l = PerfModel::new(&ModelSpec::moe_gpt_l(4, 1, 4096), &c);
        assert!(l.t_fnec > s.t_fnec);
        assert!((l.t_bnec - 2.0 * l.t_fnec).abs() < 1e-18);
    }
}
