//! Metrics and report emission: balance degree / RB (Fig 16), speedup
//! tables, Table I breakdowns, and JSON result files under bench_results/.

use crate::util::json::{self, Json};
use crate::util::stats;
use std::collections::BTreeMap;
use std::path::Path;

/// Balance degree (paper §VI-C): the standard deviation of the input
/// distribution tensor (we apply it to per-device computed load H as the
/// paper does when comparing placements).
pub fn balance_degree(h: &[u64]) -> f64 {
    let xs: Vec<f64> = h.iter().map(|&x| x as f64).collect();
    stats::std_dev(&xs)
}

/// RB: ratio of balance degree before vs after employing a load-balancing
/// solution (>1 = the solution improved balance).
pub fn rb(before: &[u64], after: &[u64]) -> f64 {
    let b = balance_degree(before);
    let a = balance_degree(after);
    if a <= 1e-12 {
        if b <= 1e-12 {
            1.0
        } else {
            f64::INFINITY
        }
    } else {
        b / a
    }
}

/// Similarity of two non-negative load vectors in [0, 1]:
/// 1 − normalized-L1/2 (both vectors normalized to the simplex; negative
/// entries are clamped to zero).  This is THE distribution-similarity
/// core of the repo: `planner::locality::similarity` (Fig 4),
/// `prophet::drift` and [`normalized_l1`] are all thin wrappers.
pub fn similarity_f64(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ta: f64 = a.iter().map(|&x| x.max(0.0)).sum();
    let tb: f64 = b.iter().map(|&x| x.max(0.0)).sum();
    if ta <= 0.0 || tb <= 0.0 {
        return if ta == tb { 1.0 } else { 0.0 };
    }
    let l1: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x.max(0.0) / ta - y.max(0.0) / tb).abs())
        .sum();
    1.0 - 0.5 * l1
}

/// Normalized L1 forecast error between a predicted distribution and the
/// observed one, in [0, 1]: 1 − [`similarity_f64`] (0 = perfect forecast,
/// 1 = disjoint mass).  This is the per-step loss the prophet ensemble
/// minimizes online.
pub fn normalized_l1(pred: &[f64], observed: &[u64]) -> f64 {
    let o: Vec<f64> = observed.iter().map(|&x| x as f64).collect();
    1.0 - similarity_f64(pred, &o)
}

/// Cosine similarity between a forecast and an observed distribution, in
/// [0, 1] for non-negative load vectors (1 = same direction).
pub fn cosine_similarity(pred: &[f64], observed: &[u64]) -> f64 {
    assert_eq!(pred.len(), observed.len());
    let mut dot = 0.0;
    let mut np = 0.0;
    let mut no = 0.0;
    for (&p, &o) in pred.iter().zip(observed) {
        let p = p.max(0.0);
        let o = o as f64;
        dot += p * o;
        np += p * p;
        no += o * o;
    }
    if np <= 0.0 || no <= 0.0 {
        return if np == no { 1.0 } else { 0.0 };
    }
    dot / (np.sqrt() * no.sqrt())
}

/// Speedup of `baseline_time` over `t` (how many x faster we are).
pub fn speedup(baseline_time: f64, t: f64) -> f64 {
    if t <= 0.0 {
        return f64::INFINITY;
    }
    baseline_time / t
}

/// A rectangular results table printed like the paper's tables.
#[derive(Clone, Debug, Default)]
pub struct TableReport {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl TableReport {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        TableReport {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    /// Render with fixed-width columns (paper-style).
    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([5])
            .max()
            .unwrap()
            .max(self.title.len().min(24));
        let col_w = self.columns.iter().map(|c| c.len().max(9)).collect::<Vec<_>>();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!("{:label_w$}", ""));
        for (c, w) in self.columns.iter().zip(&col_w) {
            out.push_str(&format!(" | {c:>w$}", w = w));
        }
        out.push('\n');
        out.push_str(&"-".repeat(label_w + col_w.iter().map(|w| w + 3).sum::<usize>()));
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("{label:label_w$}"));
            for (v, w) in vals.iter().zip(&col_w) {
                out.push_str(&format!(" | {v:>w$.3}", w = w));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("title", json::s(&self.title)),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| json::s(c)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|(l, v)| {
                            json::obj(vec![
                                ("label", json::s(l)),
                                ("values", json::num_arr(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Write a result JSON under bench_results/ (creating the directory).
/// `PRO_PROPHET_RESULT_DIR` overrides the directory so CI and scripts
/// collect every result in one place regardless of invocation CWD.
pub fn write_result(name: &str, value: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var_os("PRO_PROPHET_RESULT_DIR")
        .filter(|v| !v.is_empty())
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| Path::new("bench_results").to_path_buf());
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string())?;
    Ok(path)
}

/// Pretty fraction formatting for breakdown tables.
pub fn pct(x: f64) -> f64 {
    (x * 1000.0).round() / 10.0
}

/// Mean of a breakdown key across per-iteration maps.
pub fn mean_breakdown(
    iters: &[BTreeMap<&'static str, f64>],
    key: &str,
) -> f64 {
    if iters.is_empty() {
        return 0.0;
    }
    iters.iter().map(|m| m.get(key).copied().unwrap_or(0.0)).sum::<f64>()
        / iters.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_degree_zero_when_even() {
        assert_eq!(balance_degree(&[5, 5, 5, 5]), 0.0);
        assert!(balance_degree(&[10, 0, 0, 0]) > 0.0);
    }

    #[test]
    fn rb_direction() {
        // Balancing [12,0,0] -> [4,4,4] gives RB = inf; -> [6,4,2] gives >1.
        assert!(rb(&[12, 0, 0], &[6, 4, 2]) > 1.0);
        assert_eq!(rb(&[4, 4, 4], &[4, 4, 4]), 1.0);
        assert!(rb(&[12, 0, 0], &[4, 4, 4]).is_infinite());
    }

    #[test]
    fn similarity_f64_core() {
        assert!((similarity_f64(&[5.0, 3.0, 2.0], &[10.0, 6.0, 4.0]) - 1.0).abs() < 1e-12);
        assert!(similarity_f64(&[10.0, 0.0], &[0.0, 10.0]) < 1e-12);
        assert_eq!(similarity_f64(&[0.0], &[0.0]), 1.0);
        assert_eq!(similarity_f64(&[1.0], &[0.0]), 0.0);
        // Negative entries are clamped, not trusted.
        assert!((similarity_f64(&[5.0, -2.0], &[5.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalized_l1_bounds() {
        // Perfect forecast (any scale): zero error.
        assert!(normalized_l1(&[2.0, 4.0, 6.0], &[1, 2, 3]) < 1e-12);
        // Disjoint mass: maximal error.
        assert!((normalized_l1(&[1.0, 0.0], &[0, 10]) - 1.0).abs() < 1e-12);
        // Empty edge cases.
        assert_eq!(normalized_l1(&[0.0, 0.0], &[0, 0]), 0.0);
        assert_eq!(normalized_l1(&[1.0, 0.0], &[0, 0]), 1.0);
    }

    #[test]
    fn cosine_similarity_direction() {
        assert!((cosine_similarity(&[1.0, 2.0], &[2, 4]) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&[1.0, 0.0], &[0, 5]) < 1e-12);
        assert_eq!(cosine_similarity(&[0.0], &[0]), 1.0);
    }

    #[test]
    fn speedup_math() {
        assert!((speedup(2.0, 1.0) - 2.0).abs() < 1e-12);
        assert!((speedup(1.0, 2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = TableReport::new("Test", &["FasterMoE", "Pro-Prophet"]);
        t.row("MoE-GPT-S", vec![1.63, 1.98]);
        t.row("MoE-GPT-M", vec![1.99, 2.22]);
        let s = t.render();
        assert!(s.contains("MoE-GPT-S"));
        assert!(s.contains("1.980"));
        assert!(s.contains("Pro-Prophet"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = TableReport::new("Test", &["a", "b"]);
        t.row("x", vec![1.0]);
    }

    #[test]
    fn table_json_roundtrip() {
        let mut t = TableReport::new("T", &["c1"]);
        t.row("r1", vec![3.5]);
        let j = t.to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("rows").unwrap().idx(0).unwrap().get("label").unwrap().as_str(),
            Some("r1")
        );
    }

    #[test]
    fn pct_rounds() {
        assert_eq!(pct(0.3456), 34.6);
    }
}
