//! Pro-Prophet: a systematic load-balancing method for efficient parallel
//! training of large-scale MoE models.
//!
//! Reproduction of Wang et al., *Pro-Prophet* (CS.DC 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`prophet`] — the profiling & forecasting subsystem the paper's
//!   "profile training statistics and use them" rests on (§III–§V): a
//!   bounded trace store of per-layer load history, a one-step-ahead
//!   predictor family (last-value / EMA / window-mean / linear-trend)
//!   behind one trait, an online ensemble that picks the best predictor
//!   per layer from rolling forecast error, and drift detection that
//!   forces replans.  Data flow: trainer/sim → `prophet::store` →
//!   `prophet::ensemble` → [`planner`].  Since PR 10 the same ensemble
//!   machinery also forecasts per-device *health*:
//!   `prophet::DeviceForecaster` learns the realized slowdown vector
//!   each iteration and (when armed via `prophet.device_forecast`)
//!   substitutes its forecast into the planner's decide view — the DES
//!   always prices ground truth.
//! * [`balancer`] — the open policy API: the [`balancer::BalancingPolicy`]
//!   trait (decide → `Decision { placement, plan_cost, comm_style,
//!   schedule_kind }`, observe ← feedback), the
//!   [`balancer::BalancerSession`] owning the shared prophet and the
//!   observe→score→drift→invalidate loop, the string-keyed policy
//!   registry behind the CLI/TOML/benches, the four paper policies as
//!   trait impls, and the FlexMoE-style dynamic re-placement baseline as
//!   the worked add-a-policy-in-one-file example.
//! * [`planner`] — the paper's §IV contribution: lightweight expert
//!   placements, the analytic performance model (Eq 1–6/8) and the
//!   locality-based greedy search (Algorithm 1), planning one iteration
//!   early on [`prophet`] forecasts.  On heterogeneous clusters the
//!   search prices candidates per device (`planner.device_aware`,
//!   default on): replicas route by projected finish time
//!   (`moe::RoutingState::evaluate_weighted`) and candidates rank by
//!   the weighted compute bottleneck
//!   (`perfmodel::PerfModel::layer_time_sn_weighted`), with homogeneous
//!   clusters bit-identical to the frozen scalar search.
//! * [`scheduler`] — the paper's §V contribution: the MoE-block scheduling
//!   space, the block-wise overlap strategy (Algorithm 2), and
//!   `scheduler::dag` — operator DAGs stored structure-of-arrays: one
//!   flat row-major duration arena, CSR dependency storage, and
//!   compressed stage-barrier edges (a `(lo, hi)` node range per op
//!   instead of materialised all-pairs edges; Algorithm 2 emitted
//!   dependency-first via `build_blockwise_dag`, barrier schedules
//!   lowered via `dag::from_schedule`).
//! * [`sim`] — a discrete-event cluster simulator standing in for the
//!   authors' GPU testbeds (see DESIGN.md §3): a thin driver over
//!   [`balancer`] sessions that prices every iteration twice — on the
//!   frozen barrier `Schedule` and on the device-level event timeline
//!   (`sim::events`: one comp+comm stream pair per device, per-device
//!   exposed/idle breakdowns, straggler identification, heterogeneous
//!   clusters via `ClusterSpec::device_slowdown`).  Policies that return
//!   `balancer::ScheduleKind::DagRelaxed` execute the true-dependency
//!   Algorithm-2 DAG on the DES instead of the barrier lowering, every
//!   iteration, with the slack-aware planner cost model ranking their
//!   placements.  The hot executor (`sim::events::execute_with`) runs
//!   over caller-owned `ExecScratch` buffers reused across layers,
//!   iterations, and fleet tenants; `sim::events::execute_reference`
//!   freezes the pre-arena executor as a bit-exact oracle alongside
//!   `sim::reference` (the pre-refactor driver + closed `Policy` enum).
//!   When a layer's placement, cost inputs, and fault view are
//!   unchanged between iterations the simulator skips re-pricing
//!   entirely and reuses the priced result (`sim.des_reuse` counter).
//! * [`runtime`] + [`trainer`] + [`coordinator`] — the execution stack:
//!   PJRT loading of the AOT'd JAX/Pallas artifacts, the end-to-end
//!   training loop, and a threaded expert-parallel coordinator with
//!   virtual devices.
//! * [`faults`] — deterministic fault injection: a seeded
//!   `FaultTimeline` (transient slowdowns, persistent degrades, device
//!   down/recover) yielding per-iteration effective slowdown vectors
//!   and down-device sets that replace the static
//!   `ClusterSpec::device_slowdown` as the DES pricing input; the
//!   balancer session reacts with health-driven replans, device-masked
//!   searches, replica failover, and a last-known-good fallback, and
//!   `sim::checkpoint` makes interrupted runs resume bit-identically.
//! * [`fleet`] — multi-job cluster simulation on top of [`balancer`]:
//!   a coordinator leasing disjoint whole-node slices of one
//!   `ClusterSpec` to bounded concurrent tenants (training jobs running
//!   captured traces, inference jobs driven by seeded Poisson/bursty
//!   arrival processes with per-request SLO accounting), FIFO /
//!   smallest-first admission with counted backpressure, demand-driven
//!   lease rebalancing under a migration budget, and fleet-wide
//!   [`faults`] timelines sliced per lease — every tenant priced by the
//!   same DES step as the single-job simulator (a one-job fleet holding
//!   the whole cluster reproduces `simulate_policy` bit-for-bit).
//! * [`obs`] — the telemetry layer the statistics flow through: a
//!   dependency-free `Recorder` trait (counters / gauges / RAII spans)
//!   with a zero-cost no-op default, the `TelemetryHub` aggregating
//!   per-iteration and whole-run metrics for the five host-side phases
//!   (prophet forecast, greedy search, balancer decide/observe, DES
//!   lower/execute, trainer step), a bounded schema-versioned JSONL
//!   sink (`--metrics`), and the `report` CLI renderer/differ.
//! * [`cluster`], [`moe`], [`workload`], [`perfmodel`], [`metrics`],
//!   [`config`], [`util`], [`benchkit`] — substrates.
//!
//! Python (JAX + Pallas) exists only at build time: `make artifacts` lowers
//! the model to HLO text under `artifacts/`, and everything at run time is
//! this crate.

pub mod balancer;
pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod faults;
pub mod fleet;
pub mod metrics;
pub mod moe;
pub mod obs;
pub mod perfmodel;
pub mod planner;
pub mod prophet;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod trainer;
pub mod util;
pub mod workload;

/// Crate version, stamped into reports.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
