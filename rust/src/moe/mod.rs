//! MoE routing statistics and expert placements.
//!
//! * [`LoadMatrix`] — the per-layer "gating result": how many tokens each
//!   device routes to each expert (the W[d][e] matrix the planner's
//!   Algorithm 1 consumes as `gating`).
//! * [`Placement`] — a *lightweight expert placement* (paper §IV-A): every
//!   expert is independently replicated onto a subset of devices; only its
//!   parameters (fwd) and gradients (bwd) move, never optimizer states.
//! * [`RoutedLoad`] — H (tokens computed per device) and R (tokens received
//!   per device) after applying a placement, the inputs of Eq 1–3.

pub mod memory;
pub mod placement;
pub mod routing;

pub use memory::MemoryModel;
pub use placement::{AllDevicesDown, Placement};
pub use routing::{EvalStats, RoutingState, WeightedEvalStats};

/// Even integer split: the share of `total` that part `idx` of `parts`
/// receives (remainder round-robined to the lowest indices, so the parts
/// always sum back to `total`).  Shared by the trainer's histogram
/// spreading and the prophet's forecast-matrix fallback.
pub fn even_split(total: u64, parts: usize, idx: usize) -> u64 {
    debug_assert!(idx < parts);
    total / parts as u64 + u64::from(idx < (total % parts as u64) as usize)
}

/// Lazily computed column sums of a [`LoadMatrix`] (the planner's greedy
/// search reads `expert_load`/`total_tokens` on every selection step, and
/// the strided column walks dominated its cost at scale — see
/// EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
struct SumCache {
    /// Tokens per expert (length E).
    distribution: Vec<u64>,
    total_tokens: u64,
}

/// Tokens routed from each source device to each expert in one MoE layer:
/// `w[d][e]` = tokens resident on device `d` whose gate picked expert `e`.
#[derive(Debug)]
pub struct LoadMatrix {
    n_devices: usize,
    n_experts: usize,
    w: Vec<u64>, // row-major [d][e]
    /// Column-sum cache; MUST be invalidated by every mutation (`set`,
    /// `add`) or stale sums leak into planning decisions.
    sums: std::sync::OnceLock<SumCache>,
    /// Test hook: full routing sweeps executed over THIS instance (each
    /// `route`/`traffic`/`route_full` call is one sweep).  The simulator
    /// is pinned to exactly one identity sweep + one placement sweep per
    /// (iteration, layer) for every [`crate::balancer::ScheduleKind`] —
    /// see `one_routing_pass_per_layer_for_every_schedule_kind` in
    /// rust/tests/integration_sim.rs.  Clones start at zero.
    routing_passes: std::sync::atomic::AtomicUsize,
}

/// Manual impl: the derived form went away when the routing-pass counter
/// arrived (atomics are not `Clone`).  The sum cache is carried over when
/// valid; the counter restarts — it counts passes over one instance.
impl Clone for LoadMatrix {
    fn clone(&self) -> Self {
        LoadMatrix {
            n_devices: self.n_devices,
            n_experts: self.n_experts,
            w: self.w.clone(),
            sums: self.sums.clone(),
            routing_passes: std::sync::atomic::AtomicUsize::new(0),
        }
    }
}

/// Equality is defined by shape and contents only — the sum cache is a
/// derived quantity and never participates.
impl PartialEq for LoadMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n_devices == other.n_devices
            && self.n_experts == other.n_experts
            && self.w == other.w
    }
}

impl LoadMatrix {
    pub fn zeros(n_devices: usize, n_experts: usize) -> Self {
        LoadMatrix {
            n_devices,
            n_experts,
            w: vec![0; n_devices * n_experts],
            sums: std::sync::OnceLock::new(),
            routing_passes: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn from_rows(rows: Vec<Vec<u64>>) -> Self {
        let n_devices = rows.len();
        let n_experts = rows.first().map_or(0, Vec::len);
        let mut w = Vec::with_capacity(n_devices * n_experts);
        for r in &rows {
            assert_eq!(r.len(), n_experts, "ragged load matrix");
            w.extend_from_slice(r);
        }
        LoadMatrix {
            n_devices,
            n_experts,
            w,
            sums: std::sync::OnceLock::new(),
            routing_passes: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// Test hook: routing sweeps (`route`/`traffic`/`route_full`)
    /// executed over this instance since construction (or clone).
    pub fn routing_passes(&self) -> usize {
        self.routing_passes.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    #[inline]
    pub fn get(&self, device: usize, expert: usize) -> u64 {
        self.w[device * self.n_experts + expert]
    }

    #[inline]
    pub fn set(&mut self, device: usize, expert: usize, v: u64) {
        self.w[device * self.n_experts + expert] = v;
        let _ = self.sums.take();
    }

    #[inline]
    pub fn add(&mut self, device: usize, expert: usize, v: u64) {
        self.w[device * self.n_experts + expert] += v;
        let _ = self.sums.take();
    }

    /// Column sums, computed once and cached until the next mutation.
    fn sums(&self) -> &SumCache {
        self.sums.get_or_init(|| {
            let mut distribution = vec![0u64; self.n_experts];
            let mut total = 0u64;
            for d in 0..self.n_devices {
                let row = &self.w[d * self.n_experts..(d + 1) * self.n_experts];
                for (acc, &v) in distribution.iter_mut().zip(row) {
                    *acc += v;
                }
            }
            for &v in &distribution {
                total += v;
            }
            SumCache { distribution, total_tokens: total }
        })
    }

    /// Total tokens routed to `expert` from all devices — the "input
    /// distribution" entry the paper profiles (Fig 3/4).
    pub fn expert_load(&self, expert: usize) -> u64 {
        self.sums().distribution[expert]
    }

    /// The full input distribution (length E).
    pub fn distribution(&self) -> Vec<u64> {
        self.sums().distribution.clone()
    }

    /// Borrowed view of the input distribution (no clone).
    pub fn distribution_slice(&self) -> &[u64] {
        &self.sums().distribution
    }

    pub fn total_tokens(&self) -> u64 {
        self.sums().total_tokens
    }

    /// Tokens resident on a device (its DP shard contribution).
    pub fn device_tokens(&self, device: usize) -> u64 {
        (0..self.n_experts).map(|e| self.get(device, e)).sum()
    }

    /// Home device of an expert under the traditional EP layout
    /// (one expert per device when E == D, else round-robin).
    pub fn home(&self, expert: usize) -> usize {
        expert % self.n_devices
    }

    /// Route tokens under `placement` (the paper's `Replace_Inputs`).
    ///
    /// Rule (paper Fig 6): a token batch on device `d` destined for expert
    /// `e` is computed **locally** if `d` holds a replica of `e`;
    /// otherwise it is sent to the currently least-loaded replica of `e`
    /// (its home when `e` is not replicated).  Returns the per-device
    /// computed (H) and received (R) token counts of the performance
    /// model.
    pub fn route(&self, placement: &Placement) -> RoutedLoad {
        // Skips the traffic-matrix allocation.  NOTE: the planner's hot
        // path no longer calls this per candidate — the greedy search
        // replays deltas on [`RoutingState`], which is equivalence-gated
        // against this function (see EXPERIMENTS.md §Perf).
        self.route_impl(placement, false).0
    }

    /// Per-pair A2A traffic under `placement`: `traffic[src][dst]` = tokens
    /// moving from device `src` to device `dst` (src != dst).  Used by the
    /// discrete-event simulator, which prices each pair at its actual link
    /// bandwidth instead of the performance model's B̄ aggregate.
    pub fn traffic(&self, placement: &Placement) -> Vec<Vec<u64>> {
        self.route_impl(placement, true).1.unwrap()
    }

    /// Routing + traffic matrix in one deterministic pass.
    pub fn route_full(&self, placement: &Placement) -> (RoutedLoad, Vec<Vec<u64>>) {
        let (routed, traffic) = self.route_impl(placement, true);
        (routed, traffic.unwrap())
    }

    fn route_impl(
        &self,
        placement: &Placement,
        want_traffic: bool,
    ) -> (RoutedLoad, Option<Vec<Vec<u64>>>) {
        self.routing_passes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        assert_eq!(placement.n_experts(), self.n_experts);
        assert_eq!(placement.n_devices(), self.n_devices);
        let mut h = vec![0u64; self.n_devices];
        let mut r = vec![0u64; self.n_devices];
        let mut sent = vec![0u64; self.n_devices];
        let mut traffic = if want_traffic {
            Some(vec![vec![0u64; self.n_devices]; self.n_devices])
        } else {
            None
        };
        // Pass 1: local tokens stay put.
        let mut remote: Vec<(usize, usize, u64)> = Vec::new(); // (src, expert, n)
        for d in 0..self.n_devices {
            for e in 0..self.n_experts {
                let tokens = self.get(d, e);
                if tokens == 0 {
                    continue;
                }
                if placement.replicas(e).contains(d) {
                    h[d] += tokens;
                } else {
                    remote.push((d, e, tokens));
                }
            }
        }
        // Pass 2: remote batches go to the least-loaded replica (ties ->
        // lowest device id; the home is the only replica when e is not
        // replicated).  Heaviest batches placed first for better packing.
        // Replica sets are materialized once (BitSet iteration inside the
        // loop dominated the planner's Plan cost; see EXPERIMENTS.md §Perf).
        let replica_lists: Vec<Vec<u32>> = (0..self.n_experts)
            .map(|e| placement.replicas(e).iter().map(|d| d as u32).collect())
            .collect();
        remote.sort_unstable_by_key(|&(d, e, n)| (std::cmp::Reverse(n), d, e));
        for (d, e, tokens) in remote {
            let list = &replica_lists[e];
            let target = if list.is_empty() {
                self.home(e)
            } else {
                let mut best = list[0] as usize;
                for &cand in &list[1..] {
                    if h[cand as usize] < h[best] {
                        best = cand as usize;
                    }
                }
                best
            };
            h[target] += tokens;
            if target != d {
                r[target] += tokens;
                sent[d] += tokens;
                if let Some(t) = traffic.as_mut() {
                    t[d][target] += tokens;
                }
            }
        }
        (RoutedLoad { h, r, sent }, traffic)
    }

    /// Routed load of the traditional (identity) placement.
    pub fn route_identity(&self) -> RoutedLoad {
        self.route(&Placement::identity(self.n_experts, self.n_devices))
    }

}

/// Per-device load after routing: the H and R vectors of Table II.
#[derive(Clone, Debug, PartialEq)]
pub struct RoutedLoad {
    /// H_i: tokens computed on device i.
    pub h: Vec<u64>,
    /// R_i: tokens received by device i from other devices (A2A ingress).
    pub r: Vec<u64>,
    /// Tokens sent away by device i (A2A egress; max(in, out) bounds the
    /// per-device A2A time under the P2P implementation of Tutel).
    pub sent: Vec<u64>,
}

impl RoutedLoad {
    pub fn h_f64(&self) -> Vec<f64> {
        self.h.iter().map(|&x| x as f64).collect()
    }

    pub fn max_h(&self) -> u64 {
        self.h.iter().copied().max().unwrap_or(0)
    }

    pub fn min_h(&self) -> u64 {
        self.h.iter().copied().min().unwrap_or(0)
    }

    pub fn max_r(&self) -> u64 {
        self.r.iter().copied().max().unwrap_or(0)
    }

    /// The paper's balance condition (Eq 7):
    /// max(H) - min(H) < alpha * I / E.
    pub fn is_balanced(&self, alpha: f64, total_tokens: u64, n_experts: usize) -> bool {
        let spread = (self.max_h() - self.min_h()) as f64;
        spread < alpha * total_tokens as f64 / n_experts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3 devices / 3 experts; the Fig 6 example: device loads 5/2/2.
    fn fig6() -> LoadMatrix {
        // Rows = source device, cols = expert.
        // Dev0: 2 tokens -> E0, 1 -> E1; Dev1: 2 -> E0, 1 -> E2;
        // Dev2: 1 -> E0 ... chosen so expert loads are E0=5, E1=2, E2=2.
        LoadMatrix::from_rows(vec![
            vec![2, 1, 0],
            vec![2, 0, 1],
            vec![1, 1, 1],
        ])
    }

    #[test]
    fn distribution_and_totals() {
        let w = fig6();
        assert_eq!(w.distribution(), vec![5, 2, 2]);
        assert_eq!(w.total_tokens(), 9);
        assert_eq!(w.device_tokens(0), 3);
        assert_eq!(w.expert_load(0), 5);
    }

    #[test]
    fn identity_routing_matches_expert_loads() {
        let w = fig6();
        let routed = w.route_identity();
        // Every expert computed at its home: H = expert loads.
        assert_eq!(routed.h, vec![5, 2, 2]);
        // R0: E0 tokens from dev1 (2) + dev2 (1) = 3; R1: E1 tokens from
        // dev0 + dev2; R2: E2 token from dev1 (dev2's own E2 token stays).
        assert_eq!(routed.r, vec![3, 2, 1]);
        assert_eq!(routed.sent.iter().sum::<u64>(), routed.r.iter().sum::<u64>());
    }

    #[test]
    fn replication_keeps_tokens_local() {
        let w = fig6();
        // Replicate E0 everywhere: all E0 traffic vanishes.
        let mut p = Placement::identity(3, 3);
        p.replicate_to_all(0);
        let routed = w.route(&p);
        // dev0: local E0 (2). dev1: local E0 (2) + E1 home traffic from
        // dev0 and dev2 (1+1). dev2: local E0 (1) + local E2 (1) + E2 from
        // dev1 (1).
        assert_eq!(routed.h, vec![2, 4, 3]);
        // Remaining comm: dev0's E1 token stays home (E1@dev1): r[1] = 1;
        // dev1's E2 token -> dev2; dev2's E1 token -> dev1.
        assert_eq!(routed.r, vec![0, 2, 1]);
    }

    #[test]
    fn balance_condition_eq7() {
        let routed = RoutedLoad { h: vec![5, 2, 2], r: vec![], sent: vec![] };
        // spread 3 < alpha * 9/3 = 3 alpha -> needs alpha > 1.
        assert!(!routed.is_balanced(0.5, 9, 3));
        assert!(routed.is_balanced(1.5, 9, 3));
    }

    #[test]
    fn route_conserves_tokens() {
        let w = fig6();
        for p in [
            Placement::identity(3, 3),
            {
                let mut p = Placement::identity(3, 3);
                p.add_replica(0, 1);
                p
            },
        ] {
            let routed = w.route(&p);
            assert_eq!(routed.h.iter().sum::<u64>(), w.total_tokens());
        }
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        LoadMatrix::from_rows(vec![vec![1, 2], vec![3]]);
    }

    #[test]
    fn mutation_invalidates_cached_sums() {
        // Regression: the column-sum cache must never survive a `set`/`add`
        // — a stale distribution would silently misdirect the planner.
        let mut w = fig6();
        assert_eq!(w.distribution(), vec![5, 2, 2]); // warm the cache
        assert_eq!(w.total_tokens(), 9);
        w.set(0, 1, 10);
        assert_eq!(w.distribution(), vec![5, 11, 2]);
        assert_eq!(w.expert_load(1), 11);
        assert_eq!(w.total_tokens(), 18);
        let _ = w.distribution_slice(); // warm again
        w.add(2, 2, 5);
        assert_eq!(w.distribution_slice(), &[5, 11, 7]);
        assert_eq!(w.total_tokens(), 23);
    }

    #[test]
    fn routing_pass_counter_counts_sweeps() {
        let w = fig6();
        assert_eq!(w.routing_passes(), 0);
        let p = Placement::identity(3, 3);
        let _ = w.route(&p);
        assert_eq!(w.routing_passes(), 1);
        let _ = w.traffic(&p);
        assert_eq!(w.routing_passes(), 2);
        let _ = w.route_full(&p);
        assert_eq!(w.routing_passes(), 3);
        let _ = w.route_identity();
        assert_eq!(w.routing_passes(), 4);
        // Clones count their own passes from zero.
        let c = w.clone();
        assert_eq!(c.routing_passes(), 0);
        let _ = c.route(&p);
        assert_eq!((c.routing_passes(), w.routing_passes()), (1, 4));
    }

    #[test]
    fn clones_and_equality_ignore_cache_state() {
        let mut a = fig6();
        let b = fig6();
        assert_eq!(a.total_tokens(), 9); // a cached, b not
        assert_eq!(a, b);
        let c = a.clone(); // clone carries the (valid) cache
        assert_eq!(c.distribution(), b.distribution());
        a.set(0, 0, 0);
        assert_ne!(a, b);
        assert_eq!(c, b, "clone must be unaffected by the original's mutation");
    }
}
