//! Lightweight expert placement (paper §IV-A).
//!
//! Each expert `e` is independently mapped to a replica set of devices that
//! always includes its home device.  Under a placement, only the expert's
//! parameters (forward, `Trans`) and gradients (backward, `Agg`) are
//! communicated, and only among the replica devices — never the optimizer
//! states, which stay at home (the ZeRO-style split the paper exploits).

use crate::util::bitset::BitSet;
use std::fmt;

/// Typed error of [`Placement::fail_over`]: the health mask marks every
/// device down, so there is no live device to fail over to.  Callers must
/// treat this as "nothing can run" (the simulator refuses the iteration,
/// the fleet parks the job) — it is NOT a repairable placement state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllDevicesDown;

impl fmt::Display for AllDevicesDown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "every device is down: no live device to fail experts over to")
    }
}

impl std::error::Error for AllDevicesDown {}

#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    replicas: Vec<BitSet>, // indexed by expert
    n_devices: usize,
}

impl Placement {
    /// Traditional EP placement: expert e only on its home device e % D.
    pub fn identity(n_experts: usize, n_devices: usize) -> Self {
        let replicas = (0..n_experts)
            .map(|e| BitSet::singleton(n_devices, e % n_devices))
            .collect();
        Placement { replicas, n_devices }
    }

    pub fn n_experts(&self) -> usize {
        self.replicas.len()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn home(&self, expert: usize) -> usize {
        expert % self.n_devices
    }

    pub fn replicas(&self, expert: usize) -> &BitSet {
        &self.replicas[expert]
    }

    /// Add one replica of `expert` on `device`.
    pub fn add_replica(&mut self, expert: usize, device: usize) {
        self.replicas[expert].insert(device);
    }

    /// Replicate `expert` onto every device (FasterMoE-style shadowing).
    pub fn replicate_to_all(&mut self, expert: usize) {
        self.replicas[expert] = BitSet::full(self.n_devices);
    }

    /// Replicate `expert` onto all devices EXCEPT `excluded` (the paper's
    /// greedy step: skip the n devices with the fewest inputs for it).
    /// The home device is always retained.  Mutates the existing replica
    /// set in place — no allocation on the planner's hot path.
    pub fn replicate_except(&mut self, expert: usize, excluded: &[usize]) {
        let home = self.home(expert);
        let set = &mut self.replicas[expert];
        set.insert_all();
        for &d in excluded {
            set.remove(d);
        }
        set.insert(home);
    }

    /// Reset to the identity placement, reusing the existing bitsets when
    /// the shape matches (the incremental router re-inits once per search).
    pub(crate) fn reset_identity(&mut self, n_experts: usize, n_devices: usize) {
        if self.n_experts() == n_experts && self.n_devices() == n_devices {
            for e in 0..n_experts {
                self.set_replicas(e, [e % n_devices]);
            }
        } else {
            *self = Placement::identity(n_experts, n_devices);
        }
    }

    /// Replace `expert`'s replica set with exactly `devices` (in place).
    /// Used by the incremental router's undo path; the caller is
    /// responsible for keeping the home replica (see [`Placement::validate`]).
    pub fn set_replicas(&mut self, expert: usize, devices: impl IntoIterator<Item = usize>) {
        let set = &mut self.replicas[expert];
        set.clear();
        for d in devices {
            set.insert(d);
        }
    }

    /// Experts with more than one replica (the paper's `s` = |selected|).
    pub fn transferred_experts(&self) -> Vec<usize> {
        (0..self.n_experts())
            .filter(|&e| self.replicas[e].len() > 1)
            .collect()
    }

    pub fn is_identity(&self) -> bool {
        self.transferred_experts().is_empty()
    }

    /// Total parameter-transfer volume in expert-copies: for each selected
    /// expert, the number of devices that RECEIVE a copy (replicas minus
    /// the home, which already holds it).
    pub fn transfer_copies(&self) -> u64 {
        self.transferred_experts()
            .iter()
            .map(|&e| (self.replicas[e].len() - 1) as u64)
            .sum()
    }

    /// Per-expert replica counts (for reports).
    pub fn replica_counts(&self) -> Vec<usize> {
        self.replicas.iter().map(BitSet::len).collect()
    }

    /// Validity: every expert has at least its home replica, and replica
    /// sets only contain existing devices (checked by BitSet capacity).
    pub fn validate(&self) -> Result<(), String> {
        for e in 0..self.n_experts() {
            if !self.replicas[e].contains(self.home(e)) {
                return Err(format!("expert {e} lost its home replica"));
            }
            if self.replicas[e].is_empty() {
                return Err(format!("expert {e} has no replicas"));
            }
        }
        Ok(())
    }

    /// Validity under a device-health mask (`down[d]` == device `d` is
    /// out of service): every expert keeps at least one replica, none
    /// of them on a down device.  The home-replica invariant is
    /// intentionally relaxed — an expert whose home is down lives on a
    /// failover replica until the device recovers.
    pub fn validate_with_down(&self, down: &[bool]) -> Result<(), String> {
        for e in 0..self.n_experts() {
            if self.replicas[e].is_empty() {
                return Err(format!("expert {e} has no replicas"));
            }
            if let Some(d) = self.replicas[e].iter().find(|&d| down.get(d).copied().unwrap_or(false)) {
                return Err(format!("expert {e} has a replica on down device {d}"));
            }
            if !down.get(self.home(e)).copied().unwrap_or(false)
                && !self.replicas[e].contains(self.home(e))
            {
                return Err(format!("expert {e} lost its home replica"));
            }
        }
        Ok(())
    }

    /// Fail experts over off down devices, in place: every replica on a
    /// down device is dropped, and an expert stranded with no replicas
    /// gets one on the first live device scanning cyclically from its
    /// home (deterministic, so resumed runs fail over identically).
    /// With every device down there is nowhere to go — the placement is
    /// left untouched and the typed [`AllDevicesDown`] error is returned
    /// so callers surface a diagnostic instead of shipping an empty
    /// placement (the simulator refuses all-down iterations up front;
    /// the fleet parks the affected job for the tick).
    pub fn fail_over(&mut self, down: &[bool]) -> Result<(), AllDevicesDown> {
        let d = self.n_devices;
        if (0..d).all(|dev| down.get(dev).copied().unwrap_or(false)) {
            return Err(AllDevicesDown);
        }
        for e in 0..self.n_experts() {
            for dev in 0..d {
                if down.get(dev).copied().unwrap_or(false) {
                    self.replicas[e].remove(dev);
                }
            }
            if self.replicas[e].is_empty() {
                let home = self.home(e);
                for step in 0..d {
                    let dev = (home + step) % d;
                    if !down.get(dev).copied().unwrap_or(false) {
                        self.replicas[e].insert(dev);
                        break;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_properties() {
        let p = Placement::identity(8, 8);
        assert!(p.is_identity());
        assert_eq!(p.transfer_copies(), 0);
        assert!(p.validate().is_ok());
        for e in 0..8 {
            assert_eq!(p.replicas(e).iter().collect::<Vec<_>>(), vec![e]);
        }
    }

    #[test]
    fn more_experts_than_devices_round_robin() {
        let p = Placement::identity(8, 4);
        assert_eq!(p.home(5), 1);
        assert!(p.replicas(5).contains(1));
    }

    #[test]
    fn replicate_except_keeps_home() {
        let mut p = Placement::identity(4, 4);
        // Exclude everything including the home: home must survive.
        p.replicate_except(2, &[0, 1, 2, 3]);
        assert_eq!(p.replicas(2).iter().collect::<Vec<_>>(), vec![2]);
        assert!(p.validate().is_ok());

        p.replicate_except(1, &[3]);
        assert_eq!(p.replicas(1).iter().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(p.transferred_experts(), vec![1]);
        assert_eq!(p.transfer_copies(), 2);
    }

    #[test]
    fn set_replicas_replaces_exactly() {
        let mut p = Placement::identity(4, 4);
        p.replicate_to_all(1);
        p.set_replicas(1, [1usize, 3]);
        assert_eq!(p.replicas(1).iter().collect::<Vec<_>>(), vec![1, 3]);
        assert!(p.validate().is_ok());
        // Restoring the identity singleton round-trips.
        p.set_replicas(1, [1usize]);
        assert!(p.is_identity());
    }

    #[test]
    fn fail_over_strips_down_devices() {
        let mut p = Placement::identity(8, 4);
        p.replicate_to_all(0);
        p.replicate_to_all(5);
        let down = [false, true, false, false];
        p.fail_over(&down).unwrap();
        assert!(p.validate_with_down(&down).is_ok());
        // Replicated experts just lose the down member.
        assert_eq!(p.replicas(0).iter().collect::<Vec<_>>(), vec![0, 2, 3]);
        // Experts homed on the down device fail over to the next live
        // device, scanning cyclically from home.
        assert_eq!(p.replicas(1).iter().collect::<Vec<_>>(), vec![2]);
        assert_eq!(p.replicas(5).iter().collect::<Vec<_>>(), vec![0, 2, 3]);
        // Untouched experts keep their homes; plain validate now fails
        // only for the failed-over experts' missing homes.
        assert_eq!(p.replicas(2).iter().collect::<Vec<_>>(), vec![2]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn fail_over_wraps_past_trailing_down_devices() {
        let mut p = Placement::identity(4, 4);
        let down = [false, false, true, true];
        p.fail_over(&down).unwrap();
        assert!(p.validate_with_down(&down).is_ok());
        assert_eq!(p.replicas(2).iter().collect::<Vec<_>>(), vec![0]);
        assert_eq!(p.replicas(3).iter().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn fail_over_all_down_is_a_typed_error() {
        // Regression (PR 8): all devices down used to strand experts
        // with silently emptied replica sets; now it is a typed error
        // and the placement is left untouched.
        let mut q = Placement::identity(2, 2);
        q.replicate_to_all(0);
        let before = q.clone();
        assert_eq!(q.fail_over(&[true, true]), Err(AllDevicesDown));
        assert_eq!(q, before, "a refused fail_over must not mutate");
        assert!(AllDevicesDown.to_string().contains("every device is down"));
        // A short mask only covers a prefix; devices past its end are up,
        // so this is NOT the all-down case.
        assert!(q.fail_over(&[true]).is_ok());
        assert!(q.validate_with_down(&[true, false]).is_ok());
    }

    #[test]
    fn masked_validate_flags_down_replicas() {
        let p = Placement::identity(4, 4);
        assert!(p.validate_with_down(&[false; 4]).is_ok());
        let err = p.validate_with_down(&[false, true, false, false]).unwrap_err();
        assert!(err.contains("down device 1"), "{err}");
    }

    #[test]
    fn replicate_to_all_counts() {
        let mut p = Placement::identity(4, 4);
        p.replicate_to_all(0);
        p.replicate_to_all(3);
        assert_eq!(p.transferred_experts(), vec![0, 3]);
        assert_eq!(p.transfer_copies(), 6); // 3 receivers each
        assert_eq!(p.replica_counts(), vec![4, 1, 1, 4]);
    }
}
