//! Device memory accounting for expert placements.
//!
//! The paper's §VI notes that device memory constrains the trainable
//! token budget (LPWNV's 11 GB 2080 Ti only fits the four smaller models)
//! and that lightweight placements move *parameters and gradients* while
//! optimizer states stay at the expert's home (the ZeRO-style split).
//! This module prices a placement's per-device memory so the planner can
//! refuse replicas that would not fit.

use super::Placement;

/// Bytes-per-device accounting for one MoE layer group.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryModel {
    /// Parameters of ONE expert (f32), bytes.
    pub expert_param_bytes: f64,
    /// Optimizer state per parameter byte (Adam: m + v = 2.0).
    pub optimizer_multiplier: f64,
    /// Gradient buffer per replica (mirror of params) — 1.0 for f32 grads.
    pub gradient_multiplier: f64,
    /// Non-MoE residency per device (dense layers, activations, buffers).
    pub base_bytes: f64,
    /// Device HBM capacity, bytes.
    pub capacity_bytes: f64,
    /// Number of MoE layers sharing the device (placements are per layer;
    /// replicas of all layers coexist).
    pub n_layers: usize,
}

impl MemoryModel {
    pub fn new(
        expert_param_bytes: f64,
        capacity_gb: f64,
        n_layers: usize,
        base_bytes: f64,
    ) -> Self {
        MemoryModel {
            expert_param_bytes,
            optimizer_multiplier: 2.0, // Adam m + v
            gradient_multiplier: 1.0,
            base_bytes,
            capacity_bytes: capacity_gb * 1e9,
            n_layers: n_layers.max(1),
        }
    }

    /// Bytes one device holds for ONE layer under `placement`:
    /// home experts keep params + grads + optimizer states; replicas keep
    /// params + grads only (the lightweight-placement property).
    pub fn device_layer_bytes(&self, p: &Placement, device: usize) -> f64 {
        let mut bytes = 0.0;
        for e in 0..p.n_experts() {
            let is_home = p.home(e) == device;
            let has_replica = p.replicas(e).contains(device);
            if is_home {
                bytes += self.expert_param_bytes
                    * (1.0 + self.gradient_multiplier + self.optimizer_multiplier);
            } else if has_replica {
                bytes += self.expert_param_bytes * (1.0 + self.gradient_multiplier);
            }
        }
        bytes
    }

    /// Total device residency assuming every layer uses `placement`'s
    /// replica multiplicity (conservative planning estimate).
    pub fn device_bytes(&self, p: &Placement, device: usize) -> f64 {
        self.base_bytes + self.n_layers as f64 * self.device_layer_bytes(p, device)
    }

    /// Remaining headroom (can be negative).
    pub fn headroom(&self, p: &Placement, device: usize) -> f64 {
        self.capacity_bytes - self.device_bytes(p, device)
    }

    /// Does the whole placement fit on every device?
    pub fn fits(&self, p: &Placement) -> bool {
        (0..p.n_devices()).all(|d| self.headroom(p, d) >= 0.0)
    }

    /// How many EXTRA expert replicas one device can still host.
    pub fn replica_budget(&self, p: &Placement, device: usize) -> usize {
        let per_replica =
            self.expert_param_bytes * (1.0 + self.gradient_multiplier);
        let head = self.headroom(p, device);
        if head <= 0.0 || per_replica <= 0.0 {
            0
        } else {
            (head / (self.n_layers as f64 * per_replica)).floor() as usize
        }
    }

    /// Devices that can NOT accept another replica under `placement` —
    /// fed into the greedy search's exclusion list.
    pub fn full_devices(&self, p: &Placement) -> Vec<usize> {
        (0..p.n_devices())
            .filter(|&d| self.replica_budget(p, d) == 0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MemoryModel {
        // 4 MB experts, 1 GB devices, 12 layers, 100 MB base.
        MemoryModel::new(4e6, 1.0, 12, 100e6)
    }

    #[test]
    fn identity_accounting() {
        let m = model();
        let p = Placement::identity(4, 4);
        // Home expert: params + grads + 2x optimizer = 4 * 4MB per layer.
        assert_eq!(m.device_layer_bytes(&p, 0), 4.0 * 4e6);
        let total = 100e6 + 12.0 * 16e6;
        assert!((m.device_bytes(&p, 0) - total).abs() < 1.0);
        assert!(m.fits(&p));
    }

    #[test]
    fn replicas_cost_less_than_homes() {
        let m = model();
        let mut p = Placement::identity(4, 4);
        p.add_replica(0, 1);
        // Device 1: its own home (4x) + a replica (2x: params + grads).
        assert_eq!(m.device_layer_bytes(&p, 1), 4.0 * 4e6 + 2.0 * 4e6);
        // Optimizer states never move — device 0 unchanged.
        assert_eq!(m.device_layer_bytes(&p, 0), 4.0 * 4e6);
    }

    #[test]
    fn capacity_rejects_over_replication() {
        // Tiny device: only the home expert fits.
        let m = MemoryModel::new(4e6, 0.3, 12, 100e6);
        let mut p = Placement::identity(4, 4);
        assert!(m.fits(&p));
        for e in 0..4 {
            p.replicate_to_all(e);
        }
        assert!(!m.fits(&p), "full replication cannot fit in 0.3 GB");
    }

    #[test]
    fn replica_budget_counts() {
        let m = model();
        let p = Placement::identity(4, 4);
        // headroom = 1e9 - (100e6 + 12*16e6) = 708e6;
        // per replica across 12 layers = 12 * 8e6 = 96e6 -> 7 replicas.
        assert_eq!(m.replica_budget(&p, 0), 7);
        assert!(m.full_devices(&p).is_empty());
    }

    #[test]
    fn full_devices_flagged() {
        let m = MemoryModel::new(4e6, 0.35, 12, 100e6);
        let p = Placement::identity(4, 4);
        // 0.35 GB - 0.1 base - 0.192 homes = 58 MB < one 96 MB replica set.
        assert_eq!(m.full_devices(&p), vec![0, 1, 2, 3]);
    }
}
