//! Incremental routing engine for the Plan primitive.
//!
//! The greedy search (planner Algorithm 1) evaluates one candidate
//! placement per selected expert, and each evaluation used to call
//! [`LoadMatrix::route`] from scratch: allocate H/R/sent, walk all D·E
//! cells, materialize every expert's replica list, and sort the remote
//! batch list — per candidate.  [`RoutingState`] hoists everything that
//! does not depend on the candidate out of the loop:
//!
//! * the batch list `(tokens, src, expert)` is built and sorted **once**
//!   (its order — heaviest first, then source, then expert — is a fixed
//!   total order independent of the placement; only *membership* in the
//!   remote set changes, which is an O(1) bitset probe per batch);
//! * per-device local sums (`local_h`) and per-expert replica lists are
//!   maintained **incrementally**: replicating one expert is an O(D) delta
//!   (`apply_*`), and every delta can be reverted exactly (`undo`);
//! * all scratch (H/R/sent, the undo log) lives in reusable buffers, so a
//!   steady-state search performs no heap allocation.
//!
//! Equivalence contract: after any sequence of `apply_*`/`undo`,
//! [`RoutingState::evaluate`] + [`RoutingState::to_routed_load`] produce a
//! [`RoutedLoad`] **bit-identical** to `w.route(state.placement())` — the
//! replay processes the surviving remote batches in exactly the order the
//! full router sorts them into, with identical tie-breaking (least-loaded
//! replica, ties to the lowest device id).  Enforced by unit tests here
//! and by `prop_routing_state_matches_full_route` in
//! `rust/tests/property_tests.rs`; measured in EXPERIMENTS.md §Perf.

use super::{LoadMatrix, Placement, RoutedLoad};

/// Per-device maxima/minima of one evaluation — everything the perf
/// model's Eq 1–3 need (see `PerfModel::layer_time_sn_from_maxes`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalStats {
    pub max_h: u64,
    pub min_h: u64,
    pub max_r: u64,
}

/// [`EvalStats`] plus the slowdown-weighted compute bottleneck of one
/// [`RoutingState::evaluate_weighted`] pass: `weighted_max_h` is
/// `max_d H_d · slowdown_d` — the slowdown-seconds of expert work on the
/// device that finishes last (what
/// `PerfModel::layer_time_sn_weighted` prices).  The raw token
/// maxima/minima are kept unweighted: Eq 7's balance condition and Eq 1's
/// A2A volume are about token counts, not speeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightedEvalStats {
    pub max_h: u64,
    pub min_h: u64,
    pub max_r: u64,
    pub weighted_max_h: f64,
}

/// One applied delta, for the undo log: which expert changed and where its
/// previous replica list starts in the pooled `undo_devices` buffer.
#[derive(Clone, Copy, Debug)]
struct UndoFrame {
    expert: u32,
    offset: u32,
}

/// Incremental routing state (see module docs).
///
/// Buffers are reused across `init` calls, so a long-lived instance (e.g.
/// inside the planner's `SearchScratch`) allocates only while growing to
/// the largest (D, E) it has seen.
#[derive(Clone, Debug)]
pub struct RoutingState {
    n_devices: usize,
    n_experts: usize,
    placement: Placement,
    /// Ascending device ids per expert (mirrors `placement`'s bitsets;
    /// kept as flat lists for the least-loaded scan).
    replica_lists: Vec<Vec<u32>>,
    /// Pass-1 sums: tokens computed locally per device under `placement`.
    local_h: Vec<u64>,
    /// All non-zero (tokens, src, expert) batches, sorted by
    /// (heaviest, src, expert) — fixed for the lifetime of one `init`.
    batches: Vec<(u64, u32, u32)>,
    // Evaluation scratch (valid after `evaluate`).
    h: Vec<u64>,
    r: Vec<u64>,
    sent: Vec<u64>,
    // Undo machinery: previous replica lists pooled in one flat buffer.
    undo_log: Vec<UndoFrame>,
    undo_devices: Vec<u32>,
}

impl Default for RoutingState {
    fn default() -> Self {
        RoutingState {
            n_devices: 0,
            n_experts: 0,
            placement: Placement::identity(0, 0),
            replica_lists: Vec::new(),
            local_h: Vec::new(),
            batches: Vec::new(),
            h: Vec::new(),
            r: Vec::new(),
            sent: Vec::new(),
            undo_log: Vec::new(),
            undo_devices: Vec::new(),
        }
    }
}

impl RoutingState {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)attach to a load matrix, starting from the identity placement.
    /// Every later call must pass the SAME matrix to `apply_*`/`undo`.
    pub fn init(&mut self, w: &LoadMatrix) {
        let (d, e) = (w.n_devices(), w.n_experts());
        self.n_devices = d;
        self.n_experts = e;
        self.placement.reset_identity(e, d);
        self.replica_lists.resize(e, Vec::new());
        for (x, list) in self.replica_lists.iter_mut().enumerate() {
            list.clear();
            list.push((x % d.max(1)) as u32);
        }
        self.local_h.clear();
        self.local_h.resize(d, 0);
        self.batches.clear();
        for dev in 0..d {
            for x in 0..e {
                let tokens = w.get(dev, x);
                if tokens == 0 {
                    continue;
                }
                if x % d == dev {
                    self.local_h[dev] += tokens;
                } else {
                    self.batches.push((tokens, dev as u32, x as u32));
                }
            }
        }
        // Home cells (dev == home(x)) are folded into local_h and kept out
        // of the batch list: the home replica survives every apply_* and
        // every undo, so those cells can never become remote.  All other
        // non-zero cells stay listed — their locality is re-probed against
        // the live placement on each replay.
        self.batches
            .sort_unstable_by_key(|&(n, dev, x)| (std::cmp::Reverse(n), dev, x));
        self.h.clear();
        self.h.resize(d, 0);
        self.r.clear();
        self.r.resize(d, 0);
        self.sent.clear();
        self.sent.resize(d, 0);
        self.undo_log.clear();
        self.undo_devices.clear();
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Number of deltas currently applied (undo depth).
    pub fn depth(&self) -> usize {
        self.undo_log.len()
    }

    /// Per-device computed tokens of the LAST `evaluate` call.
    pub fn h(&self) -> &[u64] {
        &self.h
    }

    /// Snapshot the last evaluation as an owned [`RoutedLoad`]
    /// (bit-identical to `w.route(self.placement())`).
    pub fn to_routed_load(&self) -> RoutedLoad {
        RoutedLoad { h: self.h.clone(), r: self.r.clone(), sent: self.sent.clone() }
    }

    // --- deltas -------------------------------------------------------------

    /// Record `expert`'s current replica list on the undo log; returns the
    /// list's start offset in the pooled buffer.
    fn snapshot(&mut self, expert: usize) -> usize {
        let offset = self.undo_devices.len();
        self.undo_devices.extend_from_slice(&self.replica_lists[expert]);
        self.undo_log.push(UndoFrame { expert: expert as u32, offset: offset as u32 });
        offset
    }

    /// Refresh `local_h` and the replica list after `placement`'s set for
    /// `expert` changed from `old` (device list) to its current value.
    fn resync_expert(&mut self, w: &LoadMatrix, expert: usize, old_start: usize) {
        for i in old_start..self.undo_devices.len() {
            let dev = self.undo_devices[i] as usize;
            self.local_h[dev] -= w.get(dev, expert);
        }
        let list = &mut self.replica_lists[expert];
        list.clear();
        for dev in self.placement.replicas(expert).iter() {
            self.local_h[dev] += w.get(dev, expert);
            list.push(dev as u32);
        }
    }

    /// Delta form of [`Placement::replicate_except`]: replicate `expert`
    /// everywhere but `excluded` (home retained).  O(D).
    pub fn apply_replicate_except(&mut self, w: &LoadMatrix, expert: usize, excluded: &[usize]) {
        self.debug_check(w);
        let old_start = self.snapshot(expert);
        self.placement.replicate_except(expert, excluded);
        self.resync_expert(w, expert, old_start);
    }

    /// Delta form of [`Placement::add_replica`].  O(D).
    pub fn apply_add_replica(&mut self, w: &LoadMatrix, expert: usize, device: usize) {
        self.debug_check(w);
        let old_start = self.snapshot(expert);
        self.placement.add_replica(expert, device);
        self.resync_expert(w, expert, old_start);
    }

    /// Delta form of [`Placement::replicate_to_all`].  O(D).
    pub fn apply_replicate_to_all(&mut self, w: &LoadMatrix, expert: usize) {
        self.debug_check(w);
        let old_start = self.snapshot(expert);
        self.placement.replicate_to_all(expert);
        self.resync_expert(w, expert, old_start);
    }

    /// Revert the most recent delta exactly.  O(D).
    pub fn undo(&mut self, w: &LoadMatrix) {
        self.debug_check(w);
        let frame = self.undo_log.pop().expect("undo on an empty delta stack");
        let expert = frame.expert as usize;
        let old_start = frame.offset as usize;
        // Remove the current set's local contributions...
        for dev in self.placement.replicas(expert).iter() {
            self.local_h[dev] -= w.get(dev, expert);
        }
        // ...restore the recorded set...
        self.placement.set_replicas(
            expert,
            self.undo_devices[old_start..].iter().map(|&d| d as usize),
        );
        // ...and re-add its contributions + replica list.
        let list = &mut self.replica_lists[expert];
        list.clear();
        for &dev in &self.undo_devices[old_start..] {
            self.local_h[dev as usize] += w.get(dev as usize, expert);
            list.push(dev);
        }
        self.undo_devices.truncate(old_start);
    }

    #[inline]
    fn debug_check(&self, w: &LoadMatrix) {
        debug_assert_eq!(w.n_devices(), self.n_devices, "RoutingState fed a different matrix");
        debug_assert_eq!(w.n_experts(), self.n_experts, "RoutingState fed a different matrix");
    }

    // --- evaluation ---------------------------------------------------------

    /// Route under the current placement: replay the pre-sorted batch list
    /// against the incremental local sums.  Allocation-free; O(B) plus the
    /// least-loaded scans of replicated experts' surviving remote batches.
    pub fn evaluate(&mut self) -> EvalStats {
        self.h.copy_from_slice(&self.local_h);
        self.r.fill(0);
        self.sent.fill(0);
        for &(tokens, src, expert) in &self.batches {
            let (src, expert) = (src as usize, expert as usize);
            if self.placement.replicas(expert).contains(src) {
                continue; // became local under the current placement
            }
            let list = &self.replica_lists[expert];
            let target = if list.is_empty() {
                expert % self.n_devices
            } else {
                let mut best = list[0] as usize;
                for &cand in &list[1..] {
                    if self.h[cand as usize] < self.h[best] {
                        best = cand as usize;
                    }
                }
                best
            };
            self.h[target] += tokens;
            if target != src {
                self.r[target] += tokens;
                self.sent[src] += tokens;
            }
        }
        EvalStats {
            max_h: self.h.iter().copied().max().unwrap_or(0),
            min_h: self.h.iter().copied().min().unwrap_or(0),
            max_r: self.r.iter().copied().max().unwrap_or(0),
        }
    }

    /// Slowdown-aware routing pass: identical batch replay to
    /// [`RoutingState::evaluate`], but the least-loaded replica scan
    /// minimizes the *projected finish time* `(H_d + tokens) · slowdown_d`
    /// instead of raw tokens (an idle 10× straggler is NOT the best target
    /// for an 8-token batch when a nominal device could absorb it on top
    /// of 9 existing tokens), and the returned stats carry the weighted
    /// compute bottleneck (`max_d H_d · slowdown_d`) alongside the raw
    /// maxima.  This is the evaluator half of the heterogeneous-mispricing
    /// fix: tokens flow to the replica that *finishes first*, and
    /// candidates are priced on the device that finishes last.
    ///
    /// `slowdown[d]` is device `d`'s compute slowdown factor (missing
    /// entries mean 1.0 — nominal speed).  With a uniform vector the batch
    /// size is a common addend and the factor a common positive multiplier,
    /// so the scan's strict ordering and tie structure match the unweighted
    /// one whenever the products `(H_d + tokens) · u` are exact in f64 —
    /// the chosen targets, and therefore `h`/`r`/`sent`, are identical to
    /// [`RoutingState::evaluate`]'s (property-tested).  The frozen
    /// `evaluate` is untouched; homogeneous callers never reach this path.
    pub fn evaluate_weighted(&mut self, slowdown: &[f64]) -> WeightedEvalStats {
        let sd = |d: usize| slowdown.get(d).copied().unwrap_or(1.0);
        self.h.copy_from_slice(&self.local_h);
        self.r.fill(0);
        self.sent.fill(0);
        for &(tokens, src, expert) in &self.batches {
            let (src, expert) = (src as usize, expert as usize);
            if self.placement.replicas(expert).contains(src) {
                continue; // became local under the current placement
            }
            let list = &self.replica_lists[expert];
            let target = if list.is_empty() {
                expert % self.n_devices
            } else {
                let mut best = list[0] as usize;
                let mut best_t = (self.h[best] + tokens) as f64 * sd(best);
                for &cand in &list[1..] {
                    let cand = cand as usize;
                    let t = (self.h[cand] + tokens) as f64 * sd(cand);
                    // Strict <: ties keep the lowest device id, exactly
                    // like the unweighted scan.
                    if t < best_t {
                        best = cand;
                        best_t = t;
                    }
                }
                best
            };
            self.h[target] += tokens;
            if target != src {
                self.r[target] += tokens;
                self.sent[src] += tokens;
            }
        }
        let mut weighted_max_h = 0.0f64;
        for (d, &h) in self.h.iter().enumerate() {
            let t = h as f64 * sd(d);
            if t > weighted_max_h {
                weighted_max_h = t;
            }
        }
        WeightedEvalStats {
            max_h: self.h.iter().copied().max().unwrap_or(0),
            min_h: self.h.iter().copied().min().unwrap_or(0),
            max_r: self.r.iter().copied().max().unwrap_or(0),
            weighted_max_h,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig6() -> LoadMatrix {
        LoadMatrix::from_rows(vec![vec![2, 1, 0], vec![2, 0, 1], vec![1, 1, 1]])
    }

    fn assert_matches_full_route(rs: &mut RoutingState, w: &LoadMatrix) {
        rs.evaluate();
        let incremental = rs.to_routed_load();
        let full = w.route(rs.placement());
        assert_eq!(incremental, full, "incremental router diverged from route()");
    }

    #[test]
    fn identity_matches_route() {
        let w = fig6();
        let mut rs = RoutingState::new();
        rs.init(&w);
        assert_matches_full_route(&mut rs, &w);
        assert_eq!(rs.to_routed_load().h, vec![5, 2, 2]);
    }

    #[test]
    fn apply_matches_route_after_each_delta() {
        let w = fig6();
        let mut rs = RoutingState::new();
        rs.init(&w);
        rs.apply_replicate_to_all(&w, 0);
        assert_matches_full_route(&mut rs, &w);
        rs.apply_add_replica(&w, 1, 0);
        assert_matches_full_route(&mut rs, &w);
        rs.apply_replicate_except(&w, 2, &[0]);
        assert_matches_full_route(&mut rs, &w);
        assert_eq!(rs.depth(), 3);
    }

    #[test]
    fn undo_restores_exactly() {
        let w = fig6();
        let mut rs = RoutingState::new();
        rs.init(&w);
        rs.evaluate();
        let baseline = rs.to_routed_load();
        rs.apply_replicate_to_all(&w, 0);
        rs.apply_replicate_except(&w, 1, &[2]);
        rs.undo(&w);
        assert_matches_full_route(&mut rs, &w);
        rs.undo(&w);
        rs.evaluate();
        assert_eq!(rs.to_routed_load(), baseline);
        assert!(rs.placement().is_identity());
        assert_eq!(rs.depth(), 0);
    }

    #[test]
    fn reinit_reuses_buffers_across_shapes() {
        let mut rs = RoutingState::new();
        let w1 = fig6();
        rs.init(&w1);
        rs.apply_replicate_to_all(&w1, 0);
        assert_matches_full_route(&mut rs, &w1);
        // Different shape: must fully reset.
        let w2 = LoadMatrix::from_rows(vec![vec![10, 0, 3, 1]; 2]);
        rs.init(&w2);
        assert_matches_full_route(&mut rs, &w2);
        assert_eq!(rs.depth(), 0);
        // Same shape again: placement reset in place.
        rs.init(&w1);
        assert!(rs.placement().is_identity());
        assert_matches_full_route(&mut rs, &w1);
    }

    #[test]
    fn shrinking_delta_roundtrips() {
        // replicate_except can SHRINK a previously grown set; the local_h
        // bookkeeping must follow both directions.
        let w = fig6();
        let mut rs = RoutingState::new();
        rs.init(&w);
        rs.apply_replicate_to_all(&w, 0);
        rs.apply_replicate_except(&w, 0, &[0, 1]); // {0,1,2} -> {0 (home), 2}
        assert_matches_full_route(&mut rs, &w);
        rs.undo(&w);
        assert_matches_full_route(&mut rs, &w);
        rs.undo(&w);
        assert!(rs.placement().is_identity());
    }

    #[test]
    fn weighted_with_unit_vector_matches_evaluate() {
        // slowdown == 1.0 everywhere: products are exact, so the scan
        // order, tie-breaks, and every routed token match the frozen
        // evaluate bit-for-bit.
        let w = fig6();
        let mut rs = RoutingState::new();
        rs.init(&w);
        rs.apply_replicate_to_all(&w, 0);
        rs.apply_add_replica(&w, 1, 0);
        let plain = rs.evaluate();
        let routed_plain = rs.to_routed_load();
        for sd in [vec![1.0; 3], vec![]] {
            let weighted = rs.evaluate_weighted(&sd);
            assert_eq!(rs.to_routed_load(), routed_plain);
            assert_eq!(weighted.max_h, plain.max_h);
            assert_eq!(weighted.min_h, plain.min_h);
            assert_eq!(weighted.max_r, plain.max_r);
            assert_eq!(weighted.weighted_max_h.to_bits(), (plain.max_h as f64).to_bits());
        }
    }

    #[test]
    fn weighted_routes_around_straggler_replica() {
        // One expert replicated everywhere, all remote traffic for it
        // comes from a device that is not a replica... simplest shape:
        // 3 devices, expert 0 replicated to all; device 2 is 10x slow.
        // The raw least-loaded scan would feed the emptiest device even
        // if it is the straggler; the weighted scan must not.
        let w = LoadMatrix::from_rows(vec![
            vec![9, 0, 0], // home traffic for expert 0 on device 0
            vec![8, 0, 0], // remote batch (8, src=1, expert=0)
            vec![0, 0, 0],
        ]);
        let mut rs = RoutingState::new();
        rs.init(&w);
        rs.apply_replicate_to_all(&w, 0);
        // Unweighted: after replication the batch from device 1 is local
        // (device 1 is a replica), so force a remote decision instead:
        // shrink to replicas {0, 2}.
        rs.undo(&w);
        rs.apply_replicate_except(&w, 0, &[1]);
        let plain = rs.evaluate();
        // Device 2 is empty, device 0 carries 9 -> raw scan sends the
        // 8-token batch to device 2.
        assert_eq!(rs.to_routed_load().h, vec![9, 0, 8]);
        assert_eq!(plain.max_h, 9);
        // 10x straggler on device 2: finish time 8*10 = 80 vs 17 on
        // device 0 — the weighted scan routes to the nominal device.
        let weighted = rs.evaluate_weighted(&[1.0, 1.0, 10.0]);
        assert_eq!(rs.to_routed_load().h, vec![17, 0, 0]);
        assert_eq!(weighted.max_h, 17);
        assert_eq!(weighted.weighted_max_h, 17.0);
        // Token conservation: both passes route every token somewhere.
        let total: u64 = (0..3).map(|d| (0..3).map(|e| w.get(d, e)).sum::<u64>()).sum();
        assert_eq!(rs.to_routed_load().h.iter().sum::<u64>(), total);
    }

    #[test]
    fn zero_matrix_is_fine() {
        let w = LoadMatrix::zeros(4, 4);
        let mut rs = RoutingState::new();
        rs.init(&w);
        let stats = rs.evaluate();
        assert_eq!(stats, EvalStats { max_h: 0, min_h: 0, max_r: 0 });
        rs.apply_replicate_except(&w, 1, &[3]);
        assert_matches_full_route(&mut rs, &w);
    }
}
