//! Configuration system: model specs (the paper's Table III family),
//! training/runtime settings, and TOML file loading.
//!
//! Everything the launcher (`pro-prophet` CLI), the benches and the
//! simulator consume is described here, so experiments are reproducible
//! from a single file (see `examples/configs/`).

pub mod toml;

use crate::balancer::{registry, BalancingPolicy, ProphetOptions, ScheduleKind};
use crate::cluster::ClusterSpec;
use crate::faults::FaultTimeline;
use crate::obs::ObsConfig;
use crate::planner::PlannerConfig;
use crate::prophet::{PredictorKind, ProphetConfig};

/// One MoE-GPT variant (paper Table III).  Every FFN layer is a MoE layer;
/// the number of experts per layer equals the number of devices.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Number of transformer (MoE) blocks: "Layers" in Table III.
    pub n_layers: usize,
    /// Model width: "Embedding" in Table III.
    pub d_model: usize,
    /// Expert FFN hidden width: "Hidden" in Table III.
    pub d_ff: usize,
    /// Experts per MoE layer (== #GPUs in the paper's runs).
    pub n_experts: usize,
    /// Experts per token (top-k gate), 1 or 2 in the evaluation.
    pub k: usize,
    /// Tokens trained in one iteration across the whole cluster.
    pub tokens_per_iter: u64,
}

impl ModelSpec {
    pub fn new(
        name: &str,
        n_layers: usize,
        d_model: usize,
        d_ff: usize,
        n_experts: usize,
        k: usize,
        tokens_per_iter: u64,
    ) -> Self {
        assert!((1..=n_experts).contains(&k), "k={k} out of range");
        ModelSpec {
            name: name.to_string(),
            n_layers,
            d_model,
            d_ff,
            n_experts,
            k,
            tokens_per_iter,
        }
    }

    // --- Table III presets -------------------------------------------------
    pub fn moe_gpt_s(e: usize, k: usize, tokens: u64) -> Self {
        Self::new("MoE-GPT-S", 12, 512, 1024, e, k, tokens)
    }
    pub fn moe_gpt_m(e: usize, k: usize, tokens: u64) -> Self {
        Self::new("MoE-GPT-M", 12, 1024, 2048, e, k, tokens)
    }
    pub fn moe_gpt_l(e: usize, k: usize, tokens: u64) -> Self {
        Self::new("MoE-GPT-L", 12, 2048, 4096, e, k, tokens)
    }
    pub fn moe_gpt_ds(e: usize, k: usize, tokens: u64) -> Self {
        Self::new("MoE-GPT-DS", 24, 512, 1024, e, k, tokens)
    }
    pub fn moe_gpt_dm(e: usize, k: usize, tokens: u64) -> Self {
        Self::new("MoE-GPT-DM", 24, 1024, 2048, e, k, tokens)
    }

    /// All five Table III variants.
    pub fn table3(e: usize, k: usize, tokens: u64) -> Vec<Self> {
        vec![
            Self::moe_gpt_s(e, k, tokens),
            Self::moe_gpt_m(e, k, tokens),
            Self::moe_gpt_l(e, k, tokens),
            Self::moe_gpt_ds(e, k, tokens),
            Self::moe_gpt_dm(e, k, tokens),
        ]
    }

    /// The four variants that fit the 2080Ti cluster (Table V drops L).
    pub fn table3_small(e: usize, k: usize, tokens: u64) -> Vec<Self> {
        vec![
            Self::moe_gpt_s(e, k, tokens),
            Self::moe_gpt_m(e, k, tokens),
            Self::moe_gpt_ds(e, k, tokens),
            Self::moe_gpt_dm(e, k, tokens),
        ]
    }

    pub fn by_name(name: &str, e: usize, k: usize, tokens: u64) -> Option<Self> {
        match name {
            "MoE-GPT-S" | "s" => Some(Self::moe_gpt_s(e, k, tokens)),
            "MoE-GPT-M" | "m" => Some(Self::moe_gpt_m(e, k, tokens)),
            "MoE-GPT-L" | "l" => Some(Self::moe_gpt_l(e, k, tokens)),
            "MoE-GPT-DS" | "ds" => Some(Self::moe_gpt_ds(e, k, tokens)),
            "MoE-GPT-DM" | "dm" => Some(Self::moe_gpt_dm(e, k, tokens)),
            _ => None,
        }
    }

    // --- Derived byte/flop quantities used by the performance model --------

    /// Bytes of one routed token's activation (f32 row of width d_model).
    pub fn token_bytes(&self) -> f64 {
        (self.d_model * 4) as f64
    }

    /// Bytes of ONE expert's parameters (w1 + b1 + w2 + b2, f32) — the unit
    /// moved by the Trans primitive (and matched by Agg for gradients).
    pub fn expert_param_bytes(&self) -> f64 {
        ((2 * self.d_model * self.d_ff + self.d_ff + self.d_model) * 4) as f64
    }

    /// Forward FLOPs to push one token through one expert FFN.
    pub fn ffn_flops_per_token(&self) -> f64 {
        // Two GEMMs: (1,D)x(D,F) and (1,F)x(F,D).
        (4 * self.d_model * self.d_ff) as f64
    }

    /// Forward FLOPs of the non-MoE part of a block per token (attention
    /// projections; the seq-len dependent score term is folded into MFU).
    pub fn non_moe_flops_per_token(&self) -> f64 {
        (8 * self.d_model * self.d_model) as f64
    }

    /// Tokens each device contributes per iteration (DP-style split).
    pub fn tokens_per_device(&self, n_devices: usize) -> u64 {
        self.tokens_per_iter / n_devices as u64
    }
}

/// Settings for the end-to-end trainer (`pro-prophet train`).
#[derive(Clone, Debug)]
pub struct TrainingConfig {
    /// Artifact preset name (matches `{preset}_manifest.json`).
    pub preset: String,
    pub artifacts_dir: String,
    pub steps: usize,
    pub seed: u64,
    /// Log every n steps.
    pub log_every: usize,
    /// Feed observed gate loads into the planner+simulator as we train.
    pub analyze_balance: bool,
    pub report_path: Option<String>,
    /// Persist the prophet's history ring buffer (workload-trace format)
    /// here after the run — replayable via `pro-prophet trace
    /// --from-store` and the simulator.
    pub store_path: Option<String>,
    /// Warm-start the forecasting subsystem by replaying a previously
    /// saved prophet history (the `store_path` of an earlier run)
    /// through the session before step 1 — history, drift state and
    /// forecast scoring resume where the last run stopped.
    pub resume_store: Option<String>,
    /// Write per-step structured metrics (schema-versioned JSONL) here
    /// (`--metrics`); None = telemetry off, zero-cost no-op recorder.
    pub metrics_path: Option<String>,
    /// Cap on retained per-step metric records (the whole-run aggregates
    /// still see every step; drops are reported, never silent).
    pub metrics_max_events: usize,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            preset: "tiny".into(),
            artifacts_dir: "artifacts".into(),
            steps: 50,
            seed: 42,
            log_every: 10,
            analyze_balance: true,
            report_path: None,
            store_path: None,
            resume_store: None,
            metrics_path: None,
            metrics_max_events: crate::obs::DEFAULT_MAX_EVENTS,
        }
    }
}

/// A full experiment: model x cluster x policy x planner x prophet
/// settings.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelSpec,
    pub cluster: ClusterSpec,
    /// Balancing-policy registry name (`[policy] name = "..."`; see
    /// [`crate::balancer::registry`]).
    pub policy: String,
    /// Block-wise overlap scheduling on/off (`[policy] scheduler = ...`,
    /// consumed by the Pro-Prophet family).
    pub scheduler_on: bool,
    /// Explicit schedule-kind override (`[policy] schedule = "..."`,
    /// e.g. `"dag_relaxed"`).  None = the policy's own default.  When
    /// present it wins over `scheduler` for the Pro-Prophet family via
    /// [`ProphetOptions::apply_schedule`]: `dag_relaxed`/`blockwise`
    /// force the scheduler on (relaxed vs barrier assembly), `blocking`
    /// forces it off.  `no_load_balance` is rejected at parse time (it
    /// is the Deepspeed-MoE policy, not a scheduling mode).
    pub schedule: Option<ScheduleKind>,
    pub planner: PlannerConfig,
    /// Forecasting subsystem knobs (`[prophet]` table).
    pub prophet: ProphetConfig,
    /// Telemetry sink knobs (`[obs]` table: `metrics`, `max_events`);
    /// CLI `--metrics`/`--max-events` override these.
    pub obs: ObsConfig,
    /// Explicit fault events (`[faults] events = [...]`, round-trippable
    /// [`crate::faults::FaultEvent`] specs validated against the
    /// cluster).  Empty = fault-free, bit-identical to a build without
    /// the subsystem.
    pub faults: FaultTimeline,
    /// Seed for a synthetic timeline (`[faults] seed = N`) — mutually
    /// exclusive with explicit events; resolved by
    /// [`ExperimentConfig::fault_timeline`] once the iteration horizon
    /// is known.
    pub fault_seed: Option<u64>,
    pub iterations: usize,
    pub seed: u64,
    /// Multi-job fleet simulation (`[fleet]` table: tick clock,
    /// admission/rebalancing knobs, tenant specs).  None when the file
    /// has no `[fleet]` table — single-job commands ignore it entirely.
    pub fleet: Option<crate::fleet::FleetConfig>,
}

impl ExperimentConfig {
    /// Load from a TOML-subset file; unspecified keys fall back to the
    /// paper's defaults (MoE-GPT-M on 4 HPWNV nodes).
    pub fn from_table(t: &toml::Table) -> Result<Self, String> {
        let mut cluster = ClusterSpec::by_name(
            &t.str_or("cluster.kind", "hpwnv"),
            t.usize_or("cluster.nodes", 4),
        )
        .ok_or_else(|| format!("unknown cluster kind {:?}", t.str_or("cluster.kind", "")))?;
        // Heterogeneity knobs: a full per-device `slowdown` vector, or
        // the `straggler_device` (+ optional `straggler_slowdown`, default
        // 2.0) shorthand for the one-slow-GPU scenario.
        if let Some(v) = t.get("cluster.slowdown") {
            let vals = match v {
                toml::Value::Arr(vals) => vals,
                _ => return Err("cluster.slowdown must be an array of factors".into()),
            };
            let factors: Vec<f64> = vals
                .iter()
                .map(|x| {
                    x.as_f64()
                        .ok_or_else(|| "cluster.slowdown entries must be numbers".to_string())
                })
                .collect::<Result<_, _>>()?;
            if factors.len() != cluster.n_devices() {
                return Err(format!(
                    "cluster.slowdown has {} entries for {} devices",
                    factors.len(),
                    cluster.n_devices()
                ));
            }
            if factors.iter().any(|f| !f.is_finite() || *f <= 0.0) {
                return Err(format!("cluster.slowdown factors must be > 0: {factors:?}"));
            }
            cluster = cluster.with_slowdowns(factors);
        }
        if let Some(v) = t.get("cluster.straggler_device") {
            let dev = v.as_usize().ok_or_else(|| {
                "cluster.straggler_device must be a non-negative integer".to_string()
            })?;
            if dev >= cluster.n_devices() {
                return Err(format!(
                    "cluster.straggler_device {dev} out of range for {} devices",
                    cluster.n_devices()
                ));
            }
            let factor = t.f64_or("cluster.straggler_slowdown", 2.0);
            if !factor.is_finite() || factor <= 0.0 {
                return Err(format!("cluster.straggler_slowdown must be > 0, got {factor}"));
            }
            cluster = cluster.with_slowdown(dev, factor);
        }
        let e = t.usize_or("model.experts", cluster.n_devices());
        let k = t.usize_or("model.k", 1);
        let tokens = t.usize_or("model.tokens_per_iter", 16384) as u64;
        let model = match t.get("model.name").and_then(toml::Value::as_str) {
            Some(name) => ModelSpec::by_name(name, e, k, tokens)
                .ok_or_else(|| format!("unknown model {name:?}"))?,
            None => ModelSpec::new(
                &t.str_or("model.custom_name", "custom"),
                t.usize_or("model.layers", 12),
                t.usize_or("model.d_model", 1024),
                t.usize_or("model.d_ff", 2048),
                e,
                k,
                tokens,
            ),
        };
        let planner = PlannerConfig {
            n_exclude: t.usize_or("planner.n_exclude", cluster.n_devices() / 2),
            alpha: t.f64_or("planner.alpha", 0.25),
            replan_interval: t.usize_or("planner.replan_interval", 1),
            use_overlap_model: t.bool_or("planner.use_overlap_model", true),
            device_aware: t.bool_or("planner.device_aware", true),
            ..Default::default()
        };
        let pd = ProphetConfig::default();
        let predictor_name = t.str_or("prophet.predictor", pd.predictor.name());
        let prophet = ProphetConfig {
            history: t.usize_or("prophet.history", pd.history),
            ema_beta: t.f64_or("prophet.ema_beta", pd.ema_beta),
            window: t.usize_or("prophet.window", pd.window),
            error_decay: t.f64_or("prophet.error_decay", pd.error_decay),
            drift_threshold: t.f64_or("prophet.drift_threshold", pd.drift_threshold),
            drift_cooldown: t.usize_or("prophet.drift_cooldown", pd.drift_cooldown),
            predictor: PredictorKind::from_name(&predictor_name)
                .ok_or_else(|| format!("unknown prophet.predictor {predictor_name:?}"))?,
            device_forecast: t.bool_or("prophet.device_forecast", pd.device_forecast),
        };
        prophet.validate()?;
        let policy = t.str_or("policy.name", "pro-prophet");
        if !registry::is_known(&policy) {
            return Err(format!(
                "unknown policy.name {policy:?} (known: {})",
                registry::names().join(", ")
            ));
        }
        let schedule = match t.get("policy.schedule") {
            None => None,
            Some(v) => {
                let name = v
                    .as_str()
                    .ok_or_else(|| "policy.schedule must be a string".to_string())?;
                let kind = ScheduleKind::from_name(name).ok_or_else(|| {
                    format!(
                        "unknown policy.schedule {name:?} (known: {})",
                        ScheduleKind::OVERRIDE_NAMES.join(", ")
                    )
                })?;
                if kind == ScheduleKind::NoLoadBalance {
                    // Not a Pro-Prophet scheduling mode: pretending to
                    // honor it would silently price the Blocking-with-LB
                    // timeline instead of the no-LB one.
                    return Err(
                        "policy.schedule = \"no_load_balance\" is the no-balancing \
                         timeline itself — select it with [policy] name = \"deepspeed\" \
                         (use \"blocking\" to ablate the scheduler)"
                            .into(),
                    );
                }
                Some(kind)
            }
        };
        let mut obs = ObsConfig::default();
        if let Some(v) = t.get("obs.metrics") {
            let path = v
                .as_str()
                .ok_or_else(|| "obs.metrics must be a string path".to_string())?;
            obs.metrics_path = Some(path.to_string());
        }
        if let Some(v) = t.get("obs.max_events") {
            let n = v
                .as_usize()
                .ok_or_else(|| "obs.max_events must be a non-negative integer".to_string())?;
            if n == 0 {
                return Err("obs.max_events must be >= 1 (use a large value, not 0, \
                            to keep everything)"
                    .into());
            }
            obs.max_events = n;
        }
        let faults = match t.get("faults.events") {
            None => FaultTimeline::empty(),
            Some(v) => {
                let vals = match v {
                    toml::Value::Arr(vals) => vals,
                    _ => return Err("faults.events must be an array of event specs".into()),
                };
                let specs: Vec<&str> = vals
                    .iter()
                    .map(|x| {
                        x.as_str().ok_or_else(|| {
                            "faults.events entries must be strings \
                             (e.g. \"down dev=3 start=10\")"
                                .to_string()
                        })
                    })
                    .collect::<Result<_, _>>()?;
                FaultTimeline::parse_specs(&specs, cluster.n_devices())
                    .map_err(|e| format!("faults.events: {e}"))?
            }
        };
        let fault_seed = match t.get("faults.seed") {
            None => None,
            Some(v) => Some(
                v.as_usize()
                    .ok_or_else(|| "faults.seed must be a non-negative integer".to_string())?
                    as u64,
            ),
        };
        if fault_seed.is_some() && !faults.is_empty() {
            return Err(
                "faults.seed and faults.events are mutually exclusive \
                 (the seed generates a timeline)"
                    .into(),
            );
        }
        let fleet = crate::fleet::FleetConfig::from_table(t, &cluster)?;
        Ok(ExperimentConfig {
            model,
            cluster,
            policy,
            scheduler_on: t.bool_or("policy.scheduler", true),
            schedule,
            planner,
            prophet,
            obs,
            faults,
            fault_seed,
            iterations: t.usize_or("iterations", 100),
            seed: t.usize_or("seed", 42) as u64,
            fleet,
        })
    }

    /// Resolve the experiment's fault timeline once the iteration
    /// horizon is known: explicit `[faults] events`, a seeded synthetic
    /// one sized to `horizon`, or empty.
    pub fn fault_timeline(&self, horizon: usize) -> FaultTimeline {
        match self.fault_seed {
            Some(seed) => FaultTimeline::generate(seed, self.cluster.n_devices(), horizon),
            None => self.faults.clone(),
        }
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        Self::from_table(&toml::parse_file(path)?)
    }

    /// The experiment's planner/scheduler/prophet knobs as the options
    /// object every registry constructor takes.  An explicit `[policy]
    /// schedule` override wins over the `scheduler` boolean; the
    /// `dag_relaxed` kind additionally arms the planner's slack-aware
    /// cost model.
    pub fn prophet_options(&self) -> ProphetOptions {
        let mut opts = ProphetOptions {
            planner: self.planner.clone(),
            scheduler_on: self.scheduler_on,
            relaxed_dag: false,
            prophet: self.prophet.clone(),
        };
        if let Some(kind) = self.schedule {
            opts.apply_schedule(kind);
        }
        opts
    }

    /// Construct the configured balancing policy from the registry.
    pub fn build_policy(&self) -> Result<Box<dyn BalancingPolicy>, String> {
        registry::build(&self.policy, &self.prophet_options())
            .ok_or_else(|| format!("unknown policy {:?}", self.policy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_presets_match_paper() {
        let m = ModelSpec::moe_gpt_m(16, 1, 16384);
        assert_eq!((m.n_layers, m.d_model, m.d_ff), (12, 1024, 2048));
        let l = ModelSpec::moe_gpt_l(16, 2, 16384);
        assert_eq!((l.n_layers, l.d_model, l.d_ff), (12, 2048, 4096));
        let ds = ModelSpec::moe_gpt_ds(16, 1, 16384);
        assert_eq!(ds.n_layers, 24);
        assert_eq!(ModelSpec::table3(16, 1, 16384).len(), 5);
        assert_eq!(ModelSpec::table3_small(8, 2, 4096).len(), 4);
    }

    #[test]
    fn derived_quantities() {
        let m = ModelSpec::moe_gpt_s(16, 1, 16384);
        assert_eq!(m.token_bytes(), 2048.0); // 512 * 4
        // 2*512*1024 weights *2 matmuls + biases, all f32.
        assert_eq!(
            m.expert_param_bytes(),
            ((2 * 512 * 1024 + 1024 + 512) * 4) as f64
        );
        assert_eq!(m.ffn_flops_per_token(), (4 * 512 * 1024) as f64);
        assert_eq!(m.tokens_per_device(16), 1024);
    }

    #[test]
    #[should_panic]
    fn bad_k_rejected() {
        ModelSpec::new("x", 1, 8, 8, 4, 5, 128);
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelSpec::by_name("MoE-GPT-DM", 8, 2, 4096).is_some());
        assert!(ModelSpec::by_name("nope", 8, 2, 4096).is_none());
    }

    #[test]
    fn experiment_from_toml() {
        let t = toml::parse(
            r#"
            iterations = 50
            seed = 7
            [model]
            name = "MoE-GPT-M"
            k = 2
            tokens_per_iter = 32768
            [cluster]
            kind = "hpnv"
            nodes = 4
            [planner]
            alpha = 0.5
            "#,
        )
        .unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.model.name, "MoE-GPT-M");
        assert_eq!(e.model.k, 2);
        assert_eq!(e.model.n_experts, 16); // defaults to device count
        assert_eq!(e.cluster.n_devices(), 16);
        assert!((e.planner.alpha - 0.5).abs() < 1e-12);
        assert_eq!(e.iterations, 50);
    }

    #[test]
    fn experiment_defaults() {
        let e = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(e.cluster.n_devices(), 16);
        assert_eq!(e.model.n_experts, 16);
        assert_eq!(e.iterations, 100);
    }

    #[test]
    fn experiment_rejects_unknowns() {
        let t = toml::parse("[cluster]\nkind = \"petaflop\"").unwrap();
        assert!(ExperimentConfig::from_table(&t).is_err());
        let t2 = toml::parse("[model]\nname = \"GPT-9\"").unwrap();
        assert!(ExperimentConfig::from_table(&t2).is_err());
        let t3 = toml::parse("[prophet]\npredictor = \"oracle\"").unwrap();
        assert!(ExperimentConfig::from_table(&t3).is_err());
        // Out-of-range knobs are rejected at parse time, not by a panic
        // deep inside Prophet construction.
        let t4 = toml::parse("[prophet]\nema_beta = 1.5").unwrap();
        assert!(ExperimentConfig::from_table(&t4).is_err());
        let t5 = toml::parse("[prophet]\nwindow = 0").unwrap();
        assert!(ExperimentConfig::from_table(&t5).is_err());
    }

    #[test]
    fn policy_table_parses_and_builds() {
        let t = toml::parse("[policy]\nname = \"flexmoe\"\nscheduler = false").unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.policy, "flexmoe");
        assert!(!e.scheduler_on);
        assert_eq!(e.build_policy().unwrap().name(), "FlexMoE");
        // Default policy is pro-prophet with the scheduler on.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.policy, "pro-prophet");
        assert!(d.scheduler_on);
        assert_eq!(d.build_policy().unwrap().name(), "Pro-Prophet");
        assert!(d.prophet_options().scheduler_on);
        // Unknown names fail at parse time with the known list.
        let bad = toml::parse("[policy]\nname = \"magic\"").unwrap();
        let err = ExperimentConfig::from_table(&bad).unwrap_err();
        assert!(err.contains("magic") && err.contains("pro-prophet"), "{err}");
    }

    #[test]
    fn policy_schedule_key_round_trips() {
        // dag_relaxed: selects the relaxed execution mode and arms the
        // slack-aware planner, whatever `scheduler` says.
        let t = toml::parse("[policy]\nschedule = \"dag_relaxed\"\nscheduler = false").unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.schedule, Some(ScheduleKind::DagRelaxed));
        assert_eq!(e.schedule.unwrap().name(), "dag_relaxed", "TOML round trip");
        let opts = e.prophet_options();
        assert!(opts.relaxed_dag && opts.scheduler_on && opts.planner.slack_aware);
        assert_eq!(e.build_policy().unwrap().name(), "Pro-Prophet(dag)");
        // blocking turns the scheduler off; blockwise turns it on.
        let t = toml::parse("[policy]\nschedule = \"blocking\"").unwrap();
        let opts = ExperimentConfig::from_table(&t).unwrap().prophet_options();
        assert!(!opts.scheduler_on && !opts.relaxed_dag);
        let t = toml::parse("[policy]\nschedule = \"blockwise\"\nscheduler = false").unwrap();
        let opts = ExperimentConfig::from_table(&t).unwrap().prophet_options();
        assert!(opts.scheduler_on && !opts.relaxed_dag);
        // Absent key: policy default, no override recorded.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.schedule, None);
        assert!(!d.prophet_options().relaxed_dag);
    }

    #[test]
    fn policy_schedule_rejects_unknown_kinds_helpfully() {
        let t = toml::parse("[policy]\nschedule = \"warp_speed\"").unwrap();
        let err = ExperimentConfig::from_table(&t).unwrap_err();
        assert!(err.contains("warp_speed"), "{err}");
        assert!(err.contains("dag_relaxed") && err.contains("blockwise"), "{err}");
        // Non-string values are rejected too.
        let t = toml::parse("[policy]\nschedule = 3").unwrap();
        assert!(ExperimentConfig::from_table(&t).unwrap_err().contains("string"));
        // no_load_balance is a policy (Deepspeed-MoE), not a Pro-Prophet
        // scheduling mode: honoring it silently would price the wrong
        // timeline, so it errors with a pointer.
        let t = toml::parse("[policy]\nschedule = \"no_load_balance\"").unwrap();
        let err = ExperimentConfig::from_table(&t).unwrap_err();
        assert!(err.contains("deepspeed"), "{err}");
    }

    #[test]
    fn cluster_slowdown_knobs_parse_and_validate() {
        // Straggler shorthand.
        let t = toml::parse(
            "[cluster]\nkind = \"hpwnv\"\nnodes = 1\nstraggler_device = 2\nstraggler_slowdown = 2.5",
        )
        .unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert!(e.cluster.is_heterogeneous());
        assert_eq!(e.cluster.slowdown(2), 2.5);
        assert_eq!(e.cluster.slowdown(0), 1.0);
        // Shorthand defaults to 2x.
        let t = toml::parse("[cluster]\nkind = \"hpwnv\"\nnodes = 1\nstraggler_device = 0").unwrap();
        assert_eq!(ExperimentConfig::from_table(&t).unwrap().cluster.slowdown(0), 2.0);
        // Full vector.
        let t = toml::parse("[cluster]\nnodes = 1\nslowdown = [1.0, 1.0, 3.0, 1.0]").unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.cluster.slowdown(2), 3.0);
        // Errors: wrong arity, bad values, out-of-range device.
        assert!(ExperimentConfig::from_table(
            &toml::parse("[cluster]\nnodes = 1\nslowdown = [1.0, 2.0]").unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_table(
            &toml::parse("[cluster]\nnodes = 1\nslowdown = [1.0, 1.0, 1.0, 0.0]").unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_table(
            &toml::parse("[cluster]\nnodes = 1\nstraggler_device = 99").unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_table(
            &toml::parse("[cluster]\nnodes = 1\nstraggler_device = 0\nstraggler_slowdown = -1.0")
                .unwrap()
        )
        .is_err());
    }

    #[test]
    fn obs_table_parses() {
        let t = toml::parse("[obs]\nmetrics = \"run.jsonl\"\nmax_events = 500").unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.obs.metrics_path.as_deref(), Some("run.jsonl"));
        assert_eq!(e.obs.max_events, 500);
        // Defaults: telemetry off, standard cap.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.obs.metrics_path.is_none());
        assert_eq!(d.obs.max_events, crate::obs::DEFAULT_MAX_EVENTS);
        // max_events = 0 is rejected (it would mean "record nothing").
        let bad = toml::parse("[obs]\nmax_events = 0").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("max_events"));
        // Non-string metrics path is rejected.
        let bad = toml::parse("[obs]\nmetrics = 3").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("string"));
    }

    #[test]
    fn faults_table_parses_and_validates() {
        let t = toml::parse(
            "[cluster]\nnodes = 1\n[faults]\nevents = [\"transient dev=1 factor=2.5 start=3 dur=4\", \"down dev=2 start=5\"]",
        )
        .unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.faults.events().len(), 2);
        assert_eq!(e.fault_timeline(10).specs()[1], "down dev=2 start=5");
        assert!(e.fault_seed.is_none());
        // Seeded synthetic timeline: resolved lazily, deterministic.
        let t = toml::parse("[faults]\nseed = 7").unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert!(e.faults.is_empty());
        let tl = e.fault_timeline(50);
        assert!(!tl.is_empty());
        assert_eq!(tl, e.fault_timeline(50), "seeded generation must be deterministic");
        // Defaults: no faults at all.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.faults.is_empty() && d.fault_seed.is_none());
        assert!(d.fault_timeline(100).is_empty());
        // Errors: device out of range for the cluster, bad spec, both
        // sources at once, wrong value shapes.
        let bad = toml::parse("[cluster]\nnodes = 1\n[faults]\nevents = [\"down dev=9 start=0\"]")
            .unwrap();
        let err = ExperimentConfig::from_table(&bad).unwrap_err();
        assert!(err.contains("faults.events"), "{err}");
        let bad = toml::parse("[faults]\nevents = [\"explode dev=0 start=0\"]").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("explode"));
        let bad =
            toml::parse("[faults]\nseed = 3\nevents = [\"down dev=0 start=1\"]").unwrap();
        let err = ExperimentConfig::from_table(&bad).unwrap_err();
        assert!(err.contains("mutually exclusive"), "{err}");
        let bad = toml::parse("[faults]\nevents = \"down dev=0 start=1\"").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("array"));
        let bad = toml::parse("[faults]\nevents = [3]").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("strings"));
        let bad = toml::parse("[faults]\nseed = \"lucky\"").unwrap();
        assert!(ExperimentConfig::from_table(&bad).unwrap_err().contains("integer"));
    }

    #[test]
    fn fleet_table_parses_through_experiment_config() {
        let t = toml::parse(
            "[cluster]\nnodes = 2\n[fleet]\nticks = 12\ntick_s = 0.5\njobs = [\"train name=a nodes=1 iters=4\", \"infer name=b nodes=1 rate=2\"]",
        )
        .unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        let fleet = e.fleet.expect("[fleet] table present");
        assert_eq!(fleet.ticks, 12);
        assert!((fleet.tick_s - 0.5).abs() < 1e-12);
        assert_eq!(fleet.jobs.len(), 2);
        // No [fleet] table: None, and the rest of the config is untouched.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert!(d.fleet.is_none());
        // Fleet validation errors surface through from_table.
        let bad = toml::parse("[cluster]\nnodes = 2\n[fleet]\njobs = [\"train name=a nodes=9 iters=1\"]")
            .unwrap();
        assert!(ExperimentConfig::from_table(&bad).is_err());
    }

    #[test]
    fn prophet_table_parses() {
        let t = toml::parse(
            r#"
            [prophet]
            predictor = "trend"
            history = 32
            window = 5
            ema_beta = 0.5
            drift_threshold = 0.9
            drift_cooldown = 2
            "#,
        )
        .unwrap();
        let e = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(e.prophet.predictor, crate::prophet::PredictorKind::LinearTrend);
        assert_eq!(e.prophet.history, 32);
        assert_eq!(e.prophet.window, 5);
        assert!((e.prophet.ema_beta - 0.5).abs() < 1e-12);
        assert!((e.prophet.drift_threshold - 0.9).abs() < 1e-12);
        assert_eq!(e.prophet.drift_cooldown, 2);
        // Defaults apply when the table is absent.
        let d = ExperimentConfig::from_table(&toml::parse("").unwrap()).unwrap();
        assert_eq!(d.prophet, crate::prophet::ProphetConfig::default());
    }
}
