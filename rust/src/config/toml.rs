//! TOML-subset parser for config files (the `toml` crate is unavailable
//! offline).  Supported: `[section]` / `[a.b]` headers, `key = value` with
//! string / integer / float / boolean / flat-array values, `#` comments.
//!
//! Values are exposed as a flat `dotted.path -> Value` map, which is all the
//! config system needs.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn get(&self, dotted: &str) -> Option<&Value> {
        self.entries.get(dotted)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Parse TOML-subset text into a flat dotted-key table.
pub fn parse(text: &str) -> Result<Table, String> {
    let mut entries = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unclosed section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(val.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        entries.insert(full, value);
    }
    Ok(Table { entries })
}

pub fn parse_file(path: &std::path::Path) -> Result<Table, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_sections_and_types() {
        let t = parse(
            r#"
            # experiment
            name = "fig10"
            [model]
            layers = 12
            lr = 1.5e-3
            moe = true
            [cluster.link]
            bw = [12.5, 56.0]
            "#,
        )
        .unwrap();
        assert_eq!(t.str_or("name", ""), "fig10");
        assert_eq!(t.usize_or("model.layers", 0), 12);
        assert!((t.f64_or("model.lr", 0.0) - 1.5e-3).abs() < 1e-12);
        assert!(t.bool_or("model.moe", false));
        match t.get("cluster.link.bw").unwrap() {
            Value::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[1].as_f64(), Some(56.0));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn comments_and_hash_in_string() {
        let t = parse("x = \"a#b\" # trailing\ny = 1").unwrap();
        assert_eq!(t.str_or("x", ""), "a#b");
        assert_eq!(t.usize_or("y", 0), 1);
    }

    #[test]
    fn int_vs_float() {
        let t = parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(t.get("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(t.get("b").unwrap().as_i64(), None);
        assert_eq!(t.get("b").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[unclosed").unwrap_err().contains("line 1"));
        assert!(parse("x 3").unwrap_err().contains("key = value"));
        assert!(parse("x = @").is_err());
        assert!(parse("[]").is_err());
    }

    #[test]
    fn defaults() {
        let t = parse("").unwrap();
        assert!(t.is_empty());
        assert_eq!(t.usize_or("nope", 7), 7);
        assert_eq!(t.str_or("nope", "d"), "d");
    }
}
