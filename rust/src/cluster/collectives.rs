//! A2A collective algorithms over a cluster topology.
//!
//! The paper's EP layer exchanges tokens with an All-to-All whose
//! implementation matters on hierarchical fabrics (it cites the
//! hierarchical-factor and BlueGene A2A optimizations [27-29], and Tutel's
//! P2P formulation backs Eq 1).  This module prices:
//!
//! * [`a2a_time_direct`] — D*(D-1) point-to-point transfers, each device
//!   serializing its egress and ingress (Tutel-style; what Eq 1
//!   approximates with B̄);
//! * [`a2a_time_hierarchical`] — the 2-level algorithm: gather per node
//!   over fast intra-node links, one aggregated inter-node exchange
//!   between node leaders, then scatter — fewer, larger inter-node
//!   messages (wins when inter-node bandwidth dominates cost and
//!   per-message overhead is non-trivial).

use super::ClusterSpec;

/// Fixed per-message launch overhead (latency + kernel launch), seconds.
/// 20 µs ~ NCCL P2P launch on PCIe-class fabrics.
pub const MESSAGE_OVERHEAD_S: f64 = 20e-6;

/// Direct P2P A2A: max over devices of serialized egress/ingress,
/// each message priced at its link bandwidth plus launch overhead.
pub fn a2a_time_direct(
    cluster: &ClusterSpec,
    traffic: &[Vec<u64>],
    bytes_per_token: f64,
) -> f64 {
    let d = cluster.n_devices();
    let mut worst: f64 = 0.0;
    for i in 0..d {
        let mut egress = 0.0;
        let mut ingress = 0.0;
        for j in 0..d {
            if i == j {
                continue;
            }
            if traffic[i][j] > 0 {
                egress += MESSAGE_OVERHEAD_S
                    + traffic[i][j] as f64 * bytes_per_token / cluster.bandwidth(i, j);
            }
            if traffic[j][i] > 0 {
                ingress += MESSAGE_OVERHEAD_S
                    + traffic[j][i] as f64 * bytes_per_token / cluster.bandwidth(j, i);
            }
        }
        worst = worst.max(egress).max(ingress);
    }
    worst
}

/// Hierarchical (2-level) A2A: intra-node gather to a per-node leader,
/// leader-to-leader exchange of aggregated node traffic, intra-node
/// scatter.  Returns the modeled makespan of the three phases.
pub fn a2a_time_hierarchical(
    cluster: &ClusterSpec,
    traffic: &[Vec<u64>],
    bytes_per_token: f64,
) -> f64 {
    let d = cluster.n_devices();
    let g = cluster.gpus_per_node;
    let nodes = cluster.n_nodes;
    if nodes <= 1 {
        return a2a_time_direct(cluster, traffic, bytes_per_token);
    }

    // Phase 1: each non-leader sends its INTER-NODE traffic to its node
    // leader (intra-node traffic goes direct, priced in phase1 too).
    let mut phase1: f64 = 0.0;
    for src in 0..d {
        let leader = cluster.node_of(src) * g;
        let mut t = 0.0;
        let mut cross_bytes = 0.0;
        for dst in 0..d {
            if src == dst {
                continue;
            }
            let bytes = traffic[src][dst] as f64 * bytes_per_token;
            if bytes == 0.0 {
                continue;
            }
            if cluster.node_of(dst) == cluster.node_of(src) {
                // Local delivery at intra-node bandwidth.
                t += MESSAGE_OVERHEAD_S + bytes / cluster.bandwidth(src, dst);
            } else {
                cross_bytes += bytes;
            }
        }
        if src != leader && cross_bytes > 0.0 {
            t += MESSAGE_OVERHEAD_S + cross_bytes / cluster.bandwidth(src, leader);
        }
        phase1 = phase1.max(t);
    }

    // Phase 2: node-aggregated exchange between leaders.
    let mut node_traffic = vec![vec![0.0f64; nodes]; nodes];
    for src in 0..d {
        for dst in 0..d {
            let (ns, nd) = (cluster.node_of(src), cluster.node_of(dst));
            if ns != nd {
                node_traffic[ns][nd] += traffic[src][dst] as f64 * bytes_per_token;
            }
        }
    }
    let mut phase2: f64 = 0.0;
    for ns in 0..nodes {
        let leader = ns * g;
        let mut egress = 0.0;
        let mut ingress = 0.0;
        for nd in 0..nodes {
            if ns == nd {
                continue;
            }
            let other = nd * g;
            if node_traffic[ns][nd] > 0.0 {
                egress += MESSAGE_OVERHEAD_S
                    + node_traffic[ns][nd] / cluster.bandwidth(leader, other);
            }
            if node_traffic[nd][ns] > 0.0 {
                ingress += MESSAGE_OVERHEAD_S
                    + node_traffic[nd][ns] / cluster.bandwidth(other, leader);
            }
        }
        phase2 = phase2.max(egress).max(ingress);
    }

    // Phase 3: leaders scatter received cross-node traffic locally.
    let mut phase3: f64 = 0.0;
    for dst in 0..d {
        let leader = cluster.node_of(dst) * g;
        if dst == leader {
            continue;
        }
        let mut bytes = 0.0;
        for src in 0..d {
            if cluster.node_of(src) != cluster.node_of(dst) {
                bytes += traffic[src][dst] as f64 * bytes_per_token;
            }
        }
        if bytes > 0.0 {
            phase3 = phase3
                .max(MESSAGE_OVERHEAD_S + bytes / cluster.bandwidth(leader, dst));
        }
    }

    phase1 + phase2 + phase3
}

/// Pick the cheaper algorithm for this traffic (what an auto-tuned
/// framework would do).
pub fn a2a_time_best(
    cluster: &ClusterSpec,
    traffic: &[Vec<u64>],
    bytes_per_token: f64,
) -> f64 {
    a2a_time_direct(cluster, traffic, bytes_per_token)
        .min(a2a_time_hierarchical(cluster, traffic, bytes_per_token))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_traffic(d: usize, tokens: u64) -> Vec<Vec<u64>> {
        (0..d)
            .map(|i| (0..d).map(|j| if i == j { 0 } else { tokens }).collect())
            .collect()
    }

    #[test]
    fn zero_traffic_zero_time() {
        let c = ClusterSpec::hpwnv(2);
        let t = vec![vec![0u64; 8]; 8];
        assert_eq!(a2a_time_direct(&c, &t, 2048.0), 0.0);
        assert_eq!(a2a_time_hierarchical(&c, &t, 2048.0), 0.0);
    }

    #[test]
    fn single_node_falls_back_to_direct() {
        let c = ClusterSpec::hpwnv(1);
        let t = uniform_traffic(4, 100);
        assert_eq!(
            a2a_time_hierarchical(&c, &t, 2048.0),
            a2a_time_direct(&c, &t, 2048.0)
        );
    }

    #[test]
    fn hierarchical_wins_on_many_small_cross_node_messages() {
        // 8 nodes, tiny messages: direct pays 28 inter-node launch
        // overheads per device; hierarchical pays 3 phases of few.
        let c = ClusterSpec::hpwnv(8);
        let t = uniform_traffic(32, 8); // 8 tokens per pair: overhead-bound
        let direct = a2a_time_direct(&c, &t, 2048.0);
        let hier = a2a_time_hierarchical(&c, &t, 2048.0);
        assert!(
            hier < direct,
            "hierarchical {hier} should beat direct {direct} on tiny messages"
        );
    }

    #[test]
    fn direct_wins_on_large_messages() {
        // Large payloads: the extra store-and-forward hop costs more than
        // the launch overhead saved.
        let c = ClusterSpec::hpwnv(2);
        let t = uniform_traffic(8, 200_000);
        let direct = a2a_time_direct(&c, &t, 2048.0);
        let hier = a2a_time_hierarchical(&c, &t, 2048.0);
        assert!(direct < hier);
    }

    #[test]
    fn best_picks_minimum() {
        let c = ClusterSpec::hpwnv(8);
        for tokens in [8u64, 200_000] {
            let t = uniform_traffic(32, tokens);
            let best = a2a_time_best(&c, &t, 2048.0);
            let d = a2a_time_direct(&c, &t, 2048.0);
            let h = a2a_time_hierarchical(&c, &t, 2048.0);
            assert!((best - d.min(h)).abs() < 1e-15);
        }
    }

    #[test]
    fn more_traffic_costs_more() {
        let c = ClusterSpec::hpnv(4);
        let t1 = uniform_traffic(16, 100);
        let t2 = uniform_traffic(16, 200);
        assert!(
            a2a_time_hierarchical(&c, &t2, 2048.0)
                > a2a_time_hierarchical(&c, &t1, 2048.0)
        );
        assert!(a2a_time_direct(&c, &t2, 2048.0) > a2a_time_direct(&c, &t1, 2048.0));
    }
}
