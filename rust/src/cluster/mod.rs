//! Cluster topology model: devices, nodes and link bandwidths.
//!
//! Mirrors the paper's three testbeds (§VI "Testbed"):
//!
//! * **HPWNV** — 4x RTX 3090 per node, PCIe 3.0 within the node,
//!   100 Gb/s InfiniBand between nodes, no NVLink.
//! * **HPNV**  — HPWNV plus NVLink-3.0 connecting the two GPUs of each
//!   pair within a node.
//! * **LPWNV** — HPWNV with RTX 2080 Ti GPUs (lower compute throughput).
//!
//! The numbers are effective (achievable) bandwidths / throughputs, not
//! peaks; they parameterize the performance model and the simulator.

pub mod collectives;

/// A homogeneous multi-node GPU cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub n_nodes: usize,
    pub gpus_per_node: usize,
    /// Effective P2P bandwidth within a node over PCIe, GB/s.
    pub intra_bw: f64,
    /// Effective P2P bandwidth across nodes (InfiniBand), GB/s.
    pub inter_bw: f64,
    /// NVLink bandwidth for paired GPUs, GB/s (0 = no NVLink).
    pub nvlink_bw: f64,
    /// Whether GPUs are grouped in NVLink pairs (HPNV).
    pub nvlink_pairs: bool,
    /// Effective per-GPU compute throughput, TFLOP/s (peak fp32).
    pub gpu_tflops: f64,
    /// Model FLOPs utilization actually achieved on expert GEMMs.
    pub mfu: f64,
    /// Per-device compute slowdown factors for heterogeneous / straggler
    /// scenarios: device `d`'s computation takes `device_slowdown[d]`
    /// times its homogeneous duration.  Empty means homogeneous (factor
    /// 1.0 everywhere).  Consumed by the device-level event timeline
    /// (`sim::events`) via the engine's `*_per_device` costs; the scalar
    /// (pre-maxed) cost path deliberately ignores it, so a straggler's
    /// effect is exactly the DES-vs-barrier gap.
    pub device_slowdown: Vec<f64>,
}

impl ClusterSpec {
    // --- presets matching the paper's testbeds ----------------------------

    /// 3090 nodes, PCIe-only (the paper's default cluster).
    pub fn hpwnv(n_nodes: usize) -> Self {
        ClusterSpec {
            name: format!("HPWNV-{n_nodes}"),
            n_nodes,
            gpus_per_node: 4,
            intra_bw: 11.0,  // PCIe 3.0 x16 effective
            inter_bw: 10.0,  // 100 Gb/s IB effective
            nvlink_bw: 0.0,
            nvlink_pairs: false,
            gpu_tflops: 35.6, // RTX 3090 fp32 peak
            mfu: 0.35,
            device_slowdown: Vec::new(),
        }
    }

    /// 3090 nodes with NVLink-3.0 pairs.
    pub fn hpnv(n_nodes: usize) -> Self {
        ClusterSpec {
            name: format!("HPNV-{n_nodes}"),
            nvlink_bw: 56.0, // NVLink-3.0 pair, effective
            nvlink_pairs: true,
            ..Self::hpwnv(n_nodes)
        }
    }

    /// 2080 Ti nodes (lower compute, same interconnect as HPWNV).
    pub fn lpwnv(n_nodes: usize) -> Self {
        ClusterSpec {
            name: format!("LPWNV-{n_nodes}"),
            gpu_tflops: 13.4, // RTX 2080 Ti fp32 peak
            ..Self::hpwnv(n_nodes)
        }
    }

    pub fn by_name(kind: &str, n_nodes: usize) -> Option<Self> {
        match kind.to_ascii_lowercase().as_str() {
            "hpwnv" => Some(Self::hpwnv(n_nodes)),
            "hpnv" => Some(Self::hpnv(n_nodes)),
            "lpwnv" => Some(Self::lpwnv(n_nodes)),
            _ => None,
        }
    }

    // --- heterogeneity ------------------------------------------------------

    /// Compute slowdown factor of `device` (1.0 when homogeneous).
    pub fn slowdown(&self, device: usize) -> f64 {
        self.device_slowdown.get(device).copied().unwrap_or(1.0)
    }

    /// Whether any device deviates from the homogeneous baseline.
    pub fn is_heterogeneous(&self) -> bool {
        self.device_slowdown.iter().any(|&s| s != 1.0)
    }

    /// Builder: slow `device` down by `factor` (>= 1.0 models a
    /// straggler; < 1.0 a faster-than-baseline device).
    pub fn with_slowdown(self, device: usize, factor: f64) -> Self {
        match self.try_with_slowdown(device, factor) {
            Ok(c) => c,
            Err(e) => panic!("{e}"),
        }
    }

    /// Builder: set the full per-device slowdown vector at once.
    pub fn with_slowdowns(self, factors: Vec<f64>) -> Self {
        match self.try_with_slowdowns(factors) {
            Ok(c) => c,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`Self::with_slowdown`]: rejects out-of-range devices
    /// and non-positive / non-finite factors with a clear error instead
    /// of deferring validation to the TOML layer.
    pub fn try_with_slowdown(mut self, device: usize, factor: f64) -> Result<Self, String> {
        let d = self.n_devices();
        if device >= d {
            return Err(format!(
                "cluster {}: slowdown device {device} out of range (cluster has {d} devices)",
                self.name
            ));
        }
        if !(factor.is_finite() && factor > 0.0) {
            return Err(format!(
                "cluster {}: slowdown factor {factor} for device {device} \
                 must be finite and > 0",
                self.name
            ));
        }
        if self.device_slowdown.is_empty() {
            self.device_slowdown = vec![1.0; d];
        }
        self.device_slowdown[device] = factor;
        Ok(self)
    }

    /// Fallible [`Self::with_slowdowns`]: rejects a vector whose length
    /// is not exactly `n_devices()` or that carries non-positive /
    /// non-finite factors.
    pub fn try_with_slowdowns(mut self, factors: Vec<f64>) -> Result<Self, String> {
        let d = self.n_devices();
        if factors.len() != d {
            return Err(format!(
                "cluster {}: slowdown vector has {} entries, cluster has {d} devices",
                self.name,
                factors.len()
            ));
        }
        if let Some(f) = factors.iter().find(|f| !(f.is_finite() && **f > 0.0)) {
            return Err(format!(
                "cluster {}: slowdown factor {f} must be finite and > 0",
                self.name
            ));
        }
        self.device_slowdown = factors;
        Ok(self)
    }

    // --- topology queries ---------------------------------------------------

    pub fn n_devices(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    pub fn node_of(&self, device: usize) -> usize {
        device / self.gpus_per_node
    }

    /// NVLink partners sit on adjacent even/odd local ids (2i, 2i+1).
    pub fn nvlink_partner(&self, device: usize) -> Option<usize> {
        if !self.nvlink_pairs {
            return None;
        }
        let local = device % self.gpus_per_node;
        let partner_local = local ^ 1;
        if partner_local >= self.gpus_per_node {
            return None;
        }
        Some(self.node_of(device) * self.gpus_per_node + partner_local)
    }

    /// Effective point-to-point bandwidth between two devices, bytes/s.
    pub fn bandwidth(&self, a: usize, b: usize) -> f64 {
        assert!(a < self.n_devices() && b < self.n_devices());
        if a == b {
            // Device-local "transfer" ~ HBM copy; effectively free relative
            // to links, modeled as very fast rather than infinite.
            return 700.0e9;
        }
        if self.node_of(a) != self.node_of(b) {
            return self.inter_bw * 1e9;
        }
        if self.nvlink_partner(a) == Some(b) {
            return self.nvlink_bw * 1e9;
        }
        self.intra_bw * 1e9
    }

    /// Average pairwise bandwidth B̄ over distinct device pairs, bytes/s —
    /// the B̄ of the paper's performance model (Table II).
    pub fn avg_bandwidth(&self) -> f64 {
        let d = self.n_devices();
        if d < 2 {
            return self.intra_bw * 1e9;
        }
        let mut acc = 0.0;
        let mut n = 0u64;
        for a in 0..d {
            for b in 0..d {
                if a != b {
                    acc += self.bandwidth(a, b);
                    n += 1;
                }
            }
        }
        acc / n as f64
    }

    /// Effective expert-compute throughput `t`: tokens/second one device
    /// pushes through ONE expert FFN of the given model (paper Table II).
    pub fn tokens_per_sec(&self, ffn_flops_per_token: f64) -> f64 {
        self.gpu_tflops * 1e12 * self.mfu / ffn_flops_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_shape() {
        let c = ClusterSpec::hpwnv(4);
        assert_eq!(c.n_devices(), 16);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(15), 3);
        assert!(ClusterSpec::by_name("HPNV", 2).is_some());
        assert!(ClusterSpec::by_name("xxx", 2).is_none());
    }

    #[test]
    fn bandwidth_hierarchy() {
        let c = ClusterSpec::hpnv(2);
        // NVLink pair > PCIe intra > IB inter.
        let nv = c.bandwidth(0, 1);
        let pcie = c.bandwidth(0, 2);
        let ib = c.bandwidth(0, 4);
        assert!(nv > pcie && pcie > ib, "{nv} {pcie} {ib}");
        // Self-transfer fastest of all.
        assert!(c.bandwidth(3, 3) > nv);
    }

    #[test]
    fn hpwnv_has_no_nvlink() {
        let c = ClusterSpec::hpwnv(2);
        assert_eq!(c.nvlink_partner(0), None);
        assert_eq!(c.bandwidth(0, 1), c.bandwidth(0, 2));
    }

    #[test]
    fn nvlink_pairing_is_symmetric() {
        let c = ClusterSpec::hpnv(1);
        assert_eq!(c.nvlink_partner(0), Some(1));
        assert_eq!(c.nvlink_partner(1), Some(0));
        assert_eq!(c.nvlink_partner(2), Some(3));
        assert_eq!(c.bandwidth(2, 3), 56.0e9);
    }

    #[test]
    fn avg_bandwidth_between_min_max() {
        let c = ClusterSpec::hpnv(2);
        let avg = c.avg_bandwidth();
        assert!(avg > c.inter_bw * 1e9);
        assert!(avg < c.nvlink_bw * 1e9);
    }

    #[test]
    fn lpwnv_slower_compute() {
        let hp = ClusterSpec::hpwnv(2);
        let lp = ClusterSpec::lpwnv(2);
        let f = 4.0 * 512.0 * 1024.0;
        assert!(lp.tokens_per_sec(f) < hp.tokens_per_sec(f));
        assert_eq!(lp.inter_bw, hp.inter_bw);
    }

    #[test]
    fn slowdown_defaults_to_homogeneous() {
        let c = ClusterSpec::hpwnv(2);
        assert!(!c.is_heterogeneous());
        assert_eq!(c.slowdown(0), 1.0);
        assert_eq!(c.slowdown(7), 1.0);
        let het = c.with_slowdown(3, 2.5);
        assert!(het.is_heterogeneous());
        assert_eq!(het.slowdown(3), 2.5);
        assert_eq!(het.slowdown(0), 1.0);
        // A full vector of ones is still homogeneous.
        let ones = ClusterSpec::hpwnv(1).with_slowdowns(vec![1.0; 4]);
        assert!(!ones.is_heterogeneous());
    }

    #[test]
    #[should_panic]
    fn slowdown_out_of_range_rejected() {
        let _ = ClusterSpec::hpwnv(1).with_slowdown(4, 2.0);
    }

    #[test]
    fn try_slowdown_reports_clear_errors() {
        let err = ClusterSpec::hpwnv(1).try_with_slowdown(4, 2.0).unwrap_err();
        assert!(err.contains("out of range") && err.contains("4 devices"), "{err}");
        for bad in [0.0, -1.5, f64::NAN, f64::INFINITY] {
            let err = ClusterSpec::hpwnv(1).try_with_slowdown(0, bad).unwrap_err();
            assert!(err.contains("finite and > 0"), "{bad}: {err}");
        }
        let err = ClusterSpec::hpwnv(1).try_with_slowdowns(vec![1.0; 3]).unwrap_err();
        assert!(err.contains("3 entries") && err.contains("4 devices"), "{err}");
        let err = ClusterSpec::hpwnv(1)
            .try_with_slowdowns(vec![1.0, 1.0, 0.0, 1.0])
            .unwrap_err();
        assert!(err.contains("finite and > 0"), "{err}");
        // Happy path matches the panicking builders.
        let a = ClusterSpec::hpwnv(1).try_with_slowdown(2, 2.5).unwrap();
        assert_eq!(a, ClusterSpec::hpwnv(1).with_slowdown(2, 2.5));
        let b = ClusterSpec::hpwnv(1).try_with_slowdowns(vec![1.0, 2.0, 1.0, 1.0]).unwrap();
        assert_eq!(b.slowdown(1), 2.0);
    }

    #[test]
    fn tokens_per_sec_scales_with_model() {
        let c = ClusterSpec::hpwnv(1);
        let small = c.tokens_per_sec(4.0 * 512.0 * 1024.0);
        let large = c.tokens_per_sec(4.0 * 2048.0 * 4096.0);
        assert!(small > large * 10.0);
    }
}
