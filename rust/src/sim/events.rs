//! Device-level discrete-event executor: plays an [`OpDag`] onto one
//! (compute, communication) stream pair **per device** and reports the
//! per-device critical path.
//!
//! This is the successor of pricing an iteration as a single global
//! two-stream [`crate::scheduler::Schedule`]: instead of collapsing every
//! operator to a scalar (the max over devices) before the timeline sees
//! it, ops carry per-device duration vectors and the makespan emerges
//! from per-device stream occupancy plus the DAG's dependency edges —
//! which is where stragglers, per-device exposed communication, and
//! heterogeneous clusters become visible (paper §V, Fig 7/8).
//!
//! # Semantics
//!
//! * Nodes execute in issue order on each stream (FIFO per device, one
//!   comp + one comm stream — the CUDA/NCCL pair).
//! * A **compute** node starts on device `d` when `d`'s comp stream is
//!   free and all its dependencies have finished **on `d`** (its inputs
//!   are device-local).
//! * A **communication** node is a collective: it starts on *all* devices
//!   at once, when every device's comm stream is free and every
//!   dependency has finished on every device; it then occupies device
//!   `d`'s comm stream for its per-device duration.
//! * The **critical path** is recovered by walking back from the
//!   last-finishing (node, device) through whichever predecessor
//!   determined each start time.  Ties prefer compute-stream sources
//!   (matching `Schedule::exposed_breakdown`'s `comp >= comm` rule), then
//!   the later node, then the lower device.  Charging the path's
//!   durations by [`crate::scheduler::Op::breakdown_key`] yields an
//!   exposed breakdown that sums exactly to the makespan.
//!
//! # Oracle equivalence
//!
//! On a barrier-shaped DAG with uniform per-device durations
//! ([`crate::scheduler::dag::from_schedule`]), the executor reproduces
//! the frozen Stage model's `total_time()` and `exposed_breakdown()`
//! **bit-for-bit** (every start is a `max` of previously computed finish
//! times — the same additions in the same order).  That equivalence is
//! pinned for all built-in policies in
//! `rust/tests/integration_timeline.rs`; relaxing the barriers
//! ([`crate::scheduler::build_blockwise_dag`]) and slowing devices
//! ([`crate::cluster::ClusterSpec::with_slowdown`]) are the new
//! capabilities on top.

use crate::scheduler::dag::OpDag;
use crate::scheduler::Stream;
use std::collections::BTreeMap;

/// Per-device stream/idle accounting of one executed DAG.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceStats {
    /// Seconds the device's compute stream is busy.
    pub busy_comp: f64,
    /// Seconds the device's communication stream is busy.
    pub busy_comm: f64,
    /// Communication seconds NOT overlapped by computation on this
    /// device — the per-device "exposed communication" of §V.
    pub exposed_comm: f64,
    /// Seconds neither stream is busy, up to the global makespan (a
    /// straggler elsewhere shows up as idle time here).
    pub idle: f64,
    /// When this device's last op finishes.
    pub finish: f64,
}

/// Outcome of executing an [`OpDag`].
#[derive(Clone, Debug, Default)]
pub struct DesResult {
    /// Iteration time: the per-device critical path (latest finish over
    /// all nodes and devices).
    pub makespan: f64,
    /// `start[node][device]` / `finish[node][device]` in seconds.
    pub start: Vec<Vec<f64>>,
    pub finish: Vec<Vec<f64>>,
    /// Exposed seconds per breakdown category, from critical-path
    /// attribution; values sum to `makespan`.
    pub exposed: BTreeMap<&'static str, f64>,
    /// Exposed seconds per block id (critical-path attribution; sums to
    /// `makespan` like `exposed`).
    pub per_block_exposed: Vec<f64>,
    /// Per-device stream/idle accounting.
    pub devices: Vec<DeviceStats>,
    /// The iteration's straggler: the device whose streams are busy
    /// longest (ties -> lowest id) — the one the others idle-wait on at
    /// collectives.
    pub straggler: usize,
}

/// Candidate source of a start time: (finish, from-comp-stream, node,
/// device).  `better` is the tie-break order documented in the module
/// docs.
type Cand = (f64, bool, usize, usize);

fn better(a: Cand, b: Cand) -> bool {
    if a.0 != b.0 {
        return a.0 > b.0;
    }
    if a.1 != b.1 {
        return a.1;
    }
    if a.2 != b.2 {
        return a.2 > b.2;
    }
    a.3 < b.3
}

fn consider(best: &mut Option<Cand>, cand: Cand) {
    let replace = match best {
        None => true,
        Some(b) => better(cand, *b),
    };
    if replace {
        *best = Some(cand);
    }
}

/// Execute `dag` and return times, per-device stats and the
/// critical-path exposed breakdown.
pub fn execute(dag: &OpDag) -> DesResult {
    debug_assert!(dag.validate().is_ok(), "invalid DAG: {:?}", dag.validate());
    let d = dag.n_devices;
    let n = dag.len();
    let nodes = dag.nodes();
    let mut start = vec![vec![0.0f64; d]; n];
    let mut finish = vec![vec![0.0f64; d]; n];
    // Which (node, device) determined each start (None = started at 0).
    let mut pred: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; d]; n];
    // Last node issued on each device's comp / comm stream.
    let mut comp_last: Vec<Option<usize>> = vec![None; d];
    let mut comm_last: Vec<Option<usize>> = vec![None; d];

    let is_comp = |i: usize| nodes[i].op.stream() == Stream::Comp;

    for (i, node) in nodes.iter().enumerate() {
        match node.op.stream() {
            Stream::Comp => {
                for dev in 0..d {
                    let mut best: Option<Cand> = None;
                    if let Some(p) = comp_last[dev] {
                        consider(&mut best, (finish[p][dev], true, p, dev));
                    }
                    for &dep in &node.deps {
                        consider(&mut best, (finish[dep][dev], is_comp(dep), dep, dev));
                    }
                    let s = best.map_or(0.0, |c| c.0);
                    start[i][dev] = s;
                    finish[i][dev] = s + node.dur[dev];
                    pred[i][dev] = best.map(|c| (c.2, c.3));
                    comp_last[dev] = Some(i);
                }
            }
            Stream::Comm => {
                // Collective: one synchronized start across all devices.
                let mut best: Option<Cand> = None;
                for dev in 0..d {
                    if let Some(p) = comm_last[dev] {
                        consider(&mut best, (finish[p][dev], false, p, dev));
                    }
                    for &dep in &node.deps {
                        consider(&mut best, (finish[dep][dev], is_comp(dep), dep, dev));
                    }
                }
                let s = best.map_or(0.0, |c| c.0);
                for dev in 0..d {
                    start[i][dev] = s;
                    finish[i][dev] = s + node.dur[dev];
                    pred[i][dev] = best.map(|c| (c.2, c.3));
                    comm_last[dev] = Some(i);
                }
            }
        }
    }

    // Terminal: the last-finishing (node, device), same tie-break as the
    // per-start predecessor choice.
    let mut terminal: Option<Cand> = None;
    for i in 0..n {
        for dev in 0..d {
            consider(&mut terminal, (finish[i][dev], is_comp(i), i, dev));
        }
    }
    let makespan = terminal.map_or(0.0, |c| c.0);

    // Critical path: walk predecessors back from the terminal, then
    // charge durations in chronological order (same addition order as
    // `Schedule::exposed_breakdown` on the barrier lowering).
    let mut path: Vec<(usize, usize)> = Vec::new();
    let mut cur = terminal.map(|c| (c.2, c.3));
    while let Some((i, dev)) = cur {
        path.push((i, dev));
        cur = pred[i][dev];
    }
    path.reverse();
    let mut exposed: BTreeMap<&'static str, f64> = BTreeMap::new();
    let n_blocks = dag.max_block().map_or(0, |b| b + 1);
    let mut per_block_exposed = vec![0.0; n_blocks];
    for &(i, dev) in &path {
        let dur = nodes[i].dur[dev];
        if dur > 0.0 {
            *exposed.entry(nodes[i].op.breakdown_key()).or_insert(0.0) += dur;
            per_block_exposed[nodes[i].op.block()] += dur;
        }
    }

    // Per-device stream/idle accounting (interval arithmetic over the
    // placed ops).
    let mut devices = Vec::with_capacity(d);
    for dev in 0..d {
        let mut comp_iv: Vec<(f64, f64)> = Vec::new();
        let mut comm_iv: Vec<(f64, f64)> = Vec::new();
        let mut busy_comp = 0.0;
        let mut busy_comm = 0.0;
        let mut dev_finish = 0.0f64;
        for (i, node) in nodes.iter().enumerate() {
            let dur = node.dur[dev];
            dev_finish = dev_finish.max(finish[i][dev]);
            if dur <= 0.0 {
                continue;
            }
            match node.op.stream() {
                Stream::Comp => {
                    busy_comp += dur;
                    comp_iv.push((start[i][dev], finish[i][dev]));
                }
                Stream::Comm => {
                    busy_comm += dur;
                    comm_iv.push((start[i][dev], finish[i][dev]));
                }
            }
        }
        let comp_merged = merge(&mut comp_iv);
        let exposed_comm: f64 =
            comm_iv.iter().map(|&iv| uncovered(iv, &comp_merged)).sum();
        let mut all = comp_merged.clone();
        all.extend(comm_iv.iter().copied());
        let covered: f64 = merge(&mut all).iter().map(|&(a, b)| b - a).sum();
        devices.push(DeviceStats {
            busy_comp,
            busy_comm,
            exposed_comm,
            idle: (makespan - covered).max(0.0),
            finish: dev_finish,
        });
    }
    // Straggler: the busiest device (ties -> lowest id).  Synchronized
    // collectives drag every device's FINISH to nearly the same instant,
    // so "finishes last" cannot identify the cause; the device whose
    // streams work longest is the one the others idle-wait on.
    let mut straggler = 0;
    for (i, s) in devices.iter().enumerate().skip(1) {
        let cur = &devices[straggler];
        if s.busy_comp + s.busy_comm > cur.busy_comp + cur.busy_comm {
            straggler = i;
        }
    }

    DesResult {
        makespan,
        start,
        finish,
        exposed,
        per_block_exposed,
        devices,
        straggler,
    }
}

/// Sort and merge half-open busy intervals; returns the disjoint union.
fn merge(intervals: &mut [(f64, f64)]) -> Vec<(f64, f64)> {
    intervals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(intervals.len());
    for &(a, b) in intervals.iter() {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Length of `iv` not covered by the disjoint sorted `cover` intervals.
fn uncovered(iv: (f64, f64), cover: &[(f64, f64)]) -> f64 {
    let (a, b) = iv;
    let mut exposed = b - a;
    for &(ca, cb) in cover {
        if cb <= a {
            continue;
        }
        if ca >= b {
            break;
        }
        exposed -= cb.min(b) - ca.max(a);
    }
    exposed.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::dag::{from_schedule, OpDag};
    use crate::scheduler::{A2aPhase, Op, OpInstance, Schedule, Stage};

    fn inst(op: Op, dur: f64) -> OpInstance {
        OpInstance::new(op, dur)
    }

    #[test]
    fn empty_dag_is_trivial() {
        let r = execute(&OpDag::new(4));
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.devices.len(), 4);
        assert!(r.exposed.is_empty());
        assert_eq!(r.straggler, 0);
    }

    #[test]
    fn comp_and_comm_overlap_within_a_device() {
        // FEC (2s, comp) issued first; an independent Trans (1s, comm)
        // overlaps it entirely.
        let mut dag = OpDag::new(1);
        dag.push_uniform(Op::Fec { block: 0 }, 2.0, vec![]);
        dag.push_uniform(Op::Trans { block: 0, part: 0 }, 1.0, vec![]);
        let r = execute(&dag);
        assert_eq!(r.makespan, 2.0);
        assert_eq!(r.devices[0].busy_comp, 2.0);
        assert_eq!(r.devices[0].busy_comm, 1.0);
        assert_eq!(r.devices[0].exposed_comm, 0.0, "comm fully hidden");
        assert_eq!(r.devices[0].idle, 0.0);
        assert_eq!(r.exposed.get("expert_comp"), Some(&2.0));
        assert_eq!(r.exposed.get("place"), None, "hidden comm not charged");
    }

    #[test]
    fn dependency_serializes_across_streams() {
        let mut dag = OpDag::new(1);
        let a = dag.push_uniform(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, 1.0, vec![]);
        dag.push_uniform(Op::Fec { block: 0 }, 2.0, vec![a]);
        let r = execute(&dag);
        assert_eq!(r.makespan, 3.0);
        assert_eq!(r.start[1][0], 1.0);
        assert_eq!(r.exposed.get("a2a"), Some(&1.0));
        assert_eq!(r.exposed.get("expert_comp"), Some(&2.0));
        // Comm had nothing to hide under: fully exposed on the device.
        assert_eq!(r.devices[0].exposed_comm, 1.0);
    }

    #[test]
    fn collectives_synchronize_across_devices() {
        // Device 1's FEC is slower; the following A2A (collective) must
        // wait for it on BOTH devices.
        let mut dag = OpDag::new(2);
        let f = dag.push(Op::Fec { block: 0 }, vec![1.0, 3.0], vec![]);
        dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.5, 0.5], vec![f]);
        let r = execute(&dag);
        assert_eq!(r.start[1][0], 3.0, "device 0 waits for device 1's FEC");
        assert_eq!(r.makespan, 3.5);
        assert_eq!(r.straggler, 1);
        // Device 0 idles from 1.0 to 3.0.
        assert!((r.devices[0].idle - 2.0).abs() < 1e-12);
        assert_eq!(r.devices[1].idle, 0.0);
    }

    #[test]
    fn comp_deps_are_device_local() {
        // A per-device comp chain: device 0 finishes earlier and does NOT
        // wait for device 1 (no collective in between).
        let mut dag = OpDag::new(2);
        let f = dag.push(Op::Fec { block: 0 }, vec![1.0, 3.0], vec![]);
        dag.push(Op::Fnec { block: 0 }, vec![1.0, 1.0], vec![f]);
        let r = execute(&dag);
        assert_eq!(r.start[1][0], 1.0);
        assert_eq!(r.start[1][1], 3.0);
        assert_eq!(r.makespan, 4.0);
    }

    #[test]
    fn exposed_sums_to_makespan() {
        let mut dag = OpDag::new(2);
        let a = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, vec![0.5, 1.0], vec![]);
        let f = dag.push(Op::Fec { block: 0 }, vec![2.0, 1.0], vec![a]);
        dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.25, 0.25], vec![f]);
        let r = execute(&dag);
        let total: f64 = r.exposed.values().sum();
        assert!((total - r.makespan).abs() < 1e-12, "{total} vs {}", r.makespan);
        let per_block: f64 = r.per_block_exposed.iter().sum();
        assert!((per_block - r.makespan).abs() < 1e-12);
    }

    #[test]
    fn barrier_lowering_matches_stage_model_bitwise() {
        // The module-level equivalence property on a hand-built schedule
        // (the policy-driven gate lives in integration_timeline.rs).
        let sched = Schedule {
            stages: vec![
                Stage::comm_only(vec![inst(Op::Trans { block: 0, part: 0 }, 0.7)]),
                Stage::pair(
                    vec![inst(Op::Fec { block: 0 }, 2.0)],
                    vec![inst(Op::Trans { block: 1, part: 0 }, 3.0)],
                ),
                Stage::pair(
                    vec![inst(Op::Plan { block: 0 }, 0.4)],
                    vec![inst(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, 0.4)],
                ),
                Stage::comp_only(vec![inst(Op::Fnec { block: 0 }, 1.1)]),
            ],
        };
        let r = execute(&from_schedule(&sched, 4));
        assert_eq!(r.makespan.to_bits(), sched.total_time().to_bits());
        let want = sched.exposed_breakdown();
        assert_eq!(r.exposed.keys().collect::<Vec<_>>(), want.keys().collect::<Vec<_>>());
        for (k, v) in &want {
            assert_eq!(r.exposed[k].to_bits(), v.to_bits(), "key {k}");
        }
        // Equal-duration stage 2: comp wins the tie, like the Stage rule.
        assert_eq!(r.exposed.get("search"), Some(&0.4));
        assert_eq!(r.exposed.get("a2a"), None);
    }

    #[test]
    fn straggler_prefers_lowest_id_on_ties() {
        let mut dag = OpDag::new(3);
        dag.push(Op::Fec { block: 0 }, vec![1.0, 1.0, 1.0], vec![]);
        let r = execute(&dag);
        assert_eq!(r.straggler, 0);
    }

    #[test]
    fn interval_helpers() {
        let mut iv = vec![(2.0, 3.0), (0.0, 1.0), (0.5, 1.5)];
        assert_eq!(merge(&mut iv), vec![(0.0, 1.5), (2.0, 3.0)]);
        assert_eq!(uncovered((0.0, 4.0), &[(0.0, 1.5), (2.0, 3.0)]), 1.5);
        assert_eq!(uncovered((1.5, 2.0), &[(0.0, 1.5), (2.0, 3.0)]), 0.5);
        assert_eq!(uncovered((0.0, 1.0), &[(0.0, 2.0)]), 0.0);
    }
}
