//! Device-level discrete-event executor: plays an [`OpDag`] onto one
//! (compute, communication) stream pair **per device** and reports the
//! per-device critical path.
//!
//! This is the successor of pricing an iteration as a single global
//! two-stream [`crate::scheduler::Schedule`]: instead of collapsing every
//! operator to a scalar (the max over devices) before the timeline sees
//! it, ops carry per-device duration vectors and the makespan emerges
//! from per-device stream occupancy plus the DAG's dependency edges —
//! which is where stragglers, per-device exposed communication, and
//! heterogeneous clusters become visible (paper §V, Fig 7/8).
//!
//! # Semantics
//!
//! * Nodes execute in issue order on each stream (FIFO per device, one
//!   comp + one comm stream — the CUDA/NCCL pair).
//! * A **compute** node starts on device `d` when `d`'s comp stream is
//!   free and all its dependencies have finished **on `d`** (its inputs
//!   are device-local).
//! * A **communication** node is a collective: it starts on *all* devices
//!   at once, when every device's comm stream is free and every
//!   dependency has finished on every device; it then occupies device
//!   `d`'s comm stream for its per-device duration.
//! * The **critical path** is recovered by walking back from the
//!   last-finishing (node, device) through whichever predecessor
//!   determined each start time.  Ties prefer compute-stream sources
//!   (matching `Schedule::exposed_breakdown`'s `comp >= comm` rule), then
//!   the later node, then the lower device.  Charging the path's
//!   durations by [`crate::scheduler::Op::breakdown_key`] yields an
//!   exposed breakdown that sums exactly to the makespan.
//!
//! # Hot path: [`ExecScratch`] + [`execute_with`]
//!
//! The sweep is O(n·d) over SoA arena rows: a compute node's start is an
//! elementwise `f64::max` fold of its dependencies' finish **rows** into
//! one accumulator row, and a collective's synchronized start is a
//! branch-light horizontal max over that row — both autovectorizable.
//! All working memory (flat start/finish matrices, the accumulator row,
//! per-stream predecessor ids, interval buffers, the path stack) lives in
//! a caller-owned [`ExecScratch`] reused across layers, iterations, and
//! fleet tenants (the discipline `planner::SearchScratch` set), so the
//! steady state allocates nothing per call.  No predecessor matrix is
//! stored: the critical-path walk *recomputes* each step's predecessor
//! from the per-stream FIFO ids + dependency edges with the same
//! tie-break — the tie-break is a strict total order, so argmax does not
//! depend on scan order and the recomputation is exact.
//!
//! [`execute`] wraps `execute_with` with a fresh scratch and retains the
//! per-(node, device) start/finish instants ([`DesTimes`]) for trace
//! export; the hot path leaves `times` as `None`.
//!
//! # Oracle equivalence
//!
//! On a barrier-shaped DAG with uniform per-device durations
//! ([`crate::scheduler::dag::from_schedule`]), the executor reproduces
//! the frozen Stage model's `total_time()` and `exposed_breakdown()`
//! **bit-for-bit** (every start is a `max` of previously computed finish
//! times — the same additions in the same order).  That equivalence is
//! pinned for all built-in policies in
//! `rust/tests/integration_timeline.rs`; relaxing the barriers
//! ([`crate::scheduler::build_blockwise_dag`]) and slowing devices
//! ([`crate::cluster::ClusterSpec::with_slowdown`]) are the new
//! capabilities on top.
//!
//! [`execute_reference`] preserves the pre-arena executor (nested
//! per-node vectors, stored predecessor matrix, candidate-at-a-time
//! scans) as a **frozen oracle**: `prop_execute_matches_reference`
//! (rust/tests/property_tests.rs) pins the restructured engine to it
//! bit-for-bit over random DAGs × random per-device durations.  Do not
//! "optimize" the reference; change it only in lockstep with an
//! intentional semantic change.

use crate::scheduler::dag::OpDag;
use crate::scheduler::Stream;
use std::collections::BTreeMap;

/// Per-device stream/idle accounting of one executed DAG.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceStats {
    /// Seconds the device's compute stream is busy.
    pub busy_comp: f64,
    /// Seconds the device's communication stream is busy.
    pub busy_comm: f64,
    /// Communication seconds NOT overlapped by computation on this
    /// device — the per-device "exposed communication" of §V.
    pub exposed_comm: f64,
    /// Seconds neither stream is busy, up to the global makespan (a
    /// straggler elsewhere shows up as idle time here).
    pub idle: f64,
    /// When this device's last op finishes.
    pub finish: f64,
}

/// Per-(node, device) start/finish instants of one executed DAG, stored
/// row-major like the [`OpDag`] duration arena.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DesTimes {
    n_devices: usize,
    start: Vec<f64>,
    finish: Vec<f64>,
}

impl DesTimes {
    /// When `node` starts on `dev` (seconds).
    #[inline]
    pub fn start(&self, node: usize, dev: usize) -> f64 {
        self.start[node * self.n_devices + dev]
    }

    /// When `node` finishes on `dev` (seconds).
    #[inline]
    pub fn finish(&self, node: usize, dev: usize) -> f64 {
        self.finish[node * self.n_devices + dev]
    }
}

/// Outcome of executing an [`OpDag`].
#[derive(Clone, Debug, Default)]
pub struct DesResult {
    /// Iteration time: the per-device critical path (latest finish over
    /// all nodes and devices).
    pub makespan: f64,
    /// Exposed seconds per breakdown category, from critical-path
    /// attribution; values sum to `makespan`.
    pub exposed: BTreeMap<&'static str, f64>,
    /// Exposed seconds per block id (critical-path attribution; sums to
    /// `makespan` like `exposed`).
    pub per_block_exposed: Vec<f64>,
    /// Per-device stream/idle accounting.
    pub devices: Vec<DeviceStats>,
    /// The iteration's straggler: the device whose streams are busy
    /// longest (ties -> lowest id) — the one the others idle-wait on at
    /// collectives.
    pub straggler: usize,
    /// Per-(node, device) start/finish instants.  `Some` from
    /// [`execute`] / [`execute_reference`] (trace export needs them);
    /// `None` from the hot [`execute_with`] path, whose scratch keeps
    /// the matrices for reuse instead.
    pub times: Option<DesTimes>,
}

impl DesResult {
    /// When `node` starts on `dev`.  Panics if times were not retained
    /// (use [`execute`], not [`execute_with`], when you need them).
    pub fn start(&self, node: usize, dev: usize) -> f64 {
        self.times
            .as_ref()
            .expect("DesResult::start: times not retained (use events::execute)")
            .start(node, dev)
    }

    /// When `node` finishes on `dev`.  Panics if times were not retained.
    pub fn finish(&self, node: usize, dev: usize) -> f64 {
        self.times
            .as_ref()
            .expect("DesResult::finish: times not retained (use events::execute)")
            .finish(node, dev)
    }
}

/// Candidate source of a start time: (finish, from-comp-stream, node,
/// device).  `better` is the tie-break order documented in the module
/// docs.
type Cand = (f64, bool, usize, usize);

fn better(a: Cand, b: Cand) -> bool {
    if a.0 != b.0 {
        return a.0 > b.0;
    }
    if a.1 != b.1 {
        return a.1;
    }
    if a.2 != b.2 {
        return a.2 > b.2;
    }
    a.3 < b.3
}

fn consider(best: &mut Option<Cand>, cand: Cand) {
    let replace = match best {
        None => true,
        Some(b) => better(cand, *b),
    };
    if replace {
        *best = Some(cand);
    }
}

/// "No node" sentinel for the per-stream FIFO predecessor arrays
/// ([`OpDag`] asserts node counts stay below `u32::MAX`).
const NONE32: u32 = u32::MAX;

#[inline]
fn is_comp(dag: &OpDag, i: usize) -> bool {
    dag.op(i).stream() == Stream::Comp
}

/// Reusable working memory for [`execute_with`] — flat start/finish
/// matrices, the collective accumulator row, per-stream FIFO predecessor
/// ids, interval-accounting buffers, and the critical-path stack.
///
/// Owned by the *caller* (one per pricing loop: `sim::PriceState` holds
/// one per simulation run, each fleet tenant holds one) and reused across
/// layers and iterations; buffers grow to the largest DAG seen and then
/// stay allocation-free.  A scratch carries no results between calls —
/// every buffer is fully rewritten — so reuse is bit-identical to a
/// fresh scratch (pinned by `scratch_reuse_is_bit_identical`).
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// Row-major start/finish instants: node `i`, device `dev` at
    /// `i * n_devices + dev`.
    start: Vec<f64>,
    finish: Vec<f64>,
    /// Per-device accumulator row for dependency max-folds.
    acc: Vec<f64>,
    /// Previous node on node `i`'s own stream when `i` issued
    /// (`NONE32` = stream was empty) — enough to recompute any
    /// predecessor on demand during the critical-path walk.
    prev: Vec<u32>,
    comp_iv: Vec<(f64, f64)>,
    comm_iv: Vec<(f64, f64)>,
    merged: Vec<(f64, f64)>,
    all_iv: Vec<(f64, f64)>,
    path: Vec<(usize, usize)>,
}

impl ExecScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Recompute the predecessor of `(i, dev)` — the candidate that
/// determined its start — from the per-stream FIFO ids and dependency
/// edges.  Exact: the candidate set is identical to the sweep's and the
/// tie-break is a strict total order, so the argmax is scan-order
/// independent.
fn pred_of(dag: &OpDag, finish: &[f64], prev: &[u32], i: usize, dev: usize) -> Option<(usize, usize)> {
    let d = dag.n_devices;
    let mut best: Option<Cand> = None;
    match dag.op(i).stream() {
        Stream::Comp => {
            if prev[i] != NONE32 {
                let p = prev[i] as usize;
                consider(&mut best, (finish[p * d + dev], true, p, dev));
            }
            for dep in dag.deps_of(i) {
                consider(&mut best, (finish[dep * d + dev], is_comp(dag, dep), dep, dev));
            }
        }
        Stream::Comm => {
            for dv in 0..d {
                if prev[i] != NONE32 {
                    let p = prev[i] as usize;
                    consider(&mut best, (finish[p * d + dv], false, p, dv));
                }
                for dep in dag.deps_of(i) {
                    consider(&mut best, (finish[dep * d + dv], is_comp(dag, dep), dep, dv));
                }
            }
        }
    }
    best.map(|c| (c.2, c.3))
}

/// Execute `dag` and return per-device stats and the critical-path
/// exposed breakdown.  Hot path: all working memory comes from
/// `scratch`, nothing per-(node, device) is allocated, and the result's
/// `times` is `None` (use [`execute`] when start/finish instants are
/// needed, e.g. for trace export).
///
/// Bit-identical to [`execute_reference`] on every valid DAG — durations
/// are finite and non-negative ([`OpDag::validate`]), so finish times
/// are never NaN or -0.0 and the 0.0-seeded `f64::max` folds reproduce
/// the reference's candidate scans exactly.
pub fn execute_with(dag: &OpDag, scratch: &mut ExecScratch) -> DesResult {
    debug_assert!(dag.validate().is_ok(), "invalid DAG: {:?}", dag.validate());
    let d = dag.n_devices;
    let n = dag.len();
    let ExecScratch {
        start,
        finish,
        acc,
        prev,
        comp_iv,
        comm_iv,
        merged,
        all_iv,
        path,
    } = scratch;
    // Every cell below is overwritten by the sweep; no zeroing needed.
    start.resize(n * d, 0.0);
    finish.resize(n * d, 0.0);
    acc.resize(d, 0.0);
    prev.resize(n, NONE32);
    // Last node issued on each stream — identical on every device (both
    // sweep arms issue on all devices at once), hence scalars.
    let mut comp_last = NONE32;
    let mut comm_last = NONE32;

    for i in 0..n {
        let dur = dag.dur(i);
        match dag.op(i).stream() {
            Stream::Comp => {
                // Device-local start: max over the comp-stream FIFO
                // predecessor and every dependency, elementwise per
                // device.
                match comp_last {
                    NONE32 => acc.fill(0.0),
                    p => acc.copy_from_slice(&finish[p as usize * d..(p as usize + 1) * d]),
                }
                for dep in dag.deps_of(i) {
                    let row = &finish[dep * d..(dep + 1) * d];
                    for (a, &f) in acc.iter_mut().zip(row) {
                        *a = a.max(f);
                    }
                }
                start[i * d..(i + 1) * d].copy_from_slice(acc);
                for dev in 0..d {
                    finish[i * d + dev] = acc[dev] + dur[dev];
                }
                prev[i] = comp_last;
                comp_last = i as u32;
            }
            Stream::Comm => {
                // Collective: one synchronized start across all devices
                // — the horizontal max of the same accumulator row.
                match comm_last {
                    NONE32 => acc.fill(0.0),
                    p => acc.copy_from_slice(&finish[p as usize * d..(p as usize + 1) * d]),
                }
                for dep in dag.deps_of(i) {
                    let row = &finish[dep * d..(dep + 1) * d];
                    for (a, &f) in acc.iter_mut().zip(row) {
                        *a = a.max(f);
                    }
                }
                let s = acc.iter().copied().fold(0.0f64, f64::max);
                start[i * d..(i + 1) * d].fill(s);
                for dev in 0..d {
                    finish[i * d + dev] = s + dur[dev];
                }
                prev[i] = comm_last;
                comm_last = i as u32;
            }
        }
    }

    // Makespan: flat max over all finishes (all >= 0.0, never -0.0, so
    // the 0.0 seed is exact); then the terminal (node, device) among the
    // cells attaining it, same tie-break as the per-start choice.
    let makespan = finish[..n * d].iter().copied().fold(0.0f64, f64::max);
    let mut terminal: Option<Cand> = None;
    for i in 0..n {
        let ic = is_comp(dag, i);
        for (dev, &f) in finish[i * d..(i + 1) * d].iter().enumerate() {
            if f == makespan {
                consider(&mut terminal, (f, ic, i, dev));
            }
        }
    }

    // Critical path: walk predecessors back from the terminal (each one
    // recomputed on demand — see `pred_of`), then charge durations in
    // chronological order (same addition order as
    // `Schedule::exposed_breakdown` on the barrier lowering).
    path.clear();
    let mut cur = terminal.map(|c| (c.2, c.3));
    while let Some((i, dev)) = cur {
        path.push((i, dev));
        cur = pred_of(dag, finish, prev, i, dev);
    }
    path.reverse();
    let mut exposed: BTreeMap<&'static str, f64> = BTreeMap::new();
    let n_blocks = dag.max_block().map_or(0, |b| b + 1);
    let mut per_block_exposed = vec![0.0; n_blocks];
    for &(i, dev) in path.iter() {
        let dur = dag.dur(i)[dev];
        if dur > 0.0 {
            *exposed.entry(dag.op(i).breakdown_key()).or_insert(0.0) += dur;
            per_block_exposed[dag.op(i).block()] += dur;
        }
    }

    // Per-device stream/idle accounting (interval arithmetic over the
    // placed ops).
    let mut devices = Vec::with_capacity(d);
    for dev in 0..d {
        comp_iv.clear();
        comm_iv.clear();
        let mut busy_comp = 0.0;
        let mut busy_comm = 0.0;
        let mut dev_finish = 0.0f64;
        for i in 0..n {
            let dur = dag.dur(i)[dev];
            dev_finish = dev_finish.max(finish[i * d + dev]);
            if dur <= 0.0 {
                continue;
            }
            let iv = (start[i * d + dev], finish[i * d + dev]);
            match dag.op(i).stream() {
                Stream::Comp => {
                    busy_comp += dur;
                    comp_iv.push(iv);
                }
                Stream::Comm => {
                    busy_comm += dur;
                    comm_iv.push(iv);
                }
            }
        }
        merge_into(comp_iv, merged);
        let exposed_comm: f64 = comm_iv.iter().map(|&iv| uncovered(iv, merged)).sum();
        all_iv.clear();
        all_iv.extend_from_slice(merged);
        all_iv.extend_from_slice(comm_iv);
        all_iv.sort_by(cmp_iv);
        let covered = covered_len(all_iv);
        devices.push(DeviceStats {
            busy_comp,
            busy_comm,
            exposed_comm,
            idle: (makespan - covered).max(0.0),
            finish: dev_finish,
        });
    }
    // Straggler: the busiest device (ties -> lowest id).  Synchronized
    // collectives drag every device's FINISH to nearly the same instant,
    // so "finishes last" cannot identify the cause; the device whose
    // streams work longest is the one the others idle-wait on.
    let mut straggler = 0;
    for (i, s) in devices.iter().enumerate().skip(1) {
        let cur = &devices[straggler];
        if s.busy_comp + s.busy_comm > cur.busy_comp + cur.busy_comm {
            straggler = i;
        }
    }

    DesResult {
        makespan,
        exposed,
        per_block_exposed,
        devices,
        straggler,
        times: None,
    }
}

/// Execute `dag` with a private scratch and retain the per-(node,
/// device) start/finish instants in the result's `times` — the
/// convenience form for trace export and tests.  Pricing loops should
/// hold an [`ExecScratch`] and call [`execute_with`] instead.
pub fn execute(dag: &OpDag) -> DesResult {
    let mut scratch = ExecScratch::new();
    let mut r = execute_with(dag, &mut scratch);
    r.times = Some(DesTimes {
        n_devices: dag.n_devices,
        start: std::mem::take(&mut scratch.start),
        finish: std::mem::take(&mut scratch.finish),
    });
    r
}

/// The pre-arena executor, preserved verbatim as a frozen equivalence
/// oracle (nested per-node vectors, stored predecessor matrix,
/// candidate-at-a-time scans).  `prop_execute_matches_reference` pins
/// [`execute`] / [`execute_with`] to this bit-for-bit; see the module
/// docs before touching it.
pub fn execute_reference(dag: &OpDag) -> DesResult {
    debug_assert!(dag.validate().is_ok(), "invalid DAG: {:?}", dag.validate());
    let d = dag.n_devices;
    let n = dag.len();
    let mut start = vec![vec![0.0f64; d]; n];
    let mut finish = vec![vec![0.0f64; d]; n];
    // Which (node, device) determined each start (None = started at 0).
    let mut pred: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; d]; n];
    // Last node issued on each device's comp / comm stream.
    let mut comp_last: Vec<Option<usize>> = vec![None; d];
    let mut comm_last: Vec<Option<usize>> = vec![None; d];

    for i in 0..n {
        match dag.op(i).stream() {
            Stream::Comp => {
                for dev in 0..d {
                    let mut best: Option<Cand> = None;
                    if let Some(p) = comp_last[dev] {
                        consider(&mut best, (finish[p][dev], true, p, dev));
                    }
                    for dep in dag.deps_of(i) {
                        consider(&mut best, (finish[dep][dev], is_comp(dag, dep), dep, dev));
                    }
                    let s = best.map_or(0.0, |c| c.0);
                    start[i][dev] = s;
                    finish[i][dev] = s + dag.dur(i)[dev];
                    pred[i][dev] = best.map(|c| (c.2, c.3));
                    comp_last[dev] = Some(i);
                }
            }
            Stream::Comm => {
                // Collective: one synchronized start across all devices.
                let mut best: Option<Cand> = None;
                for dev in 0..d {
                    if let Some(p) = comm_last[dev] {
                        consider(&mut best, (finish[p][dev], false, p, dev));
                    }
                    for dep in dag.deps_of(i) {
                        consider(&mut best, (finish[dep][dev], is_comp(dag, dep), dep, dev));
                    }
                }
                let s = best.map_or(0.0, |c| c.0);
                for dev in 0..d {
                    start[i][dev] = s;
                    finish[i][dev] = s + dag.dur(i)[dev];
                    pred[i][dev] = best.map(|c| (c.2, c.3));
                    comm_last[dev] = Some(i);
                }
            }
        }
    }

    // Terminal: the last-finishing (node, device), same tie-break as the
    // per-start predecessor choice.
    let mut terminal: Option<Cand> = None;
    for i in 0..n {
        for dev in 0..d {
            consider(&mut terminal, (finish[i][dev], is_comp(dag, i), i, dev));
        }
    }
    let makespan = terminal.map_or(0.0, |c| c.0);

    // Critical path: walk predecessors back from the terminal, then
    // charge durations in chronological order.
    let mut path: Vec<(usize, usize)> = Vec::new();
    let mut cur = terminal.map(|c| (c.2, c.3));
    while let Some((i, dev)) = cur {
        path.push((i, dev));
        cur = pred[i][dev];
    }
    path.reverse();
    let mut exposed: BTreeMap<&'static str, f64> = BTreeMap::new();
    let n_blocks = dag.max_block().map_or(0, |b| b + 1);
    let mut per_block_exposed = vec![0.0; n_blocks];
    for &(i, dev) in &path {
        let dur = dag.dur(i)[dev];
        if dur > 0.0 {
            *exposed.entry(dag.op(i).breakdown_key()).or_insert(0.0) += dur;
            per_block_exposed[dag.op(i).block()] += dur;
        }
    }

    // Per-device stream/idle accounting (interval arithmetic over the
    // placed ops).
    let mut devices = Vec::with_capacity(d);
    for dev in 0..d {
        let mut comp_iv: Vec<(f64, f64)> = Vec::new();
        let mut comm_iv: Vec<(f64, f64)> = Vec::new();
        let mut busy_comp = 0.0;
        let mut busy_comm = 0.0;
        let mut dev_finish = 0.0f64;
        for i in 0..n {
            let dur = dag.dur(i)[dev];
            dev_finish = dev_finish.max(finish[i][dev]);
            if dur <= 0.0 {
                continue;
            }
            match dag.op(i).stream() {
                Stream::Comp => {
                    busy_comp += dur;
                    comp_iv.push((start[i][dev], finish[i][dev]));
                }
                Stream::Comm => {
                    busy_comm += dur;
                    comm_iv.push((start[i][dev], finish[i][dev]));
                }
            }
        }
        let comp_merged = merge(&mut comp_iv);
        let exposed_comm: f64 =
            comm_iv.iter().map(|&iv| uncovered(iv, &comp_merged)).sum();
        let mut all = comp_merged.clone();
        all.extend(comm_iv.iter().copied());
        let covered: f64 = merge(&mut all).iter().map(|&(a, b)| b - a).sum();
        devices.push(DeviceStats {
            busy_comp,
            busy_comm,
            exposed_comm,
            idle: (makespan - covered).max(0.0),
            finish: dev_finish,
        });
    }
    let mut straggler = 0;
    for (i, s) in devices.iter().enumerate().skip(1) {
        let cur = &devices[straggler];
        if s.busy_comp + s.busy_comm > cur.busy_comp + cur.busy_comm {
            straggler = i;
        }
    }

    let flat = |m: Vec<Vec<f64>>| m.into_iter().flatten().collect::<Vec<f64>>();
    DesResult {
        makespan,
        exposed,
        per_block_exposed,
        devices,
        straggler,
        times: Some(DesTimes {
            n_devices: d,
            start: flat(start),
            finish: flat(finish),
        }),
    }
}

/// Total order on intervals: lexicographic `f64::total_cmp`.  The old
/// `partial_cmp(..).unwrap_or(Equal)` made the sort *incomparable*-NaN
/// dependent on input order; `total_cmp` also fixes -0.0 vs +0.0 to one
/// deterministic order.  On valid DAGs (finite, >= 0.0 durations) the
/// two orders agree, so this is bit-identical where it matters and
/// deterministic everywhere.
fn cmp_iv(a: &(f64, f64), b: &(f64, f64)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1))
}

/// Sort `intervals` in place and write their disjoint union into `out`.
fn merge_into(intervals: &mut [(f64, f64)], out: &mut Vec<(f64, f64)>) {
    intervals.sort_by(cmp_iv);
    out.clear();
    for &(a, b) in intervals.iter() {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
}

/// Sort and merge half-open busy intervals; returns the disjoint union.
fn merge(intervals: &mut [(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(intervals.len());
    merge_into(intervals, &mut out);
    out
}

/// Total covered length of sorted intervals — `merge` fused with the
/// length sum (same merge walk, same addition order), minus the
/// intermediate vector.
fn covered_len(sorted: &[(f64, f64)]) -> f64 {
    let mut total = 0.0f64;
    let mut cur: Option<(f64, f64)> = None;
    for &(a, b) in sorted {
        match cur {
            Some((ca, cb)) if a <= cb => cur = Some((ca, cb.max(b))),
            Some((ca, cb)) => {
                total += cb - ca;
                cur = Some((a, b));
            }
            None => cur = Some((a, b)),
        }
    }
    if let Some((ca, cb)) = cur {
        total += cb - ca;
    }
    total
}

/// Length of `iv` not covered by the disjoint sorted `cover` intervals.
fn uncovered(iv: (f64, f64), cover: &[(f64, f64)]) -> f64 {
    let (a, b) = iv;
    let mut exposed = b - a;
    for &(ca, cb) in cover {
        if cb <= a {
            continue;
        }
        if ca >= b {
            break;
        }
        exposed -= cb.min(b) - ca.max(a);
    }
    exposed.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::dag::{from_schedule, OpDag};
    use crate::scheduler::{A2aPhase, Op, OpInstance, Schedule, Stage};

    fn inst(op: Op, dur: f64) -> OpInstance {
        OpInstance::new(op, dur)
    }

    /// Bitwise comparison of everything a DesResult reports, including
    /// the times when both sides retain them.
    fn assert_bit_eq(a: &DesResult, b: &DesResult) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(
            a.exposed.keys().collect::<Vec<_>>(),
            b.exposed.keys().collect::<Vec<_>>()
        );
        for (k, v) in &a.exposed {
            assert_eq!(v.to_bits(), b.exposed[k].to_bits(), "exposed[{k}]");
        }
        assert_eq!(a.per_block_exposed.len(), b.per_block_exposed.len());
        for (x, y) in a.per_block_exposed.iter().zip(&b.per_block_exposed) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.devices.len(), b.devices.len());
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.busy_comp.to_bits(), y.busy_comp.to_bits());
            assert_eq!(x.busy_comm.to_bits(), y.busy_comm.to_bits());
            assert_eq!(x.exposed_comm.to_bits(), y.exposed_comm.to_bits());
            assert_eq!(x.idle.to_bits(), y.idle.to_bits());
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
        assert_eq!(a.straggler, b.straggler);
        if let (Some(ta), Some(tb)) = (&a.times, &b.times) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn empty_dag_is_trivial() {
        let r = execute(&OpDag::new(4));
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.devices.len(), 4);
        assert!(r.exposed.is_empty());
        assert_eq!(r.straggler, 0);
        assert_bit_eq(&r, &execute_reference(&OpDag::new(4)));
    }

    #[test]
    fn comp_and_comm_overlap_within_a_device() {
        // FEC (2s, comp) issued first; an independent Trans (1s, comm)
        // overlaps it entirely.
        let mut dag = OpDag::new(1);
        dag.push_uniform(Op::Fec { block: 0 }, 2.0, vec![]);
        dag.push_uniform(Op::Trans { block: 0, part: 0 }, 1.0, vec![]);
        let r = execute(&dag);
        assert_eq!(r.makespan, 2.0);
        assert_eq!(r.devices[0].busy_comp, 2.0);
        assert_eq!(r.devices[0].busy_comm, 1.0);
        assert_eq!(r.devices[0].exposed_comm, 0.0, "comm fully hidden");
        assert_eq!(r.devices[0].idle, 0.0);
        assert_eq!(r.exposed.get("expert_comp"), Some(&2.0));
        assert_eq!(r.exposed.get("place"), None, "hidden comm not charged");
    }

    #[test]
    fn dependency_serializes_across_streams() {
        let mut dag = OpDag::new(1);
        let a = dag.push_uniform(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, 1.0, vec![]);
        dag.push_uniform(Op::Fec { block: 0 }, 2.0, vec![a]);
        let r = execute(&dag);
        assert_eq!(r.makespan, 3.0);
        assert_eq!(r.start(1, 0), 1.0);
        assert_eq!(r.exposed.get("a2a"), Some(&1.0));
        assert_eq!(r.exposed.get("expert_comp"), Some(&2.0));
        // Comm had nothing to hide under: fully exposed on the device.
        assert_eq!(r.devices[0].exposed_comm, 1.0);
    }

    #[test]
    fn collectives_synchronize_across_devices() {
        // Device 1's FEC is slower; the following A2A (collective) must
        // wait for it on BOTH devices.
        let mut dag = OpDag::new(2);
        let f = dag.push(Op::Fec { block: 0 }, vec![1.0, 3.0], vec![]);
        dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.5, 0.5], vec![f]);
        let r = execute(&dag);
        assert_eq!(r.start(1, 0), 3.0, "device 0 waits for device 1's FEC");
        assert_eq!(r.makespan, 3.5);
        assert_eq!(r.straggler, 1);
        // Device 0 idles from 1.0 to 3.0.
        assert!((r.devices[0].idle - 2.0).abs() < 1e-12);
        assert_eq!(r.devices[1].idle, 0.0);
    }

    #[test]
    fn comp_deps_are_device_local() {
        // A per-device comp chain: device 0 finishes earlier and does NOT
        // wait for device 1 (no collective in between).
        let mut dag = OpDag::new(2);
        let f = dag.push(Op::Fec { block: 0 }, vec![1.0, 3.0], vec![]);
        dag.push(Op::Fnec { block: 0 }, vec![1.0, 1.0], vec![f]);
        let r = execute(&dag);
        assert_eq!(r.start(1, 0), 1.0);
        assert_eq!(r.start(1, 1), 3.0);
        assert_eq!(r.makespan, 4.0);
    }

    #[test]
    fn exposed_sums_to_makespan() {
        let mut dag = OpDag::new(2);
        let a = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, vec![0.5, 1.0], vec![]);
        let f = dag.push(Op::Fec { block: 0 }, vec![2.0, 1.0], vec![a]);
        dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.25, 0.25], vec![f]);
        let r = execute(&dag);
        let total: f64 = r.exposed.values().sum();
        assert!((total - r.makespan).abs() < 1e-12, "{total} vs {}", r.makespan);
        let per_block: f64 = r.per_block_exposed.iter().sum();
        assert!((per_block - r.makespan).abs() < 1e-12);
    }

    #[test]
    fn barrier_lowering_matches_stage_model_bitwise() {
        // The module-level equivalence property on a hand-built schedule
        // (the policy-driven gate lives in integration_timeline.rs).
        let sched = Schedule {
            stages: vec![
                Stage::comm_only(vec![inst(Op::Trans { block: 0, part: 0 }, 0.7)]),
                Stage::pair(
                    vec![inst(Op::Fec { block: 0 }, 2.0)],
                    vec![inst(Op::Trans { block: 1, part: 0 }, 3.0)],
                ),
                Stage::pair(
                    vec![inst(Op::Plan { block: 0 }, 0.4)],
                    vec![inst(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, 0.4)],
                ),
                Stage::comp_only(vec![inst(Op::Fnec { block: 0 }, 1.1)]),
            ],
        };
        let r = execute(&from_schedule(&sched, 4));
        assert_eq!(r.makespan.to_bits(), sched.total_time().to_bits());
        let want = sched.exposed_breakdown();
        assert_eq!(r.exposed.keys().collect::<Vec<_>>(), want.keys().collect::<Vec<_>>());
        for (k, v) in &want {
            assert_eq!(r.exposed[k].to_bits(), v.to_bits(), "key {k}");
        }
        // Equal-duration stage 2: comp wins the tie, like the Stage rule.
        assert_eq!(r.exposed.get("search"), Some(&0.4));
        assert_eq!(r.exposed.get("a2a"), None);
    }

    #[test]
    fn straggler_prefers_lowest_id_on_ties() {
        let mut dag = OpDag::new(3);
        dag.push(Op::Fec { block: 0 }, vec![1.0, 1.0, 1.0], vec![]);
        let r = execute(&dag);
        assert_eq!(r.straggler, 0);
    }

    #[test]
    fn matches_reference_on_mixed_dag() {
        // Collectives, device-local chains, zero durations, straggler
        // ties — one DAG exercising every arm against the frozen oracle.
        let mut dag = OpDag::new(3);
        let a = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdDispatch }, vec![0.5, 1.0, 0.0], vec![]);
        let f = dag.push(Op::Fec { block: 0 }, vec![2.0, 1.0, 1.5], vec![a]);
        let t = dag.push(Op::Trans { block: 1, part: 0 }, vec![0.3, 0.3, 0.3], vec![]);
        let c = dag.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.25, 0.5, 0.25], vec![f, t]);
        dag.push(Op::Fnec { block: 1 }, vec![1.0, 0.0, 1.0], vec![c]);
        assert_bit_eq(&execute(&dag), &execute_reference(&dag));
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // One scratch across DAGs of different shapes/sizes (including
        // shrinking) — every result matches a fresh-scratch run bitwise.
        let mut big = OpDag::new(4);
        let f = big.push(Op::Fec { block: 0 }, vec![1.0, 2.0, 3.0, 4.0], vec![]);
        let a = big.push(Op::A2a { block: 0, phase: A2aPhase::FwdCombine }, vec![0.5; 4], vec![f]);
        big.push(Op::Fnec { block: 0 }, vec![2.0, 1.0, 1.0, 1.0], vec![a]);
        let mut small = OpDag::new(2);
        small.push(Op::Fec { block: 0 }, vec![9.0, 1.0], vec![]);
        let mut scratch = ExecScratch::new();
        for dag in [&big, &small, &big] {
            let hot = execute_with(dag, &mut scratch);
            assert!(hot.times.is_none(), "hot path must not retain times");
            assert_bit_eq(&hot, &execute(dag));
        }
    }

    #[test]
    fn interval_helpers() {
        let mut iv = vec![(2.0, 3.0), (0.0, 1.0), (0.5, 1.5)];
        assert_eq!(merge(&mut iv), vec![(0.0, 1.5), (2.0, 3.0)]);
        assert_eq!(uncovered((0.0, 4.0), &[(0.0, 1.5), (2.0, 3.0)]), 1.5);
        assert_eq!(uncovered((1.5, 2.0), &[(0.0, 1.5), (2.0, 3.0)]), 0.5);
        assert_eq!(uncovered((0.0, 1.0), &[(0.0, 2.0)]), 0.0);
        assert_eq!(covered_len(&[(0.0, 1.0), (0.5, 1.5), (2.0, 3.0)]), 2.5);
    }

    #[test]
    fn merge_order_is_total_on_nan_and_negative_zero() {
        // Regression for the old partial_cmp(..).unwrap_or(Equal) sort:
        // incomparable NaNs made the merged output depend on input
        // order.  total_cmp gives one answer for every permutation.
        let base = [(f64::NAN, 1.0), (-0.0, 0.5), (0.0, 0.25)];
        let mut a = vec![base[0], base[1], base[2]];
        let mut b = vec![base[2], base[0], base[1]];
        let bits = |v: &[(f64, f64)]| {
            v.iter().map(|&(x, y)| (x.to_bits(), y.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(bits(&merge(&mut a)), bits(&merge(&mut b)));
        // -0.0 sorts before +0.0 (total order), deterministically.
        let mut c = vec![(0.0, 0.25), (-0.0, 0.5)];
        let m = merge(&mut c);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].0.to_bits(), (-0.0f64).to_bits());
        assert_eq!(m[0].1, 0.5);
    }
}
