//! Training simulation: balancing policies executed over workload traces
//! on the discrete-event engine.
//!
//! This is the harness behind every paper table and figure: it prices one
//! training iteration of a (model, cluster, policy) triple and aggregates
//! per-iteration, per-layer, per-device and breakdown statistics.
//!
//! The simulator is a *thin driver* over
//! [`crate::balancer::BalancerSession`]: policies come in as
//! `Box<dyn BalancingPolicy>` (see [`simulate_policy`]), the session owns
//! the observe→score→drift→invalidate loop, and this module prices each
//! [`Decision`] on the engine and assembles the timeline its
//! [`ScheduleKind`] asks for — twice:
//!
//! * the frozen barrier [`crate::scheduler::Schedule`] (scalar, pre-maxed
//!   operator costs), whose `total_time()`/`exposed_breakdown()` remain
//!   the reported `time`/`breakdown` on homogeneous clusters (pinned by
//!   the golden test against [`reference`]);
//! * the device-level event timeline ([`events`]): the same schedule
//!   lowered to a barrier-shaped [`crate::scheduler::OpDag`] with the
//!   engine's per-device cost vectors, executed on one comp+comm stream
//!   pair per device.  It fills the per-device report fields
//!   (`des_time`, `devices`, `straggler`) always, and **becomes** the
//!   reported `time`/`breakdown` when the cluster is heterogeneous
//!   (`ClusterSpec::device_slowdown`) — the barrier model cannot see a
//!   straggler at all.
//!
//! A [`ScheduleKind::DagRelaxed`] decision swaps the second model's input:
//! instead of the barrier-shaped lowering, the iteration is assembled by
//! [`crate::scheduler::build_blockwise_dag`] — Algorithm 2 with true data
//! dependencies, no cross-stream barriers — and the DES prices it every
//! iteration, homogeneous clusters included.  The frozen barrier schedule
//! is still built and reported as [`IterationResult::barrier_time`], the
//! relaxed-vs-barrier comparison column.
//!
//! The closed `Policy` enum that predated the balancer trait is fully
//! retired; its last copy lives in [`reference`] as input vocabulary for
//! the frozen pre-refactor oracle.

pub mod checkpoint;
pub mod engine;
pub mod events;
pub mod reference;
pub mod timeline;

pub use engine::Engine;
pub use events::{DesResult, DeviceStats};

use crate::balancer::{
    BalancerSession, BalancingPolicy, CommStyle, Decision, ScheduleKind,
};
use crate::cluster::ClusterSpec;
use crate::config::ModelSpec;
use crate::faults::{FaultTimeline, FaultView};
use crate::metrics::balance_degree;
use crate::moe::{LoadMatrix, Placement};
use crate::obs::{self, Labels, Recorder, Span};
use crate::perfmodel::PerfModel;
use crate::scheduler::{
    build_blocking, build_blockwise, build_blockwise_dag, dag, BlockCosts, DeviceBlockCosts,
    LoadBalanceOps, Op, OpDag, OpInstance, Schedule, SplitMode,
};
use crate::util::threads;
use crate::workload::Trace;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Re-exported from [`crate::balancer`] (its canonical home) so existing
/// `sim::ProphetOptions` imports keep working.
pub use crate::balancer::ProphetOptions;

/// Aggregates of one simulated iteration.
#[derive(Clone, Debug)]
pub struct IterationResult {
    /// Iteration time: the barrier Stage model on homogeneous clusters
    /// (frozen semantics), the device-level DES makespan when the
    /// cluster has per-device slowdowns OR the policy runs in the
    /// relaxed-DAG execution mode ([`ScheduleKind::DagRelaxed`], priced
    /// by the DES on every cluster).
    pub time: f64,
    /// The frozen barrier estimate of the same iteration — the scalar
    /// Stage model's total, regardless of what `time` reports.  Equals
    /// `time` bit-for-bit for the pre-existing schedule kinds on
    /// homogeneous clusters; for [`ScheduleKind::DagRelaxed`] it is the
    /// barrier-vs-relaxed comparison column (`time <= barrier_time` on
    /// homogeneous clusters — relaxing barriers only removes waiting).
    pub barrier_time: f64,
    /// Exposed seconds per breakdown category (search/place/reduce/...),
    /// from the same model `time` came from.
    pub breakdown: BTreeMap<&'static str, f64>,
    /// Per-MoE-block exposed time (sums to `time`).
    pub per_block_time: Vec<f64>,
    /// Balance degree (std of per-device computed load) before and after
    /// placement, averaged over layers.
    pub balance_before: f64,
    pub balance_after: f64,
    /// Parameter copies moved by Trans this iteration (comm volume proxy).
    pub trans_copies: u64,
    /// Mean normalized-L1 error of the prophet forecasts this iteration's
    /// plans were based on (None for non-forecasting policies and for the
    /// warm-up iteration).
    pub forecast_error: Option<f64>,
    /// Device-level event-timeline makespan of the same iteration (the
    /// per-device critical path).  At most `time` on homogeneous
    /// clusters (the per-device refinement only removes pessimism);
    /// equals `time` on heterogeneous ones.
    pub des_time: f64,
    /// Per-device stream/idle accounting from the event timeline.
    pub devices: Vec<DeviceStats>,
    /// The event timeline's straggler: the device whose streams are busy
    /// longest this iteration (ties -> lowest id).
    pub straggler: usize,
}

/// Whole-run aggregates.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub policy: String,
    pub iters: Vec<IterationResult>,
    /// Greedy searches actually executed (all layers, whole run).
    pub plans_run: usize,
    /// Plans served from the placement cache.
    pub plans_reused: usize,
    /// Replans forced by prophet drift detection.
    pub drift_replans: usize,
}

impl SimReport {
    pub fn total_time(&self) -> f64 {
        self.iters.iter().map(|i| i.time).sum()
    }

    pub fn avg_iter_time(&self) -> f64 {
        if self.iters.is_empty() {
            0.0
        } else {
            self.total_time() / self.iters.len() as f64
        }
    }

    /// Mean device-level event-timeline makespan (see
    /// [`IterationResult::des_time`]).
    pub fn avg_des_time(&self) -> f64 {
        if self.iters.is_empty() {
            0.0
        } else {
            self.iters.iter().map(|i| i.des_time).sum::<f64>() / self.iters.len() as f64
        }
    }

    /// Mean frozen barrier estimate (see
    /// [`IterationResult::barrier_time`]) — the relaxed-vs-barrier
    /// comparison column of the CLI tables.
    pub fn avg_barrier_time(&self) -> f64 {
        if self.iters.is_empty() {
            0.0
        } else {
            self.iters.iter().map(|i| i.barrier_time).sum::<f64>() / self.iters.len() as f64
        }
    }

    pub fn iter_times(&self) -> Vec<f64> {
        self.iters.iter().map(|i| i.time).collect()
    }

    /// The device most often identified as the iteration straggler
    /// (None for an empty report).
    pub fn straggler_device(&self) -> Option<usize> {
        let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
        for it in &self.iters {
            *counts.entry(it.straggler).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .max_by_key(|&(dev, n)| (n, std::cmp::Reverse(dev)))
            .map(|(dev, _)| dev)
    }

    /// Mean idle seconds per device across iterations (empty when the
    /// report is empty).
    pub fn mean_device_idle(&self) -> Vec<f64> {
        let Some(first) = self.iters.first() else {
            return vec![];
        };
        let d = first.devices.len();
        let mut acc = vec![0.0; d];
        for it in &self.iters {
            for (a, s) in acc.iter_mut().zip(&it.devices) {
                *a += s.idle;
            }
        }
        for a in &mut acc {
            *a /= self.iters.len() as f64;
        }
        acc
    }

    /// Mean exposed load-balancing fraction (Table I's "L.B." column).
    pub fn lb_fraction(&self) -> f64 {
        let total = self.total_time();
        if total <= 0.0 {
            return 0.0;
        }
        let lb: f64 = self
            .iters
            .iter()
            .map(|i| {
                i.breakdown.get("search").unwrap_or(&0.0)
                    + i.breakdown.get("place").unwrap_or(&0.0)
                    + i.breakdown.get("reduce").unwrap_or(&0.0)
            })
            .sum();
        lb / total
    }

    pub fn breakdown_fraction(&self, key: &str) -> f64 {
        let total = self.total_time();
        if total <= 0.0 {
            return 0.0;
        }
        let v: f64 = self
            .iters
            .iter()
            .map(|i| i.breakdown.get(key).copied().unwrap_or(0.0))
            .sum();
        v / total
    }

    /// Mean RB: balance-degree ratio before/after placement (Fig 16).
    pub fn mean_rb(&self) -> f64 {
        let ratios: Vec<f64> = self
            .iters
            .iter()
            .filter(|i| i.balance_after > 1e-9)
            .map(|i| i.balance_before / i.balance_after)
            .collect();
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }

    /// Mean forecast error over the iterations that had a forecast
    /// (NaN when the policy never forecast anything).
    pub fn mean_forecast_error(&self) -> f64 {
        let errs: Vec<f64> = self.iters.iter().filter_map(|i| i.forecast_error).collect();
        if errs.is_empty() {
            f64::NAN
        } else {
            errs.iter().sum::<f64>() / errs.len() as f64
        }
    }

    pub fn mean_per_block_time(&self) -> Vec<f64> {
        if self.iters.is_empty() {
            return vec![];
        }
        let blocks = self.iters[0].per_block_time.len();
        let mut acc = vec![0.0; blocks];
        for it in &self.iters {
            for (a, t) in acc.iter_mut().zip(&it.per_block_time) {
                *a += t;
            }
        }
        for a in &mut acc {
            *a /= self.iters.len() as f64;
        }
        acc
    }
}

/// Checkpoint knobs for [`simulate_policy_faulted`].
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Directory holding `checkpoint.json` (created if missing).
    pub dir: PathBuf,
    /// Snapshot every this many completed iterations (clamped to >= 1).
    /// The final iteration is never snapshotted — a finished run has
    /// nothing to resume.
    pub every: usize,
    /// Load an existing snapshot and continue from it instead of
    /// starting cold.
    pub resume: bool,
}

impl CheckpointConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointConfig { dir: dir.into(), every: 1, resume: false }
    }
}

/// Extended options for [`simulate_policy_faulted`].  `Default` is the
/// plain run: no faults, no checkpointing, full trace — bit-identical to
/// [`simulate_policy_with`] (which is now a thin wrapper over it).
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// Fault events injected into the run
    /// ([`FaultTimeline::empty`] = none).
    pub faults: FaultTimeline,
    /// Periodic snapshots + resume (see [`CheckpointConfig`]).
    pub checkpoint: Option<CheckpointConfig>,
    /// Stop after this many completed iterations — the "kill" half of
    /// the kill-and-resume contract, deterministic enough to test.  The
    /// partial report is returned as-is.
    pub stop_after: Option<usize>,
    /// Incremental re-pricing: reuse the previous iteration's priced DES
    /// result when every pricing input (per-layer placements, cost
    /// inputs, fault view) is unchanged — see [`price_iteration`] for
    /// the exact invalidation rule.  Hits are bit-identical to
    /// re-pricing and counted by the `sim.des_reuse` metric.  On by
    /// default; turn off to force full pricing every iteration.
    pub des_reuse: bool,
}

// Manual impl: a derived Default would set `des_reuse: false`.
impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            faults: FaultTimeline::empty(),
            checkpoint: None,
            stop_after: None,
            des_reuse: true,
        }
    }
}

impl SimOptions {
    /// Borrowed view for [`simulate_policy_opts`] — lets one options
    /// value drive many runs (the fleet loop, the CLI's speedup
    /// baseline) without cloning the fault timeline per call.
    pub fn as_ref(&self) -> SimOptionsRef<'_> {
        SimOptionsRef {
            faults: &self.faults,
            checkpoint: self.checkpoint.as_ref(),
            stop_after: self.stop_after,
            des_reuse: self.des_reuse,
        }
    }
}

/// Borrowing form of [`SimOptions`]: same knobs, nothing owned.  `Copy`,
/// so call sites hand it around freely; build one via
/// [`SimOptions::as_ref`] or field-by-field.
#[derive(Clone, Copy, Debug)]
pub struct SimOptionsRef<'a> {
    /// Fault events injected into the run.
    pub faults: &'a FaultTimeline,
    /// Periodic snapshots + resume.
    pub checkpoint: Option<&'a CheckpointConfig>,
    /// Stop after this many completed iterations.
    pub stop_after: Option<usize>,
    /// Incremental re-pricing (see [`SimOptions::des_reuse`]).
    pub des_reuse: bool,
}

impl<'a> SimOptionsRef<'a> {
    /// Faults only — the common fleet/CLI case.
    pub fn faults_only(faults: &'a FaultTimeline) -> Self {
        SimOptionsRef { faults, checkpoint: None, stop_after: None, des_reuse: true }
    }
}

/// Per-layer decide + price outcome (the parallel phase's unit of work).
struct LayerOutcome {
    costs: BlockCosts,
    dev_costs: DeviceBlockCosts,
    bal_before: f64,
    bal_after: f64,
    trans_copies: u64,
    schedule: ScheduleKind,
}

/// Price one layer's [`Decision`] on the engine (scalar + per-device).
/// One routing pass per side: the identity route for the "before"
/// balance degree, and `priced_block_styled`'s single pass for costs AND
/// the "after" balance degree.
fn price_layer(eng: &Engine, w: &LoadMatrix, d: &Decision) -> LayerOutcome {
    let routed_before = w.route_identity();
    let unicast = d.comm_style == CommStyle::Coarse;
    let (costs, dev_costs, routed_after) =
        eng.priced_block_styled(w, &d.placement, d.plan_cost, unicast);
    LayerOutcome {
        costs,
        dev_costs,
        bal_before: balance_degree(&routed_before.h),
        bal_after: balance_degree(&routed_after.h),
        trans_copies: d.placement.transfer_copies(),
        schedule: d.schedule_kind,
    }
}

/// Per-device durations of one schedule op, from the engine's
/// [`DeviceBlockCosts`], written straight into the node's arena row (no
/// per-op `Vec`).  `Trans`/`Agg` sub-operators carry a fraction of their
/// block's scalar total; every device contributes the same fraction of
/// its own share.  `Plan` runs on the host and stays uniform.
fn device_durations_into(
    op: &OpInstance,
    scalar: &[BlockCosts],
    device: &[DeviceBlockCosts],
    out: &mut [f64],
) {
    let b = op.op.block().min(scalar.len() - 1);
    let (dev, total) = match op.op {
        Op::Plan { .. } => return out.fill(op.dur),
        Op::A2a { .. } => return out.copy_from_slice(&device[b].a2a),
        Op::Fec { .. } => return out.copy_from_slice(&device[b].fec),
        Op::Bec { .. } => return out.copy_from_slice(&device[b].bec),
        Op::Fnec { .. } => return out.copy_from_slice(&device[b].fnec),
        Op::Bnec { .. } => return out.copy_from_slice(&device[b].bnec),
        Op::Trans { .. } => (&device[b].trans, scalar[b].trans),
        Op::Agg { .. } => (&device[b].agg, scalar[b].agg),
    };
    if total <= 0.0 {
        return out.fill(0.0);
    }
    let frac = op.dur / total;
    for (o, &t) in out.iter_mut().zip(dev) {
        *o = t * frac;
    }
}

/// Lower a barrier [`Schedule`] onto the engine's per-device block costs:
/// the same barrier shape, every op refined to its per-device duration
/// vector (`Trans`/`Agg` sub-operators carry their fraction of each
/// device's share).  This is the simulator's own lowering for every
/// barrier-priced [`ScheduleKind`]; it is public so tests can price the
/// schedule-kind axis on identical cost inputs (the makespan-ordering
/// property in `rust/tests/property_tests.rs`).
pub fn dag_from_schedule_with_costs(
    schedule: &Schedule,
    scalar: &[BlockCosts],
    device: &[DeviceBlockCosts],
    n_devices: usize,
) -> OpDag {
    dag::from_schedule_with(schedule, n_devices, |op, row| {
        device_durations_into(op, scalar, device, row)
    })
}

/// One fully priced iteration: the frozen barrier schedule, its
/// device-level lowering (or, for [`ScheduleKind::DagRelaxed`], the
/// relaxed Algorithm-2 DAG), and the executed event timeline.
#[derive(Clone)]
struct PricedIteration {
    schedule: Schedule,
    des: DesResult,
    kind: ScheduleKind,
    bal_before: f64,
    bal_after: f64,
    trans_copies: u64,
}

/// Exact key of one layer's pricing inputs, for the incremental
/// re-pricing cache.  Placement identity is the `Arc` pointer (PR 2's
/// plan cache hands out the same `Arc` while a plan is reused, so
/// pointer equality is both cheap and exact — a re-planned layer
/// allocates a new `Arc` even if the placement is coincidentally equal,
/// which only costs a cache miss, never a wrong hit).
struct DecisionKey {
    placement: std::sync::Arc<Placement>,
    plan_cost: u64,
    comm_style: CommStyle,
    schedule_kind: ScheduleKind,
}

impl DecisionKey {
    fn of(d: &Decision) -> Self {
        DecisionKey {
            placement: d.placement.clone(),
            plan_cost: d.plan_cost.to_bits(),
            comm_style: d.comm_style,
            schedule_kind: d.schedule_kind,
        }
    }

    fn matches(&self, d: &Decision) -> bool {
        std::sync::Arc::ptr_eq(&self.placement, &d.placement)
            && self.plan_cost == d.plan_cost.to_bits()
            && self.comm_style == d.comm_style
            && self.schedule_kind == d.schedule_kind
    }
}

/// Everything the previous iteration's pricing depended on, plus its
/// result.  Reusable iff EVERY input matches exactly (see
/// [`price_iteration`]'s invalidation rule).
struct PriceCache {
    layers: Vec<LoadMatrix>,
    keys: Vec<DecisionKey>,
    view: Option<FaultView>,
    priced: PricedIteration,
    n_events: u64,
}

/// Cross-iteration pricing state owned by one simulation run (or one
/// fleet tenant): the reusable DES [`events::ExecScratch`] and the
/// incremental re-pricing cache.  Not shared between runs — the cache
/// key contains `Arc` pointer identities that only mean anything within
/// one session's plan cache.
pub struct PriceState {
    scratch: events::ExecScratch,
    reuse_enabled: bool,
    cache: Option<PriceCache>,
}

impl PriceState {
    /// `des_reuse` gates the cache ([`SimOptions::des_reuse`]); the
    /// scratch is always used.
    pub fn new(des_reuse: bool) -> Self {
        PriceState { scratch: events::ExecScratch::new(), reuse_enabled: des_reuse, cache: None }
    }

    /// Drop the cached iteration (scratch buffers survive).  Call after
    /// anything that re-creates the session or changes the cluster under
    /// the same state (the fleet calls this on tenant resize).
    pub fn reset(&mut self) {
        self.cache = None;
    }
}

/// Decide + price one iteration.
///
/// The **decide** phase always runs — `decide_layer` is where plan
/// caching, drift handling, and the plans_run/reused counters live, and
/// the decisions are the cache key.  The **pricing** phase (routing
/// sweep, cost build, schedule, DAG lowering, DES) is skipped when the
/// previous iteration's pricing inputs match exactly:
///
/// * same layer count, and per layer: `Arc`-pointer-equal placement,
///   bit-equal `plan_cost`, equal comm style and schedule kind;
/// * per layer, an *equal* [`LoadMatrix`] (`PartialEq` on shape + loads);
/// * an equal fault view (including both `None`).
///
/// A hit returns a clone of the cached [`PricedIteration`] — bit-identical
/// to re-pricing, because pricing is a pure function of exactly those
/// inputs (the engine is fixed for the run; fleet resize calls
/// [`PriceState::reset`]) — bumps `sim.des_reuse`, and re-emits the
/// iteration-shaped `des.events`/`des.makespan_s` metrics.  The returned
/// `OpDag` is `None` on a hit (nothing was lowered).
fn price_iteration(
    eng: &Engine,
    pm: &PerfModel,
    session: &BalancerSession,
    layers: &[LoadMatrix],
    view: &Option<FaultView>,
    rec: &dyn Recorder,
    state: &mut PriceState,
) -> (PricedIteration, Option<OpDag>) {
    let n_layers = layers.len();
    let n_devices = eng.cluster.n_devices();
    let work = layers.first().map_or(1, |w| w.n_devices() * w.n_experts());
    // Phase 1a (parallel across layers): decide placements.
    let decisions: Vec<Decision> =
        threads::par_map(n_layers, work, |l| session.decide_layer(l, &layers[l], pm));

    // Incremental re-pricing: cheap identity checks first, the
    // LoadMatrix comparison last.
    if let Some(cache) = &state.cache {
        if cache.keys.len() == n_layers
            && cache.view == *view
            && cache.keys.iter().zip(&decisions).all(|(k, d)| k.matches(d))
            && cache.layers.iter().zip(layers).all(|(a, b)| a == b)
        {
            if rec.enabled() {
                rec.counter("sim.des_reuse", Labels::None, 1);
                // Keep the per-iteration metric stream shaped like a
                // priced iteration.
                rec.counter("des.events", Labels::None, cache.n_events);
                rec.gauge("des.makespan_s", Labels::None, cache.priced.des.makespan);
            }
            return (cache.priced.clone(), None);
        }
    }

    // Phase 1b (parallel across layers): price the block operators.
    let outcomes: Vec<LayerOutcome> = threads::par_map(n_layers, work, |l| {
        price_layer(eng, &layers[l], &decisions[l])
    });

    let kind = outcomes[0].schedule;
    let mut costs: Vec<BlockCosts> = Vec::with_capacity(n_layers);
    let mut dev_costs: Vec<DeviceBlockCosts> = Vec::with_capacity(n_layers);
    let mut bal_before = 0.0;
    let mut bal_after = 0.0;
    let mut trans_copies = 0u64;
    for o in outcomes {
        debug_assert!(
            o.schedule == kind,
            "policy returned mixed schedule kinds within one iteration"
        );
        bal_before += o.bal_before;
        bal_after += o.bal_after;
        trans_copies += o.trans_copies;
        costs.push(o.costs);
        dev_costs.push(o.dev_costs);
    }
    bal_before /= n_layers as f64;
    bal_after /= n_layers as f64;

    // The frozen barrier schedule is always built: it stays the reported
    // time of the pre-existing kinds on homogeneous clusters and the
    // relaxed-vs-barrier comparison column for DagRelaxed.
    let schedule = match kind {
        ScheduleKind::NoLoadBalance => build_blocking(&costs, LoadBalanceOps::None),
        ScheduleKind::Blocking => build_blocking(&costs, LoadBalanceOps::Blocking),
        ScheduleKind::Blockwise | ScheduleKind::DagRelaxed => build_blockwise(&costs),
    };
    debug_assert!(schedule.validate_dependencies().is_ok());

    // Device-level event timeline.  Barrier-priced kinds lower the
    // schedule shape-preserving (per-device durations, same barriers);
    // DagRelaxed executes Algorithm 2 as the true-dependency DAG — no
    // cross-stream barriers, per-device Fig-9c splits — every iteration,
    // homogeneous and heterogeneous alike.
    let op_dag = {
        let _sp = Span::enter(rec, "des.lower", Labels::None);
        if kind == ScheduleKind::DagRelaxed {
            build_blockwise_dag(&dev_costs, SplitMode::Split)
        } else {
            dag_from_schedule_with_costs(&schedule, &costs, &dev_costs, n_devices)
        }
    };
    debug_assert!(op_dag.validate().is_ok());
    let des = {
        let _sp = Span::enter(rec, "des.execute", Labels::None);
        events::execute_with(&op_dag, &mut state.scratch)
    };
    let n_events = (op_dag.len() * n_devices) as u64;
    if rec.enabled() {
        // The DES walks every (op, device) pair once.
        rec.counter("des.events", Labels::None, n_events);
        rec.gauge("des.makespan_s", Labels::None, des.makespan);
    }

    let priced = PricedIteration { schedule, des, kind, bal_before, bal_after, trans_copies };
    if state.reuse_enabled {
        state.cache = Some(PriceCache {
            layers: layers.to_vec(),
            keys: decisions.iter().map(DecisionKey::of).collect(),
            view: view.clone(),
            priced: priced.clone(),
            n_events,
        });
    }
    (priced, Some(op_dag))
}

/// Simulate `trace` under any [`BalancingPolicy`].
///
/// Per iteration: phase 1 fans `decide` + pricing out across layers on
/// scoped threads (planning reads only forecasts armed by PREVIOUS
/// iterations, so layer order does not matter); phase 2 feeds the ACTUAL
/// gating results through the session sequentially (scores forecasts,
/// advances history, runs drift detection, lets the policy react).
/// Results are identical to the serial loop (`PRO_PROPHET_THREADS=1`).
pub fn simulate_policy(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
) -> SimReport {
    simulate_policy_with(model, cluster, trace, policy, obs::noop_arc())
}

/// [`simulate_policy`] with a live telemetry sink: every iteration opens
/// a recorder scope, the decide/observe/DES phases are span-timed, and
/// per-device busy/idle/exposed seconds plus the straggler id are
/// gauged.  With the no-op recorder this is exactly [`simulate_policy`]
/// — same results bit-for-bit (pinned by `integration_obs.rs`).
pub fn simulate_policy_with(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
    rec: std::sync::Arc<dyn Recorder>,
) -> SimReport {
    simulate_policy_faulted(model, cluster, trace, policy, rec, &SimOptions::default())
        .expect("default SimOptions cannot fail")
}

/// Resolve one iteration's fault view and feed the down set to the
/// session (health transitions force masked replans / failover).  `None`
/// when no fault is active — the iteration prices exactly like a
/// fault-free run.  Errs when every device is down: no survivor can run
/// the model, and pretending otherwise would report a zero-cost
/// iteration.
pub(crate) fn fault_view_for(
    session: &mut BalancerSession,
    faults: &FaultTimeline,
    cluster: &ClusterSpec,
    iter_index: usize,
    rec: Option<&dyn Recorder>,
) -> Result<Option<FaultView>, String> {
    if faults.is_empty() {
        return Ok(None);
    }
    let view = faults.effective(iter_index, cluster);
    let down = view
        .as_ref()
        .map(|v| v.down.clone())
        .unwrap_or_else(|| vec![false; cluster.n_devices()]);
    session.set_device_health(&down);
    if let Some(v) = &view {
        if v.all_down() {
            return Err(format!(
                "every device is down at iteration {iter_index}; nothing left to run on"
            ));
        }
    }
    if let Some(rec) = rec {
        if rec.enabled() {
            let (activated, recovered) = faults.transitions(iter_index);
            if activated > 0 {
                rec.counter("fault.activations", Labels::None, activated as u64);
            }
            if recovered > 0 {
                rec.counter("fault.recoveries", Labels::None, recovered as u64);
            }
            rec.gauge(
                "fault.devices_down",
                Labels::None,
                down.iter().filter(|&&d| d).count() as f64,
            );
        }
    }
    Ok(view)
}

/// Rebuild one already-completed iteration's effect on the session
/// without pricing it.  The decide→observe call sequence (with the same
/// fault views and health transitions as the original run) is the
/// session's entire state input — prophet histories, planner caches,
/// drift detectors and plan counters are pure functions of it — so
/// replaying it reconstructs the session bit-for-bit while skipping the
/// expensive routing/DES work.  This is what makes the checkpoint format
/// results-only (see [`checkpoint`]).
fn replay_iteration(
    session: &mut BalancerSession,
    pm: &PerfModel,
    cluster: &ClusterSpec,
    faults: &FaultTimeline,
    iter_index: usize,
    layers: &[LoadMatrix],
) {
    let view = fault_view_for(session, faults, cluster, iter_index, None)
        .expect("replay cannot reach an all-down iteration: the original run refused to complete it");
    // Mirror price_and_observe's decide view exactly — including the
    // forecast substitution — so a resumed session's planner caches and
    // forecaster state are bit-identical to the straight run's.
    let forecast_pm = session.forecast_slowdown().map(|f| pm.with_device_slowdown(f));
    match &view {
        Some(v) => {
            let eff_pm = v.effective_perf_model(pm);
            let decide_pm = forecast_pm.as_ref().unwrap_or(&eff_pm);
            for (l, w) in layers.iter().enumerate() {
                let _ = session.decide_layer(l, w, decide_pm);
            }
        }
        None => {
            let decide_pm = forecast_pm.as_ref().unwrap_or(pm);
            for (l, w) in layers.iter().enumerate() {
                let _ = session.decide_layer(l, w, decide_pm);
            }
        }
    }
    session.observe_iteration(layers);
    if session.device_forecast_enabled() {
        let realized: Vec<f64> = match &view {
            Some(v) => v.slowdown.clone(),
            None => (0..cluster.n_devices()).map(|d| cluster.slowdown(d)).collect(),
        };
        let _ = session.observe_device_slowdown(&realized);
    }
}

/// [`simulate_policy_with`] plus the robustness axes: a seeded
/// [`FaultTimeline`] priced into every affected iteration, graceful
/// degradation through the session's health monitor, and periodic
/// checkpoints with bit-identical resume.
///
/// * An empty timeline and default options take exactly the frozen code
///   path — bit-identical to [`simulate_policy_with`] (pinned by
///   `rust/tests/integration_faults.rs`).
/// * A fault-active iteration is priced by the device-level DES on a
///   temporary fault-effective engine (slowdowns composed onto the
///   cluster's static vector; a down device has slowdown 0 and
///   contributes no work) — the barrier model cannot see per-device
///   state, exactly like the static-straggler case.
/// * `Err` is reserved for unusable inputs: a timeline sized for a
///   different cluster, every device down at once, or checkpoint I/O
///   failures.  Degraded-but-runnable states (devices down, stranded
///   experts) are handled by failover/fallback inside the session and
///   never error.
pub fn simulate_policy_faulted(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
    rec: std::sync::Arc<dyn Recorder>,
    opts: &SimOptions,
) -> Result<SimReport, String> {
    simulate_policy_opts(model, cluster, trace, policy, rec, opts.as_ref())
}

/// Price one iteration — under an optional fault view — and feed the
/// actual gating results back through the session.  This is the shared
/// single-iteration step of [`simulate_policy_opts`] and the fleet loop
/// ([`crate::fleet`]): extracting it (rather than duplicating it) is
/// what makes a degenerate one-job fleet bit-identical to
/// [`simulate_policy`] (the degenerate-fleet oracle in
/// `rust/tests/integration_fleet.rs`).
pub(crate) fn price_and_observe(
    eng: &Engine,
    heterogeneous: bool,
    session: &mut BalancerSession,
    view: &Option<FaultView>,
    layers: &[LoadMatrix],
    rec: &dyn Recorder,
    state: &mut PriceState,
) -> IterationResult {
    let n_layers = layers.len();
    let fault_active = view.is_some();
    // Decide-view health: once the session's device forecaster is armed
    // and fed, the planner ranks candidates against the FORECAST
    // slowdown vector — the session's learned, one-iteration-lagged view
    // of device health — instead of the oracle-true effective model.
    // The DES below always prices on the true effective engine:
    // forecasts inform decisions, never ground truth.  Unarmed (the
    // default), the decide view is exactly the pre-existing one.
    let forecast_pm = session.forecast_slowdown().map(|f| eng.pm.with_device_slowdown(f));
    let (priced, _dag) = match view {
        Some(v) => {
            // Price on a temporary fault-effective engine: per-device
            // compute costs scale by the composed slowdown vector, a
            // down device (slowdown 0) contributes no work and the
            // failover replicas carry its load.  The fault view is part
            // of the re-pricing cache key, so an engine rebuilt from an
            // UNCHANGED view prices identically and may reuse.
            let eff_cluster = v.effective_cluster(eng.cluster);
            let eff_pm = v.effective_perf_model(eng.pm);
            let eff_eng = Engine::new(&eff_cluster, &eff_pm);
            let decide_pm = forecast_pm.as_ref().unwrap_or(&eff_pm);
            price_iteration(&eff_eng, decide_pm, session, layers, view, rec, state)
        }
        None => {
            let decide_pm = forecast_pm.as_ref().unwrap_or(eng.pm);
            price_iteration(eng, decide_pm, session, layers, view, rec, state)
        }
    };

    // Phase 2 (sequential): the session's observe→score→drift→
    // invalidate loop over the actual gating results.
    let fb = session.observe_iteration(layers);

    // Feed the forecaster what this iteration ACTUALLY ran at: the fault
    // view's composed vector while degraded (down devices come through
    // as 0.0 and are floored inside the forecaster), the cluster's
    // static vector while healthy.  No-op unless armed.
    if session.device_forecast_enabled() {
        let realized: Vec<f64> = match view {
            Some(v) => v.slowdown.clone(),
            None => (0..eng.cluster.n_devices()).map(|d| eng.cluster.slowdown(d)).collect(),
        };
        let _ = session.observe_device_slowdown(&realized);
    }

    let (time, breakdown, per_block_time) = if heterogeneous
        || fault_active
        || priced.kind == ScheduleKind::DagRelaxed
    {
        // The barrier model cannot see per-device slowdowns —
        // static (heterogeneous cluster) or injected (active
        // fault) — and a DagRelaxed decision asks for DES pricing
        // unconditionally; report the device-level critical path.
        let mut pb = priced.des.per_block_exposed.clone();
        pb.resize(n_layers, 0.0);
        (priced.des.makespan, priced.des.exposed.clone(), pb)
    } else {
        // Frozen barrier pricing: per-block exposed time assigns each
        // stage to the block of its first op.
        let mut per_block = vec![0.0; n_layers];
        for stage in &priced.schedule.stages {
            if let Some(op) = stage.comp.first().or(stage.comm.first()) {
                let b = op.op.block().min(n_layers - 1);
                per_block[b] += stage.time();
            }
        }
        (
            priced.schedule.total_time(),
            priced.schedule.exposed_breakdown(),
            per_block,
        )
    };

    if rec.enabled() {
        rec.gauge("sim.iter_time_s", Labels::None, time);
        rec.gauge("sim.barrier_time_s", Labels::None, priced.schedule.total_time());
        rec.gauge("sim.balance_before", Labels::None, priced.bal_before);
        rec.gauge("sim.balance_after", Labels::None, priced.bal_after);
        rec.gauge("des.straggler_device", Labels::None, priced.des.straggler as f64);
        for (d, stats) in priced.des.devices.iter().enumerate() {
            let dev = Labels::one("dev", d as i64);
            rec.gauge("des.device_busy_comp_s", dev, stats.busy_comp);
            rec.gauge("des.device_busy_comm_s", dev, stats.busy_comm);
            rec.gauge("des.device_exposed_comm_s", dev, stats.exposed_comm);
            rec.gauge("des.device_idle_s", dev, stats.idle);
        }
    }

    IterationResult {
        time,
        barrier_time: priced.schedule.total_time(),
        breakdown,
        per_block_time,
        balance_before: priced.bal_before,
        balance_after: priced.bal_after,
        trans_copies: priced.trans_copies,
        forecast_error: fb.mean_forecast_error(),
        des_time: priced.des.makespan,
        devices: priced.des.devices,
        straggler: priced.des.straggler,
    }
}

/// [`simulate_policy_faulted`] with borrowed options ([`SimOptionsRef`]):
/// the core entry point.  One owned [`SimOptions`] (or a bare
/// [`FaultTimeline`]) can drive any number of runs without cloning.
pub fn simulate_policy_opts(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
    rec: std::sync::Arc<dyn Recorder>,
    opts: SimOptionsRef<'_>,
) -> Result<SimReport, String> {
    let faults = opts.faults;
    if !faults.is_empty() && faults.n_devices() != cluster.n_devices() {
        return Err(format!(
            "fault timeline is for {} devices, cluster has {}",
            faults.n_devices(),
            cluster.n_devices()
        ));
    }
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let n_layers = trace.n_layers;
    if n_layers == 0 {
        return Ok(SimReport { policy: policy.name(), ..Default::default() });
    }
    let heterogeneous = cluster.is_heterogeneous();
    let mut session = BalancerSession::with_recorder(policy, n_layers, rec.clone());
    let mut report = SimReport { policy: session.policy_name(), ..Default::default() };
    let mut price = PriceState::new(opts.des_reuse);

    // Resume: restore the completed iterations' results verbatim, then
    // replay their decide/observe sequence to rebuild the session.
    let mut start = 0usize;
    if let Some(ck) = opts.checkpoint {
        if ck.resume {
            let snap = checkpoint::Checkpoint::load(&ck.dir)?;
            snap.check_compatible(&report.policy, trace, &faults.specs())?;
            for (iter_index, layers) in
                trace.iterations.iter().enumerate().take(snap.next_iter)
            {
                replay_iteration(&mut session, &pm, cluster, faults, iter_index, layers);
            }
            report.iters = snap.iters;
            start = snap.next_iter;
        }
    }

    for (iter_index, layers) in trace.iterations.iter().enumerate().skip(start) {
        rec.iteration_start(iter_index);
        let sp_iter = Span::enter(&*rec, "sim.iteration", Labels::None);

        let view = fault_view_for(&mut session, faults, cluster, iter_index, Some(&*rec))?;
        report.iters.push(price_and_observe(
            &eng,
            heterogeneous,
            &mut session,
            &view,
            layers,
            &*rec,
            &mut price,
        ));

        // Snapshot on the period boundary and right before a graceful
        // stop; a finished run has nothing to resume, so the last
        // iteration is never snapshotted.
        let done = iter_index + 1;
        let stopping = opts.stop_after.is_some_and(|s| done >= s) && done < trace.len();
        if let Some(ck) = opts.checkpoint {
            if done < trace.len() && (done % ck.every.max(1) == 0 || stopping) {
                checkpoint::Checkpoint::of(&report.policy, trace, faults.specs(), &report.iters)
                    .save(&ck.dir)?;
                if rec.enabled() {
                    rec.counter("sim.checkpoints_written", Labels::None, 1);
                }
            }
        }
        drop(sp_iter);
        rec.iteration_end();
        if stopping {
            break;
        }
    }

    let counters = session.counters();
    report.plans_run = counters.plans_run;
    report.plans_reused = counters.plans_reused;
    report.drift_replans = counters.drift_replans;
    Ok(report)
}

/// Replay `trace` under `policy` up to iteration `index` and return that
/// iteration's device-level DAG and executed timeline (Chrome-trace
/// export, straggler inspection).  None when the trace is too short.
pub fn iteration_des(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
    index: usize,
) -> Option<(OpDag, DesResult)> {
    iteration_des_faulted(model, cluster, trace, policy, &FaultTimeline::empty(), index)
}

/// [`iteration_des`] under a fault timeline: iterations before `index`
/// replay decide/observe with the same fault views the full simulation
/// would see, and the exported iteration is priced on the fault-effective
/// engine — so a Chrome trace of a faulted run shows the distorted
/// timeline, not the healthy one.  None when the trace is too short or
/// every device is down at `index`.
pub fn iteration_des_faulted(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: Box<dyn BalancingPolicy>,
    faults: &FaultTimeline,
    index: usize,
) -> Option<(OpDag, DesResult)> {
    if trace.n_layers == 0 || index >= trace.len() {
        return None;
    }
    if !faults.is_empty() && faults.n_devices() != cluster.n_devices() {
        return None;
    }
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let mut session = BalancerSession::new(policy, trace.n_layers);
    for (i, layers) in trace.iterations.iter().enumerate() {
        if i == index {
            let view = fault_view_for(&mut session, faults, cluster, i, None).ok()?;
            let mut price = PriceState::new(false);
            // Same decide view as the run being exported: the armed
            // forecaster's substitution included (see price_and_observe).
            let forecast_pm = session.forecast_slowdown().map(|f| pm.with_device_slowdown(f));
            let (_, op_dag) = match &view {
                Some(v) => {
                    let eff_cluster = v.effective_cluster(cluster);
                    let eff_pm = v.effective_perf_model(&pm);
                    let eff_eng = Engine::new(&eff_cluster, &eff_pm);
                    let decide_pm = forecast_pm.as_ref().unwrap_or(&eff_pm);
                    price_iteration(&eff_eng, decide_pm, &session, layers, &view, obs::noop(), &mut price)
                }
                None => {
                    let decide_pm = forecast_pm.as_ref().unwrap_or(&pm);
                    price_iteration(&eng, decide_pm, &session, layers, &view, obs::noop(), &mut price)
                }
            };
            let op_dag = op_dag.expect("re-pricing disabled: the DAG is always built");
            // Re-execute on the cold path to retain per-(node, device)
            // times for trace export (bit-identical to the hot result).
            let des = events::execute(&op_dag);
            return Some((op_dag, des));
        }
        replay_iteration(&mut session, &pm, cluster, faults, i, layers);
    }
    None
}

/// Convenience: simulate a single layer's load matrix once under any
/// [`BalancingPolicy`], returning (identity placement time, policy time).
/// The one-shot comparison excludes the Plan primitive's cost on both
/// sides (pre-refactor convention, pinned by the golden test).
pub fn single_layer_times_policy(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    w: &LoadMatrix,
    policy: Box<dyn BalancingPolicy>,
) -> (f64, f64) {
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let ident = Placement::identity(w.n_experts(), w.n_devices());
    let t_ident = {
        let costs = [eng.block_costs(w, &ident, 0.0)];
        build_blocking(&costs, LoadBalanceOps::None).total_time()
    };
    let session = BalancerSession::new(policy, 1);
    let d = session.decide_layer(0, w, &pm);
    let unicast = d.comm_style == CommStyle::Coarse;
    let t_policy = match d.schedule_kind {
        // One routing pass, like the simulator's own pricing: the
        // per-device costs come out of the same sweep that would have
        // produced the (unused here) scalar side.
        ScheduleKind::DagRelaxed => {
            let (_, dev, _) = eng.priced_block_styled(w, &d.placement, 0.0, unicast);
            events::execute(&build_blockwise_dag(&[dev], SplitMode::Split)).makespan
        }
        // Frozen barrier arms: keep the exact pre-refactor call sequence
        // (pinned by the golden single_layer_times gate).
        kind => {
            let costs = [eng.block_costs_styled(w, &d.placement, 0.0, unicast)];
            if kind == ScheduleKind::Blockwise {
                build_blockwise(&costs).total_time()
            } else {
                build_blocking(&costs, LoadBalanceOps::Blocking).total_time()
            }
        }
    };
    (t_ident, t_policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{builtin, registry};
    use crate::planner::PlannerConfig;
    use crate::workload::{Trace, WorkloadConfig, WorkloadGen};

    fn setup() -> (ModelSpec, ClusterSpec, Trace) {
        let model = ModelSpec::moe_gpt_s(8, 1, 8192);
        let cluster = ClusterSpec::hpwnv(2);
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(4, 8, 8, 8192));
        let trace = Trace::capture(&mut gen, 6);
        (model, cluster, trace)
    }

    /// Simulate a registry policy with default options.
    fn run(m: &ModelSpec, c: &ClusterSpec, t: &Trace, name: &str) -> SimReport {
        simulate_policy(
            m,
            c,
            t,
            registry::build(name, &ProphetOptions::default()).unwrap(),
        )
    }

    /// Simulate the Pro-Prophet family with explicit options.
    fn run_pp(m: &ModelSpec, c: &ClusterSpec, t: &Trace, opts: ProphetOptions) -> SimReport {
        simulate_policy(m, c, t, Box::new(builtin::ProProphet::new(opts)))
    }

    #[test]
    fn deepspeed_has_zero_lb_overhead() {
        let (m, c, t) = setup();
        let r = run(&m, &c, &t, "deepspeed");
        assert_eq!(r.lb_fraction(), 0.0);
        assert!(r.avg_iter_time() > 0.0);
        assert_eq!(r.iters.len(), 6);
    }

    #[test]
    fn fastermoe_beats_deepspeed_on_skewed_load() {
        let (m, c, t) = setup();
        let ds = run(&m, &c, &t, "deepspeed");
        let fm = run(&m, &c, &t, "fastermoe");
        assert!(
            fm.avg_iter_time() < ds.avg_iter_time(),
            "FasterMoE {:.4} !< Deepspeed {:.4}",
            fm.avg_iter_time(),
            ds.avg_iter_time()
        );
        assert!(fm.lb_fraction() > 0.0, "FasterMoE pays LB overhead");
    }

    #[test]
    fn pro_prophet_beats_fastermoe() {
        let (m, c, t) = setup();
        let fm = run(&m, &c, &t, "fastermoe");
        let pp = run_pp(&m, &c, &t, ProphetOptions::full());
        assert!(
            pp.avg_iter_time() < fm.avg_iter_time(),
            "Pro-Prophet {:.4} !< FasterMoE {:.4}",
            pp.avg_iter_time(),
            fm.avg_iter_time()
        );
    }

    #[test]
    fn scheduler_ablation_ordering() {
        // dag <= full <= planner-only <= deepspeed (on skewed workloads).
        // dag <= full is rigorous: on a homogeneous cluster the slack-
        // aware planner is bit-inert, so both arms decide identical
        // placements and the relaxed DAG can only remove barrier waiting.
        let (m, c, t) = setup();
        let dag = run_pp(&m, &c, &t, ProphetOptions::dag());
        let full = run_pp(&m, &c, &t, ProphetOptions::full());
        let planner_only = run_pp(&m, &c, &t, ProphetOptions::planner_only());
        let ds = run(&m, &c, &t, "deepspeed");
        assert!(dag.avg_iter_time() <= full.avg_iter_time() + 1e-12);
        assert!(full.avg_iter_time() <= planner_only.avg_iter_time() + 1e-12);
        assert!(planner_only.avg_iter_time() < ds.avg_iter_time());
    }

    #[test]
    fn dag_relaxed_priced_by_des_every_iteration() {
        // The tentpole contract on a HOMOGENEOUS cluster: a DagRelaxed
        // policy's reported time IS the DES makespan of the relaxed DAG
        // (not the barrier estimate), bounded by the barrier time, with a
        // breakdown that sums to it.
        let (m, c, t) = setup();
        let r = run(&m, &c, &t, "pro-prophet-dag");
        assert_eq!(r.policy, "Pro-Prophet(dag)");
        assert_eq!(r.iters.len(), 6);
        assert!(r.avg_barrier_time() > 0.0);
        for (i, it) in r.iters.iter().enumerate() {
            assert_eq!(
                it.time.to_bits(),
                it.des_time.to_bits(),
                "iter {i}: DagRelaxed time must be the DES makespan"
            );
            assert!(
                it.time <= it.barrier_time + 1e-9,
                "iter {i}: relaxed {} slower than barrier {}",
                it.time,
                it.barrier_time
            );
            let sum: f64 = it.breakdown.values().sum();
            assert!((sum - it.time).abs() < 1e-9 * it.time.max(1e-9), "iter {i}: breakdown");
            let pb: f64 = it.per_block_time.iter().sum();
            assert!((pb - it.time).abs() < 1e-9 * it.time.max(1e-9), "iter {i}: per-block");
            assert!(it.straggler < c.n_devices());
            assert_eq!(it.devices.len(), c.n_devices());
        }
        // The relaxed mode must still beat the no-balancing baseline.
        let ds = run(&m, &c, &t, "deepspeed");
        assert!(r.avg_iter_time() < ds.avg_iter_time());
    }

    #[test]
    fn barrier_time_is_frozen_time_on_pre_existing_kinds() {
        // For every barrier-priced kind on a homogeneous cluster the new
        // comparison column is the reported time itself, bit for bit —
        // the added field cannot drift from the frozen pricing.
        let (m, c, t) = setup();
        for name in ["deepspeed", "fastermoe", "top2", "pro-prophet", "planner-only", "flexmoe"] {
            let r = run(&m, &c, &t, name);
            for (i, it) in r.iters.iter().enumerate() {
                assert_eq!(
                    it.time.to_bits(),
                    it.barrier_time.to_bits(),
                    "{name} iter {i}: barrier_time != time"
                );
            }
        }
    }

    #[test]
    fn balance_improves_under_planner() {
        let (m, c, t) = setup();
        let pp = run_pp(&m, &c, &t, ProphetOptions::full());
        assert!(pp.mean_rb() > 1.5, "RB {}", pp.mean_rb());
        for it in &pp.iters {
            assert!(it.balance_after <= it.balance_before + 1e-9);
        }
    }

    #[test]
    fn prophet_placements_are_lightweight_per_expert() {
        // §IV-A: a lightweight placement ships each selected expert to a
        // SUBSET of devices, vs FasterMoE's full broadcast (D-1 receivers per
        // shadowed expert).  Compare receivers per selected expert.
        let (m, c, t) = setup();
        let pm = crate::perfmodel::PerfModel::new(&m, &c);
        let w = &t.iterations[2][0];
        let pp = crate::planner::greedy_search(
            w,
            &pm,
            &crate::planner::PlannerConfig::default(),
        )
        .placement;
        let d = w.n_devices();
        for &e in &pp.transferred_experts() {
            assert!(
                pp.replicas(e).len() < d,
                "prophet replicated expert {e} to every device"
            );
        }
        let fm = crate::planner::policies::fastermoe_shadowing(w, &pm);
        for &e in &fm.transferred_experts() {
            assert_eq!(fm.replicas(e).len(), d, "FasterMoE always broadcasts");
        }
        // And despite moving each expert to fewer devices, the prophet's
        // balance is at least as good.
        let bal = |p: &Placement| balance_degree(&w.route(p).h);
        assert!(bal(&pp) <= bal(&fm) * 1.5 + 1.0);
    }

    #[test]
    fn per_block_times_sum_to_iteration() {
        let (m, c, t) = setup();
        let r = run_pp(&m, &c, &t, ProphetOptions::full());
        for it in &r.iters {
            let sum: f64 = it.per_block_time.iter().sum();
            assert!((sum - it.time).abs() < 1e-9 * it.time.max(1.0));
        }
    }

    #[test]
    fn prophet_reports_forecast_and_replan_metrics() {
        let (m, c, t) = setup();
        let r = run_pp(&m, &c, &t, ProphetOptions::full());
        // Warm-up iteration has no forecast to score; later ones do.
        assert!(r.iters[0].forecast_error.is_none());
        assert!(r.iters.iter().skip(1).all(|i| i.forecast_error.is_some()));
        assert!(
            r.mean_forecast_error() < 0.3,
            "forecast error {} too large for a high-locality trace",
            r.mean_forecast_error()
        );
        // Every layer of every iteration was either planned or reused.
        assert_eq!(r.plans_run + r.plans_reused, 6 * t.n_layers);
        let ds = run(&m, &c, &t, "deepspeed");
        assert_eq!(ds.plans_run, 0);
        assert!(ds.mean_forecast_error().is_nan());
        let fm = run(&m, &c, &t, "fastermoe");
        assert_eq!(fm.plans_run, 6 * t.n_layers);
    }

    #[test]
    fn drift_forces_replans_under_lazy_replanning() {
        // 1-layer hand-built trace: stable regime, violent shift, stable
        // again.  With a huge replan interval only drift detection can
        // trigger the second plan.
        let stable = LoadMatrix::from_rows(vec![vec![600, 100, 100, 224]; 4]);
        let shifted = LoadMatrix::from_rows(vec![vec![50, 100, 100, 774]; 4]);
        let mut trace = Trace::new(1, 4, 4);
        for _ in 0..6 {
            trace.push(vec![stable.clone()]);
        }
        for _ in 0..6 {
            trace.push(vec![shifted.clone()]);
        }
        let model = ModelSpec::moe_gpt_s(4, 1, 4096);
        let cluster = ClusterSpec::hpwnv(1);
        let opts = ProphetOptions {
            planner: PlannerConfig { replan_interval: 1000, ..Default::default() },
            ..Default::default()
        };
        let r = run_pp(&model, &cluster, &trace, opts);
        assert_eq!(r.drift_replans, 1, "exactly one regime change");
        assert_eq!(r.plans_run, 2, "initial plan + drift-forced replan");
        assert_eq!(r.plans_reused, 10);
    }

    #[test]
    fn topk_policies_run() {
        let (m, c, t) = setup();
        for k in [2, 3] {
            let r = run(&m, &c, &t, &format!("top{k}"));
            assert!(r.avg_iter_time() > 0.0);
            assert_eq!(r.policy, format!("top{k}"));
        }
    }

    #[test]
    fn single_layer_policy_times() {
        let (m, c, t) = setup();
        let w = &t.iterations[0][0];
        let (ident, pp) = single_layer_times_policy(
            &m,
            &c,
            w,
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
        );
        assert!(pp < ident, "single layer: prophet {pp} !< identity {ident}");
    }

    #[test]
    fn flexmoe_runs_entirely_through_the_trait() {
        // The open-API proof: a policy implemented outside sim/ runs the
        // full harness via the registry, no enum arm anywhere.
        let (m, c, t) = setup();
        let fx = run(&m, &c, &t, "flexmoe");
        assert_eq!(fx.policy, "FlexMoE");
        assert_eq!(fx.iters.len(), 6);
        assert!(fx.plans_run > 0, "skewed load must trigger adjustments");
        assert!(fx.mean_forecast_error().is_nan(), "FlexMoE does not forecast");
        // It must not be meaningfully slower than doing nothing, and its
        // placements must improve balance once warmed up.
        let ds = run(&m, &c, &t, "deepspeed");
        assert!(
            fx.avg_iter_time() <= ds.avg_iter_time() * 1.05,
            "FlexMoE {:.4} much slower than Deepspeed {:.4}",
            fx.avg_iter_time(),
            ds.avg_iter_time()
        );
        // Its placements actually move replicas (Trans volume) once the
        // skew is observed, and balance is not made worse on average.
        assert!(fx.iters.iter().any(|i| i.trans_copies > 0), "no replicas moved");
        assert!(fx.mean_rb() > 0.9, "RB {}", fx.mean_rb());
    }

    #[test]
    fn des_enrichment_populated_and_bounded() {
        // Homogeneous cluster: `time` stays the frozen barrier estimate;
        // the per-device DES refines it (never slower — relaxing the
        // pre-maxed scalars only removes pessimism).
        let (m, c, t) = setup();
        for name in ["deepspeed", "fastermoe", "pro-prophet"] {
            let r = run(&m, &c, &t, name);
            for it in &r.iters {
                assert_eq!(it.devices.len(), c.n_devices(), "{name}");
                assert!(it.straggler < c.n_devices());
                assert!(it.des_time > 0.0, "{name}");
                assert!(
                    it.des_time <= it.time + 1e-12,
                    "{name}: DES {} exceeds barrier {}",
                    it.des_time,
                    it.time
                );
                for dstat in &it.devices {
                    assert!(dstat.idle >= 0.0 && dstat.idle <= it.des_time + 1e-9);
                    assert!(dstat.exposed_comm <= dstat.busy_comm + 1e-9);
                }
            }
            assert!(r.avg_des_time() > 0.0);
            assert!(r.straggler_device().is_some());
            assert_eq!(r.mean_device_idle().len(), c.n_devices());
        }
    }

    #[test]
    fn iteration_des_exports_a_timeline() {
        let (m, c, t) = setup();
        let opts = ProphetOptions::default();
        let (op_dag, des) = iteration_des(
            &m,
            &c,
            &t,
            registry::build("pro-prophet", &opts).unwrap(),
            2,
        )
        .unwrap();
        assert_eq!(op_dag.n_devices, c.n_devices());
        assert!(!op_dag.is_empty());
        assert!(des.makespan > 0.0);
        // Out-of-range iterations return None.
        assert!(iteration_des(
            &m,
            &c,
            &t,
            registry::build("pro-prophet", &opts).unwrap(),
            t.len()
        )
        .is_none());
    }

    /// Run a policy through the faulted entry point with explicit opts.
    fn run_faulted(
        m: &ModelSpec,
        c: &ClusterSpec,
        t: &Trace,
        name: &str,
        opts: &SimOptions,
    ) -> Result<SimReport, String> {
        simulate_policy_faulted(
            m,
            c,
            t,
            registry::build(name, &ProphetOptions::default()).unwrap(),
            obs::noop_arc(),
            opts,
        )
    }

    #[test]
    fn faulted_default_options_bit_identical() {
        // The no-fault equivalence pin at the unit level (the integration
        // suite re-pins it across every registry policy): default
        // SimOptions must take exactly the frozen code path.
        let (m, c, t) = setup();
        for name in ["deepspeed", "fastermoe", "pro-prophet", "pro-prophet-dag"] {
            let frozen = run(&m, &c, &t, name);
            let faulted = run_faulted(&m, &c, &t, name, &SimOptions::default()).unwrap();
            assert_eq!(frozen.iters.len(), faulted.iters.len(), "{name}");
            assert_eq!(frozen.plans_run, faulted.plans_run, "{name}");
            for (i, (a, b)) in frozen.iters.iter().zip(&faulted.iters).enumerate() {
                assert_eq!(a.time.to_bits(), b.time.to_bits(), "{name} iter {i}");
                assert_eq!(a.des_time.to_bits(), b.des_time.to_bits(), "{name} iter {i}");
                assert_eq!(a.barrier_time.to_bits(), b.barrier_time.to_bits(), "{name} iter {i}");
                assert_eq!(a.straggler, b.straggler, "{name} iter {i}");
            }
        }
    }

    #[test]
    fn transient_fault_prices_des_inside_its_window() {
        // A transient 8x slowdown on device 3, iterations [2, 4): inside
        // the window the reported time IS the DES makespan and device 3
        // is the straggler; outside it the run is bit-identical to the
        // fault-free one (deepspeed decides independently of the perf
        // model, so no decision state can leak across the window).
        let (m, c, t) = setup();
        let baseline = run(&m, &c, &t, "deepspeed");
        let faults = FaultTimeline::parse_specs(
            &["transient dev=3 factor=8 start=2 dur=2"],
            c.n_devices(),
        )
        .unwrap();
        let opts = SimOptions { faults, ..Default::default() };
        let r = run_faulted(&m, &c, &t, "deepspeed", &opts).unwrap();
        assert_eq!(r.iters.len(), 6);
        for i in [0usize, 1, 4, 5] {
            assert_eq!(
                r.iters[i].time.to_bits(),
                baseline.iters[i].time.to_bits(),
                "iter {i}: inactive fault must not change pricing"
            );
            assert_eq!(r.iters[i].straggler, baseline.iters[i].straggler, "iter {i}");
        }
        for i in [2usize, 3] {
            let it = &r.iters[i];
            assert_eq!(
                it.time.to_bits(),
                it.des_time.to_bits(),
                "iter {i}: fault-active iterations are DES-priced"
            );
            assert_eq!(it.straggler, 3, "iter {i}: slowed device must straggle");
            assert!(
                it.time > baseline.iters[i].time,
                "iter {i}: an 8x compute straggler must cost time"
            );
        }
    }

    #[test]
    fn fault_timeline_for_wrong_cluster_is_rejected() {
        let (m, c, t) = setup();
        let faults = FaultTimeline::parse_specs(&["down dev=1 start=0"], 4).unwrap();
        let opts = SimOptions { faults, ..Default::default() };
        let err = run_faulted(&m, &c, &t, "deepspeed", &opts).unwrap_err();
        assert!(err.contains("devices"), "{err}");
        // All devices down: unusable, named by iteration.
        let all_down: Vec<String> = (0..c.n_devices())
            .map(|d| format!("down dev={d} start=1"))
            .collect();
        let faults = FaultTimeline::parse_specs(&all_down, c.n_devices()).unwrap();
        let opts = SimOptions { faults, ..Default::default() };
        let err = run_faulted(&m, &c, &t, "deepspeed", &opts).unwrap_err();
        assert!(err.contains("iteration 1"), "{err}");
    }

    #[test]
    fn checkpoint_resume_is_bit_identical() {
        // Kill-and-resume at the unit level, with the most stateful
        // policy (prophet histories + planner caches + drift detectors):
        // stop after 3 of 6 iterations, resume from the snapshot, and
        // require the final report bit-for-bit equal to straight-through.
        let (m, c, t) = setup();
        let full = run_pp(&m, &c, &t, ProphetOptions::full());
        let dir = std::env::temp_dir().join(format!(
            "pro_prophet_sim_resume_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let ck = CheckpointConfig { dir: dir.clone(), every: 2, resume: false };
        let opts = SimOptions {
            checkpoint: Some(ck.clone()),
            stop_after: Some(3),
            ..Default::default()
        };
        let partial = simulate_policy_faulted(
            &m,
            &c,
            &t,
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
            obs::noop_arc(),
            &opts,
        )
        .unwrap();
        assert_eq!(partial.iters.len(), 3, "stop_after must stop the run");
        let opts = SimOptions {
            checkpoint: Some(CheckpointConfig { resume: true, ..ck }),
            ..Default::default()
        };
        let resumed = simulate_policy_faulted(
            &m,
            &c,
            &t,
            Box::new(builtin::ProProphet::new(ProphetOptions::full())),
            obs::noop_arc(),
            &opts,
        )
        .unwrap();
        assert_eq!(resumed.iters.len(), full.iters.len());
        assert_eq!(resumed.plans_run, full.plans_run);
        assert_eq!(resumed.plans_reused, full.plans_reused);
        assert_eq!(resumed.drift_replans, full.drift_replans);
        for (i, (a, b)) in full.iters.iter().zip(&resumed.iters).enumerate() {
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "iter {i}");
            assert_eq!(a.barrier_time.to_bits(), b.barrier_time.to_bits(), "iter {i}");
            assert_eq!(a.des_time.to_bits(), b.des_time.to_bits(), "iter {i}");
            assert_eq!(a.balance_before.to_bits(), b.balance_before.to_bits(), "iter {i}");
            assert_eq!(a.forecast_error, b.forecast_error, "iter {i}");
            assert_eq!(a.breakdown, b.breakdown, "iter {i}");
            assert_eq!(a.devices, b.devices, "iter {i}");
            assert_eq!(a.straggler, b.straggler, "iter {i}");
        }
        // And the serialized reports — the contract the CLI smoke
        // diffs — are byte-identical.
        assert_eq!(
            checkpoint::report_to_json(&full).to_string(),
            checkpoint::report_to_json(&resumed).to_string()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
