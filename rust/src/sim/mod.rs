//! Training simulation: policies (Pro-Prophet and the baselines) executed
//! over workload traces on the discrete-event engine.
//!
//! This is the harness behind every paper table and figure: it prices one
//! training iteration of a (model, cluster, policy) triple and aggregates
//! per-iteration, per-layer, and breakdown statistics.

pub mod engine;
pub mod timeline;

pub use engine::Engine;

use crate::cluster::ClusterSpec;
use crate::config::ModelSpec;
use crate::metrics::balance_degree;
use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::planner::{greedy_search, policies, Planner, PlannerConfig};
use crate::prophet::{Prophet, ProphetConfig};
use crate::scheduler::{build_blocking, build_blockwise, BlockCosts, LoadBalanceOps};
use crate::util::threads;
use crate::workload::Trace;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Pro-Prophet feature switches (the Fig 14 ablation axes plus the
/// forecasting knobs of the prophet subsystem).
#[derive(Clone, Debug)]
pub struct ProphetOptions {
    pub planner: PlannerConfig,
    /// Block-wise overlap scheduling (§V) on/off.
    pub scheduler_on: bool,
    /// Forecasting subsystem knobs (predictor selection, drift detection).
    pub prophet: ProphetConfig,
}

impl Default for ProphetOptions {
    fn default() -> Self {
        ProphetOptions {
            planner: PlannerConfig::default(),
            scheduler_on: true,
            prophet: ProphetConfig::default(),
        }
    }
}

impl ProphetOptions {
    /// Planner only (scheduler ablated): Eq 6 evaluation, blocking timeline.
    pub fn planner_only() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: false,
            ..Default::default()
        }
    }

    /// Scheduler on, but the planner evaluates with the blocking Eq 6
    /// (i.e. without the §V-C combination).
    pub fn without_combination() -> Self {
        ProphetOptions {
            planner: PlannerConfig { use_overlap_model: false, ..Default::default() },
            scheduler_on: true,
            ..Default::default()
        }
    }

    /// Full system: block-wise scheduler + Eq 8-aware planner.
    pub fn full() -> Self {
        ProphetOptions::default()
    }
}

/// A load-balancing policy under simulation.
#[derive(Clone, Debug)]
pub enum Policy {
    /// Deepspeed-MoE: pure EP, no load balancing.
    DeepspeedMoe,
    /// FasterMoE: dynamic shadowing to ALL devices, blocking timeline.
    FasterMoe,
    /// Replicate the k heaviest experts to all devices (Fig 15 top2/top3).
    TopK(usize),
    /// Pro-Prophet (planner + optional scheduler).
    ProProphet(ProphetOptions),
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::DeepspeedMoe => "Deepspeed-MoE".into(),
            Policy::FasterMoe => "FasterMoE".into(),
            Policy::TopK(k) => format!("top{k}"),
            Policy::ProProphet(o) => {
                if o.scheduler_on && o.planner.use_overlap_model {
                    "Pro-Prophet".into()
                } else if o.scheduler_on {
                    "Pro-Prophet(no-comb)".into()
                } else {
                    "Pro-Prophet(planner)".into()
                }
            }
        }
    }
}

/// Aggregates of one simulated iteration.
#[derive(Clone, Debug)]
pub struct IterationResult {
    pub time: f64,
    /// Exposed seconds per breakdown category (search/place/reduce/...).
    pub breakdown: BTreeMap<&'static str, f64>,
    /// Per-MoE-block exposed time (sums to `time`).
    pub per_block_time: Vec<f64>,
    /// Balance degree (std of per-device computed load) before and after
    /// placement, averaged over layers.
    pub balance_before: f64,
    pub balance_after: f64,
    /// Parameter copies moved by Trans this iteration (comm volume proxy).
    pub trans_copies: u64,
    /// Mean normalized-L1 error of the prophet forecasts this iteration's
    /// plans were based on (None for non-forecasting policies and for the
    /// warm-up iteration).
    pub forecast_error: Option<f64>,
}

/// Whole-run aggregates.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub policy: String,
    pub iters: Vec<IterationResult>,
    /// Greedy searches actually executed (all layers, whole run).
    pub plans_run: usize,
    /// Plans served from the placement cache.
    pub plans_reused: usize,
    /// Replans forced by prophet drift detection.
    pub drift_replans: usize,
}

impl SimReport {
    pub fn total_time(&self) -> f64 {
        self.iters.iter().map(|i| i.time).sum()
    }

    pub fn avg_iter_time(&self) -> f64 {
        if self.iters.is_empty() {
            0.0
        } else {
            self.total_time() / self.iters.len() as f64
        }
    }

    pub fn iter_times(&self) -> Vec<f64> {
        self.iters.iter().map(|i| i.time).collect()
    }

    /// Mean exposed load-balancing fraction (Table I's "L.B." column).
    pub fn lb_fraction(&self) -> f64 {
        let total = self.total_time();
        if total <= 0.0 {
            return 0.0;
        }
        let lb: f64 = self
            .iters
            .iter()
            .map(|i| {
                i.breakdown.get("search").unwrap_or(&0.0)
                    + i.breakdown.get("place").unwrap_or(&0.0)
                    + i.breakdown.get("reduce").unwrap_or(&0.0)
            })
            .sum();
        lb / total
    }

    pub fn breakdown_fraction(&self, key: &str) -> f64 {
        let total = self.total_time();
        if total <= 0.0 {
            return 0.0;
        }
        let v: f64 = self
            .iters
            .iter()
            .map(|i| i.breakdown.get(key).copied().unwrap_or(0.0))
            .sum();
        v / total
    }

    /// Mean RB: balance-degree ratio before/after placement (Fig 16).
    pub fn mean_rb(&self) -> f64 {
        let ratios: Vec<f64> = self
            .iters
            .iter()
            .filter(|i| i.balance_after > 1e-9)
            .map(|i| i.balance_before / i.balance_after)
            .collect();
        if ratios.is_empty() {
            1.0
        } else {
            ratios.iter().sum::<f64>() / ratios.len() as f64
        }
    }

    /// Mean forecast error over the iterations that had a forecast
    /// (NaN when the policy never forecast anything).
    pub fn mean_forecast_error(&self) -> f64 {
        let errs: Vec<f64> = self.iters.iter().filter_map(|i| i.forecast_error).collect();
        if errs.is_empty() {
            f64::NAN
        } else {
            errs.iter().sum::<f64>() / errs.len() as f64
        }
    }

    pub fn mean_per_block_time(&self) -> Vec<f64> {
        if self.iters.is_empty() {
            return vec![];
        }
        let blocks = self.iters[0].per_block_time.len();
        let mut acc = vec![0.0; blocks];
        for it in &self.iters {
            for (a, t) in acc.iter_mut().zip(&it.per_block_time) {
                *a += t;
            }
        }
        for a in &mut acc {
            *a /= self.iters.len() as f64;
        }
        acc
    }
}

/// Per-layer planning + pricing outcome (the parallel phase's unit of
/// work; see [`plan_and_price`]).
struct LayerOutcome {
    costs: BlockCosts,
    bal_before: f64,
    bal_after: f64,
    trans_copies: u64,
}

/// Decide a placement for one layer and price its block operators.
/// Layers are independent within an iteration — planning reads only
/// forecasts armed by PREVIOUS iterations — so `simulate` fans this out
/// across layers with scoped threads.
fn plan_and_price(
    layer: usize,
    w: &LoadMatrix,
    policy: &Policy,
    pm: &PerfModel,
    eng: &Engine,
    planner: Option<&mut Planner>,
    prophet: Option<&Prophet>,
) -> LayerOutcome {
    let (placement, plan_cost): (Arc<Placement>, f64) = match policy {
        Policy::DeepspeedMoe => {
            (Arc::new(Placement::identity(w.n_experts(), w.n_devices())), 0.0)
        }
        Policy::FasterMoe => {
            // FasterMoE decides on the CURRENT iteration's gating (it has
            // no locality prediction) and pays its search every iteration.
            (Arc::new(policies::fastermoe_shadowing(w, pm)), pm.t_plan)
        }
        Policy::TopK(k) => {
            // topk() on the load vector: negligible decision cost.
            (Arc::new(policies::top_k_to_all(w, *k)), 0.0)
        }
        Policy::ProProphet(_) => {
            // Plan on the prophet's forecast of THIS iteration (available
            // from iteration 1 on); warm up on the observed matrix.
            let planner = planner.expect("Pro-Prophet pricing needs a planner");
            let forecast = prophet.and_then(|p| p.forecast_matrix(layer));
            let w_plan: &LoadMatrix = forecast.as_ref().unwrap_or(w);
            let before = planner.plans_run;
            let p = planner.plan(w_plan, pm);
            let cost = if planner.plans_run > before { pm.t_plan } else { 0.0 };
            (p, cost)
        }
    };
    let routed_before = w.route_identity();
    let routed_after = w.route(&placement);
    let unicast = matches!(policy, Policy::FasterMoe | Policy::TopK(_));
    LayerOutcome {
        costs: eng.block_costs_styled(w, &placement, plan_cost, unicast),
        bal_before: balance_degree(&routed_before.h),
        bal_after: balance_degree(&routed_after.h),
        trans_copies: placement.transfer_copies(),
    }
}

/// Simulate `trace` under `policy`.  For Pro-Prophet, placement decisions
/// for iteration i use the prophet subsystem's forecast built from
/// iterations 0..i (§V-A: the Plan primitive runs one iteration early on
/// predicted statistics); iteration 0 plans on its own distribution.
/// Prophet drift detection invalidates a layer's cached placement, forcing
/// a replan regardless of the replan interval.
///
/// The per-layer planning/pricing fan-out runs on scoped threads
/// ([`crate::util::threads`]); prophet observation stays sequential, so
/// results are identical to the serial loop (`PRO_PROPHET_THREADS=1`).
pub fn simulate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: &Policy,
) -> SimReport {
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let n_layers = trace.n_layers;

    // Per-layer planner state + the shared forecasting subsystem for
    // Pro-Prophet.
    let mut planners: Vec<Planner> = match policy {
        Policy::ProProphet(o) => (0..n_layers).map(|_| Planner::new(o.planner.clone())).collect(),
        _ => vec![],
    };
    let mut prophet: Option<Prophet> = match policy {
        Policy::ProProphet(o) => Some(Prophet::new(o.prophet.clone(), n_layers)),
        _ => None,
    };

    let mut report = SimReport { policy: policy.name(), ..Default::default() };

    for layers in trace.iterations.iter() {
        // Phase 1 (parallel across layers): plan placements and price the
        // block operators.  Planning consumes forecasts armed by previous
        // iterations only, so layer order does not matter.
        let outcomes: Vec<LayerOutcome> = match policy {
            Policy::ProProphet(_) => {
                let prophet_ref = prophet.as_ref();
                threads::par_map_mut(&mut planners, |l, planner| {
                    plan_and_price(l, &layers[l], policy, &pm, &eng, Some(planner), prophet_ref)
                })
            }
            _ => threads::par_map(n_layers, |l| {
                plan_and_price(l, &layers[l], policy, &pm, &eng, None, None)
            }),
        };

        // Phase 2 (sequential): feed the ACTUAL gating results to the
        // prophet — scores the outstanding forecasts, advances the
        // history, and runs drift detection for the next iteration's
        // plans.
        let mut forecast_errs: Vec<f64> = Vec::new();
        if let Some(prophet) = prophet.as_mut() {
            for (l, w) in layers.iter().enumerate() {
                let obs = prophet.observe_layer(l, w);
                if let Some(e) = obs.forecast_error {
                    forecast_errs.push(e);
                }
                if obs.drift {
                    planners[l].invalidate();
                    report.drift_replans += 1;
                }
            }
        }

        let mut costs: Vec<BlockCosts> = Vec::with_capacity(n_layers);
        let mut bal_before = 0.0;
        let mut bal_after = 0.0;
        let mut trans_copies = 0u64;
        for o in outcomes {
            bal_before += o.bal_before;
            bal_after += o.bal_after;
            trans_copies += o.trans_copies;
            costs.push(o.costs);
        }
        bal_before /= n_layers as f64;
        bal_after /= n_layers as f64;

        let schedule = match policy {
            Policy::DeepspeedMoe => build_blocking(&costs, LoadBalanceOps::None),
            Policy::FasterMoe | Policy::TopK(_) => {
                build_blocking(&costs, LoadBalanceOps::Blocking)
            }
            Policy::ProProphet(o) => {
                if o.scheduler_on {
                    build_blockwise(&costs)
                } else {
                    build_blocking(&costs, LoadBalanceOps::Blocking)
                }
            }
        };
        debug_assert!(schedule.validate_dependencies().is_ok());

        // Per-block exposed time: assign each stage to the block of its
        // first op.
        let mut per_block = vec![0.0; n_layers];
        for stage in &schedule.stages {
            if let Some(op) = stage.comp.first().or(stage.comm.first()) {
                let b = op.op.block().min(n_layers - 1);
                per_block[b] += stage.time();
            }
        }

        report.iters.push(IterationResult {
            time: schedule.total_time(),
            breakdown: schedule.exposed_breakdown(),
            per_block_time: per_block,
            balance_before: bal_before,
            balance_after: bal_after,
            trans_copies,
            forecast_error: if forecast_errs.is_empty() {
                None
            } else {
                Some(forecast_errs.iter().sum::<f64>() / forecast_errs.len() as f64)
            },
        });
    }

    // Whole-run planning totals.
    match policy {
        Policy::ProProphet(_) => {
            report.plans_run = planners.iter().map(|p| p.plans_run).sum();
            report.plans_reused = planners.iter().map(|p| p.plans_reused).sum();
        }
        Policy::FasterMoe => {
            // Pays its shadowing search for every layer of every iteration.
            report.plans_run = trace.len() * n_layers;
        }
        Policy::DeepspeedMoe | Policy::TopK(_) => {}
    }
    report
}

/// Convenience: simulate a single layer's load matrix once under a given
/// placement strategy, returning (identity placement time, policy time).
pub fn single_layer_times(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    w: &LoadMatrix,
    policy: &Policy,
) -> (f64, f64) {
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let ident = Placement::identity(w.n_experts(), w.n_devices());
    let t_ident = {
        let costs = [eng.block_costs(w, &ident, 0.0)];
        build_blocking(&costs, LoadBalanceOps::None).total_time()
    };
    let (placement, overlap) = match policy {
        Policy::DeepspeedMoe => (ident, false),
        Policy::FasterMoe => (policies::fastermoe_shadowing(w, &pm), false),
        Policy::TopK(k) => (policies::top_k_to_all(w, *k), false),
        Policy::ProProphet(o) => (
            greedy_search(w, &pm, &o.planner).placement,
            o.scheduler_on,
        ),
    };
    let unicast = matches!(policy, Policy::FasterMoe | Policy::TopK(_));
    let costs = [eng.block_costs_styled(w, &placement, 0.0, unicast)];
    let t_policy = if overlap {
        build_blockwise(&costs).total_time()
    } else {
        build_blocking(&costs, LoadBalanceOps::Blocking).total_time()
    };
    (t_ident, t_policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Trace, WorkloadConfig, WorkloadGen};

    fn setup() -> (ModelSpec, ClusterSpec, Trace) {
        let model = ModelSpec::moe_gpt_s(8, 1, 8192);
        let cluster = ClusterSpec::hpwnv(2);
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(4, 8, 8, 8192));
        let trace = Trace::capture(&mut gen, 6);
        (model, cluster, trace)
    }

    #[test]
    fn deepspeed_has_zero_lb_overhead() {
        let (m, c, t) = setup();
        let r = simulate(&m, &c, &t, &Policy::DeepspeedMoe);
        assert_eq!(r.lb_fraction(), 0.0);
        assert!(r.avg_iter_time() > 0.0);
        assert_eq!(r.iters.len(), 6);
    }

    #[test]
    fn fastermoe_beats_deepspeed_on_skewed_load() {
        let (m, c, t) = setup();
        let ds = simulate(&m, &c, &t, &Policy::DeepspeedMoe);
        let fm = simulate(&m, &c, &t, &Policy::FasterMoe);
        assert!(
            fm.avg_iter_time() < ds.avg_iter_time(),
            "FasterMoE {:.4} !< Deepspeed {:.4}",
            fm.avg_iter_time(),
            ds.avg_iter_time()
        );
        assert!(fm.lb_fraction() > 0.0, "FasterMoE pays LB overhead");
    }

    #[test]
    fn pro_prophet_beats_fastermoe() {
        let (m, c, t) = setup();
        let fm = simulate(&m, &c, &t, &Policy::FasterMoe);
        let pp = simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::full()));
        assert!(
            pp.avg_iter_time() < fm.avg_iter_time(),
            "Pro-Prophet {:.4} !< FasterMoE {:.4}",
            pp.avg_iter_time(),
            fm.avg_iter_time()
        );
    }

    #[test]
    fn scheduler_ablation_ordering() {
        // full <= planner-only <= deepspeed (on skewed workloads).
        let (m, c, t) = setup();
        let full = simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::full()));
        let planner_only =
            simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::planner_only()));
        let ds = simulate(&m, &c, &t, &Policy::DeepspeedMoe);
        assert!(full.avg_iter_time() <= planner_only.avg_iter_time() + 1e-12);
        assert!(planner_only.avg_iter_time() < ds.avg_iter_time());
    }

    #[test]
    fn balance_improves_under_planner() {
        let (m, c, t) = setup();
        let pp = simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::full()));
        assert!(pp.mean_rb() > 1.5, "RB {}", pp.mean_rb());
        for it in &pp.iters {
            assert!(it.balance_after <= it.balance_before + 1e-9);
        }
    }

    #[test]
    fn prophet_placements_are_lightweight_per_expert() {
        // §IV-A: a lightweight placement ships each selected expert to a
        // SUBSET of devices, vs FasterMoE's full broadcast (D-1 receivers per
        // shadowed expert).  Compare receivers per selected expert.
        let (m, c, t) = setup();
        let pm = crate::perfmodel::PerfModel::new(&m, &c);
        let w = &t.iterations[2][0];
        let pp = crate::planner::greedy_search(
            w,
            &pm,
            &crate::planner::PlannerConfig::default(),
        )
        .placement;
        let d = w.n_devices();
        for &e in &pp.transferred_experts() {
            assert!(
                pp.replicas(e).len() < d,
                "prophet replicated expert {e} to every device"
            );
        }
        let fm = crate::planner::policies::fastermoe_shadowing(w, &pm);
        for &e in &fm.transferred_experts() {
            assert_eq!(fm.replicas(e).len(), d, "FasterMoE always broadcasts");
        }
        // And despite moving each expert to fewer devices, the prophet's
        // balance is at least as good.
        let bal = |p: &Placement| balance_degree(&w.route(p).h);
        assert!(bal(&pp) <= bal(&fm) * 1.5 + 1.0);
    }

    #[test]
    fn per_block_times_sum_to_iteration() {
        let (m, c, t) = setup();
        let r = simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::full()));
        for it in &r.iters {
            let sum: f64 = it.per_block_time.iter().sum();
            assert!((sum - it.time).abs() < 1e-9 * it.time.max(1.0));
        }
    }

    #[test]
    fn prophet_reports_forecast_and_replan_metrics() {
        let (m, c, t) = setup();
        let r = simulate(&m, &c, &t, &Policy::ProProphet(ProphetOptions::full()));
        // Warm-up iteration has no forecast to score; later ones do.
        assert!(r.iters[0].forecast_error.is_none());
        assert!(r.iters.iter().skip(1).all(|i| i.forecast_error.is_some()));
        assert!(
            r.mean_forecast_error() < 0.3,
            "forecast error {} too large for a high-locality trace",
            r.mean_forecast_error()
        );
        // Every layer of every iteration was either planned or reused.
        assert_eq!(r.plans_run + r.plans_reused, 6 * t.n_layers);
        let ds = simulate(&m, &c, &t, &Policy::DeepspeedMoe);
        assert_eq!(ds.plans_run, 0);
        assert!(ds.mean_forecast_error().is_nan());
        let fm = simulate(&m, &c, &t, &Policy::FasterMoe);
        assert_eq!(fm.plans_run, 6 * t.n_layers);
    }

    #[test]
    fn drift_forces_replans_under_lazy_replanning() {
        // 1-layer hand-built trace: stable regime, violent shift, stable
        // again.  With a huge replan interval only drift detection can
        // trigger the second plan.
        let stable = LoadMatrix::from_rows(vec![vec![600, 100, 100, 224]; 4]);
        let shifted = LoadMatrix::from_rows(vec![vec![50, 100, 100, 774]; 4]);
        let mut trace = Trace::new(1, 4, 4);
        for _ in 0..6 {
            trace.push(vec![stable.clone()]);
        }
        for _ in 0..6 {
            trace.push(vec![shifted.clone()]);
        }
        let model = ModelSpec::moe_gpt_s(4, 1, 4096);
        let cluster = ClusterSpec::hpwnv(1);
        let opts = ProphetOptions {
            planner: PlannerConfig { replan_interval: 1000, ..Default::default() },
            ..Default::default()
        };
        let r = simulate(&model, &cluster, &trace, &Policy::ProProphet(opts));
        assert_eq!(r.drift_replans, 1, "exactly one regime change");
        assert_eq!(r.plans_run, 2, "initial plan + drift-forced replan");
        assert_eq!(r.plans_reused, 10);
    }

    #[test]
    fn topk_policies_run() {
        let (m, c, t) = setup();
        for k in [2, 3] {
            let r = simulate(&m, &c, &t, &Policy::TopK(k));
            assert!(r.avg_iter_time() > 0.0);
            assert_eq!(r.policy, format!("top{k}"));
        }
    }

    #[test]
    fn single_layer_policy_times() {
        let (m, c, t) = setup();
        let w = &t.iterations[0][0];
        let (ident, pp) =
            single_layer_times(&m, &c, w, &Policy::ProProphet(ProphetOptions::full()));
        assert!(pp < ident, "single layer: prophet {pp} !< identity {ident}");
    }
}
