//! Fine-grained operator cost engine of the discrete-event simulator.
//!
//! Unlike the planner's performance model (which aggregates over devices
//! with `max` and the average bandwidth B̄ — Eq 1–5), the engine prices
//! every transfer at the *actual* link bandwidth of the device pair and
//! serializes each device's egress/ingress, i.e. it plays the role of the
//! authors' real cluster.  The gap between the two is exactly what the
//! paper's Fig 13 measures (<5% mean error), reproduced by our fig13
//! bench.

use crate::cluster::ClusterSpec;
use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::scheduler::{BlockCosts, DeviceBlockCosts};

pub struct Engine<'a> {
    pub cluster: &'a ClusterSpec,
    pub pm: &'a PerfModel,
}

impl<'a> Engine<'a> {
    pub fn new(cluster: &'a ClusterSpec, pm: &'a PerfModel) -> Self {
        assert_eq!(cluster.n_devices(), pm.n_devices);
        Engine { cluster, pm }
    }

    /// A2A makespan from a per-pair token traffic matrix: each device
    /// serializes its sends over its NIC and its receives likewise; links
    /// of distinct pairs run concurrently (Tutel's P2P A2A).
    pub fn a2a_time(&self, traffic: &[Vec<u64>]) -> f64 {
        let d = self.cluster.n_devices();
        let bytes = self.pm.token_bytes;
        let mut worst: f64 = 0.0;
        for i in 0..d {
            let mut egress = 0.0;
            let mut ingress = 0.0;
            for j in 0..d {
                if i == j {
                    continue;
                }
                if traffic[i][j] > 0 {
                    egress += traffic[i][j] as f64 * bytes / self.cluster.bandwidth(i, j);
                }
                if traffic[j][i] > 0 {
                    ingress += traffic[j][i] as f64 * bytes / self.cluster.bandwidth(j, i);
                }
            }
            worst = worst.max(egress).max(ingress);
        }
        worst
    }

    /// Trans makespan.  Each selected expert's parameters are broadcast to
    /// its replica set with a scatter+allgather collective (the standard
    /// large-message broadcast): the tensor is chunked D ways, so moving it
    /// to r of D devices streams ~ r/D of the bytes over the slowest
    /// participating link.  Collectives of one layer share the comm stream
    /// and serialize — which is exactly the shape of the paper's Eq 4
    /// (s·(D−n)·size / (D·B̄)), with the per-expert bottleneck link in
    /// place of B̄.
    pub fn trans_time(&self, p: &Placement) -> f64 {
        let d = self.cluster.n_devices() as f64;
        let bytes = self.pm.expert_bytes;
        let mut total = 0.0;
        for e in p.transferred_experts() {
            let home = p.home(e);
            let mut bottleneck = f64::INFINITY;
            for dev in p.replicas(e).iter() {
                if dev != home {
                    bottleneck = bottleneck.min(self.cluster.bandwidth(home, dev));
                }
            }
            if bottleneck.is_finite() {
                let r = p.replicas(e).len() as f64;
                total += r * bytes / (d * bottleneck);
            }
        }
        total
    }

    /// Agg mirrors Trans (gradients flow replica -> home).
    pub fn agg_time(&self, p: &Placement) -> f64 {
        self.trans_time(p)
    }

    /// Coarse transfer (FasterMoE shadowing / top-k-to-all): the same
    /// collective volume but launched blocking and un-chunked
    /// ([`crate::perfmodel::COARSE_FACTOR`] slower than the pipelined
    /// transfer Pro-Prophet's scheduler issues).
    pub fn trans_time_coarse(&self, p: &Placement) -> f64 {
        crate::perfmodel::COARSE_FACTOR * self.trans_time(p)
    }

    /// Expert computation: per-device token queue over its throughput.
    pub fn fec_time(&self, h: &[u64]) -> f64 {
        let max_h = h.iter().copied().max().unwrap_or(0) as f64;
        max_h / self.pm.tokens_per_s
    }

    pub fn bec_time(&self, h: &[u64]) -> f64 {
        2.0 * self.fec_time(h)
    }

    // --- per-device cost vectors -------------------------------------------
    //
    // The scalar costs above pre-collapse every operator to its
    // worst-case device (`max`), which is what the frozen barrier
    // [`crate::scheduler::Schedule`] consumes.  The `*_per_device`
    // variants keep the whole vector so the device-level event timeline
    // ([`crate::sim::events`]) can see stragglers, per-device exposed
    // communication and the cluster's [`ClusterSpec::device_slowdown`]
    // knob.  Compute costs scale with the per-device slowdown;
    // communication costs do not (a slow GPU's NIC is not slower).

    /// Per-device A2A busy time: each device serializes its egress and
    /// its ingress; the slower of the two bounds its participation
    /// (`max` over this vector == [`Engine::a2a_time`]).
    pub fn a2a_time_per_device(&self, traffic: &[Vec<u64>]) -> Vec<f64> {
        let d = self.cluster.n_devices();
        let bytes = self.pm.token_bytes;
        let mut out = vec![0.0; d];
        for (i, slot) in out.iter_mut().enumerate() {
            let mut egress = 0.0;
            let mut ingress = 0.0;
            for j in 0..d {
                if i == j {
                    continue;
                }
                if traffic[i][j] > 0 {
                    egress += traffic[i][j] as f64 * bytes / self.cluster.bandwidth(i, j);
                }
                if traffic[j][i] > 0 {
                    ingress += traffic[j][i] as f64 * bytes / self.cluster.bandwidth(j, i);
                }
            }
            *slot = egress.max(ingress);
        }
        out
    }

    /// Per-device expert computation: the device's token queue over its
    /// (slowdown-scaled) throughput.
    pub fn fec_time_per_device(&self, h: &[u64]) -> Vec<f64> {
        h.iter()
            .enumerate()
            .map(|(i, &t)| t as f64 * self.cluster.slowdown(i) / self.pm.tokens_per_s)
            .collect()
    }

    pub fn bec_time_per_device(&self, h: &[u64]) -> Vec<f64> {
        self.fec_time_per_device(h).into_iter().map(|t| 2.0 * t).collect()
    }

    /// Per-device non-MoE computation (static per §V-B, scaled only by
    /// the device's slowdown factor).
    pub fn fnec_time_per_device(&self) -> Vec<f64> {
        (0..self.cluster.n_devices())
            .map(|i| self.pm.t_fnec * self.cluster.slowdown(i))
            .collect()
    }

    pub fn bnec_time_per_device(&self) -> Vec<f64> {
        (0..self.cluster.n_devices())
            .map(|i| self.pm.t_bnec * self.cluster.slowdown(i))
            .collect()
    }

    /// Per-device Trans busy time: each device pays the collectives it
    /// PARTICIPATES in (home or replica of a transferred expert), so
    /// `max` over this vector is at most the globally serialized
    /// [`Engine::trans_time`] — the per-device refinement the barrier
    /// model cannot express.
    pub fn trans_time_per_device(&self, p: &Placement) -> Vec<f64> {
        let d = self.cluster.n_devices() as f64;
        let bytes = self.pm.expert_bytes;
        let mut out = vec![0.0; self.cluster.n_devices()];
        for e in p.transferred_experts() {
            let home = p.home(e);
            let mut bottleneck = f64::INFINITY;
            for dev in p.replicas(e).iter() {
                if dev != home {
                    bottleneck = bottleneck.min(self.cluster.bandwidth(home, dev));
                }
            }
            if bottleneck.is_finite() {
                let r = p.replicas(e).len() as f64;
                let cost = r * bytes / (d * bottleneck);
                out[home] += cost;
                for dev in p.replicas(e).iter() {
                    if dev != home {
                        out[dev] += cost;
                    }
                }
            }
        }
        out
    }

    pub fn agg_time_per_device(&self, p: &Placement) -> Vec<f64> {
        self.trans_time_per_device(p)
    }

    /// Coarse (blocking, un-chunked) variant of
    /// [`Engine::trans_time_per_device`].
    pub fn trans_time_coarse_per_device(&self, p: &Placement) -> Vec<f64> {
        self.trans_time_per_device(p)
            .into_iter()
            .map(|t| crate::perfmodel::COARSE_FACTOR * t)
            .collect()
    }

    /// All operator costs of one MoE block under `placement`.
    /// `plan_time` is the Plan cost this iteration actually pays (0 when
    /// the planner reused a cached placement or the policy never plans).
    pub fn block_costs(
        &self,
        w: &LoadMatrix,
        placement: &Placement,
        plan_time: f64,
    ) -> BlockCosts {
        self.block_costs_styled(w, placement, plan_time, false)
    }

    /// Like [`Engine::block_costs`] but with `coarse = true` for policies
    /// whose transfer path is the coarse blocking broadcast (FasterMoE,
    /// top-k-to-all).
    pub fn block_costs_styled(
        &self,
        w: &LoadMatrix,
        placement: &Placement,
        plan_time: f64,
        coarse: bool,
    ) -> BlockCosts {
        let routed = w.route(placement);
        let traffic = w.traffic(placement);
        let (trans, agg) = if coarse {
            let t = self.trans_time_coarse(placement);
            (t, t)
        } else {
            (self.trans_time(placement), self.agg_time(placement))
        };
        BlockCosts {
            a2a: self.a2a_time(&traffic),
            fec: self.fec_time(&routed.h),
            bec: self.bec_time(&routed.h),
            fnec: self.pm.t_fnec,
            bnec: self.pm.t_bnec,
            trans,
            agg,
            plan: plan_time,
        }
    }

    /// Per-device operator costs of one MoE block (the
    /// [`DeviceBlockCosts`] the DAG builders and the event timeline
    /// consume).  `Plan` runs on the host and stays uniform.
    pub fn device_block_costs_styled(
        &self,
        w: &LoadMatrix,
        placement: &Placement,
        plan_time: f64,
        coarse: bool,
    ) -> DeviceBlockCosts {
        self.priced_block_styled(w, placement, plan_time, coarse).1
    }

    /// Scalar + per-device costs + the routed load, all from ONE routing
    /// pass.  The scalar side is computed with exactly the same calls as
    /// [`Engine::block_costs_styled`], so it is bit-identical to the
    /// frozen path; the vector side refines it per device; the
    /// [`crate::moe::RoutedLoad`] is returned so callers (the simulator's
    /// balance-degree accounting) need no second route of the same
    /// placement.
    pub fn priced_block_styled(
        &self,
        w: &LoadMatrix,
        placement: &Placement,
        plan_time: f64,
        coarse: bool,
    ) -> (BlockCosts, DeviceBlockCosts, crate::moe::RoutedLoad) {
        let (routed, traffic) = w.route_full(placement);
        let (trans, agg) = if coarse {
            let t = self.trans_time_coarse(placement);
            (t, t)
        } else {
            (self.trans_time(placement), self.agg_time(placement))
        };
        let scalar = BlockCosts {
            a2a: self.a2a_time(&traffic),
            fec: self.fec_time(&routed.h),
            bec: self.bec_time(&routed.h),
            fnec: self.pm.t_fnec,
            bnec: self.pm.t_bnec,
            trans,
            agg,
            plan: plan_time,
        };
        let (trans_dev, agg_dev) = if coarse {
            let t = self.trans_time_coarse_per_device(placement);
            (t.clone(), t)
        } else {
            (
                self.trans_time_per_device(placement),
                self.agg_time_per_device(placement),
            )
        };
        let device = DeviceBlockCosts {
            a2a: self.a2a_time_per_device(&traffic),
            fec: self.fec_time_per_device(&routed.h),
            bec: self.bec_time_per_device(&routed.h),
            fnec: self.fnec_time_per_device(),
            bnec: self.bnec_time_per_device(),
            trans: trans_dev,
            agg: agg_dev,
            plan: vec![plan_time; self.cluster.n_devices()],
        };
        (scalar, device, routed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn setup() -> (ModelSpec, ClusterSpec) {
        (ModelSpec::moe_gpt_s(8, 1, 8192), ClusterSpec::hpwnv(2))
    }

    #[test]
    fn a2a_zero_for_local_traffic() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let traffic = vec![vec![0u64; 8]; 8];
        assert_eq!(eng.a2a_time(&traffic), 0.0);
    }

    #[test]
    fn a2a_inter_node_slower_than_intra() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut intra = vec![vec![0u64; 8]; 8];
        intra[0][1] = 1000; // same node
        let mut inter = vec![vec![0u64; 8]; 8];
        inter[0][4] = 1000; // across nodes
        assert!(eng.a2a_time(&inter) > eng.a2a_time(&intra));
    }

    #[test]
    fn a2a_serializes_egress() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut one = vec![vec![0u64; 8]; 8];
        one[0][1] = 1000;
        let mut two = vec![vec![0u64; 8]; 8];
        two[0][1] = 1000;
        two[0][2] = 1000;
        assert!((eng.a2a_time(&two) - 2.0 * eng.a2a_time(&one)).abs() < 1e-12);
    }

    #[test]
    fn trans_zero_for_identity() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        assert_eq!(eng.trans_time(&Placement::identity(8, 8)), 0.0);
    }

    #[test]
    fn trans_scales_with_receivers() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut p1 = Placement::identity(8, 8);
        p1.add_replica(0, 1);
        let mut p2 = Placement::identity(8, 8);
        p2.replicate_to_all(0);
        assert!(eng.trans_time(&p2) > eng.trans_time(&p1));
        assert!((eng.agg_time(&p2) - eng.trans_time(&p2)).abs() < 1e-18);
    }

    #[test]
    fn engine_close_to_perf_model() {
        // The Fig 13 property: Eq 1's B̄ estimate lands within a modest
        // error of the engine's per-link accounting on realistic traffic.
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut gen = crate::workload::WorkloadGen::new(
            crate::workload::WorkloadConfig::paper_default(1, 8, 8, 8192),
        );
        let w = &gen.next_iteration()[0];
        let ident = Placement::identity(8, 8);
        let routed = w.route(&ident);
        let est = pm.t_a2a(&routed.r);
        let real = eng.a2a_time(&w.traffic(&ident));
        let err = (est - real).abs() / real.max(1e-12);
        assert!(err < 0.6, "estimate {est} vs engine {real} (err {err})");
    }

    #[test]
    fn per_device_vectors_refine_the_scalars() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut gen = crate::workload::WorkloadGen::new(
            crate::workload::WorkloadConfig::paper_default(1, 8, 8, 8192),
        );
        let w = &gen.next_iteration()[0];
        let mut p = Placement::identity(8, 8);
        p.add_replica(0, 1);
        p.add_replica(0, 2);
        let (routed, traffic) = w.route_full(&p);
        // max over devices reproduces the pre-maxed scalar exactly.
        let a2a = eng.a2a_time_per_device(&traffic);
        let max_a2a = a2a.iter().copied().fold(0.0f64, f64::max);
        assert_eq!(max_a2a.to_bits(), eng.a2a_time(&traffic).to_bits());
        let fec = eng.fec_time_per_device(&routed.h);
        let max_fec = fec.iter().copied().fold(0.0f64, f64::max);
        assert_eq!(max_fec.to_bits(), eng.fec_time(&routed.h).to_bits());
        for (b2, f2) in eng.bec_time_per_device(&routed.h).iter().zip(&fec) {
            assert!((b2 - 2.0 * f2).abs() < 1e-18);
        }
        // Per-device Trans charges only participants; its max is bounded
        // by the globally serialized scalar.
        let trans = eng.trans_time_per_device(&p);
        let max_trans = trans.iter().copied().fold(0.0f64, f64::max);
        assert!(max_trans <= eng.trans_time(&p) + 1e-15);
        assert!(max_trans > 0.0);
        // Non-participants pay nothing (experts 0's collective touches
        // devices 0..=2 only under this placement).
        assert_eq!(trans[5], 0.0);
        assert!(trans[0] > 0.0 && trans[1] > 0.0 && trans[2] > 0.0);
    }

    #[test]
    fn slowdown_scales_compute_not_comm() {
        let (m, c) = setup();
        let het = c.clone().with_slowdown(3, 2.0);
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let eng_het = Engine::new(&het, &pm);
        let h: Vec<u64> = vec![100; 8];
        let fec = eng.fec_time_per_device(&h);
        let fec_het = eng_het.fec_time_per_device(&h);
        assert!((fec_het[3] - 2.0 * fec[3]).abs() < 1e-18);
        assert_eq!(fec_het[0].to_bits(), fec[0].to_bits());
        assert!((eng_het.fnec_time_per_device()[3] - 2.0 * pm.t_fnec).abs() < 1e-18);
        // Communication is not scaled.
        let mut traffic = vec![vec![0u64; 8]; 8];
        traffic[3][0] = 1000;
        let a = eng.a2a_time_per_device(&traffic);
        let b = eng_het.a2a_time_per_device(&traffic);
        assert_eq!(a, b);
        // The scalar path deliberately ignores the knob.
        assert_eq!(eng.fec_time(&h).to_bits(), eng_het.fec_time(&h).to_bits());
    }

    #[test]
    fn priced_block_scalar_matches_block_costs() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let mut gen = crate::workload::WorkloadGen::new(
            crate::workload::WorkloadConfig::paper_default(1, 8, 8, 8192),
        );
        let w = &gen.next_iteration()[0];
        let mut p = Placement::identity(8, 8);
        p.replicate_to_all(0);
        for coarse in [false, true] {
            let want = eng.block_costs_styled(w, &p, 0.25, coarse);
            let (got, dev, routed) = eng.priced_block_styled(w, &p, 0.25, coarse);
            assert_eq!(routed, w.route(&p), "returned routed load must match route()");
            for (a, b) in [
                (want.a2a, got.a2a),
                (want.fec, got.fec),
                (want.bec, got.bec),
                (want.fnec, got.fnec),
                (want.bnec, got.bnec),
                (want.trans, got.trans),
                (want.agg, got.agg),
                (want.plan, got.plan),
            ] {
                assert_eq!(a.to_bits(), b.to_bits(), "coarse={coarse}");
            }
            assert_eq!(dev.n_devices(), 8);
            assert_eq!(dev.plan, vec![0.25; 8]);
        }
    }

    #[test]
    fn block_costs_plan_passthrough() {
        let (m, c) = setup();
        let pm = PerfModel::new(&m, &c);
        let eng = Engine::new(&c, &pm);
        let w = LoadMatrix::from_rows(vec![vec![128; 8]; 8]);
        let costs = eng.block_costs(&w, &Placement::identity(8, 8), 0.123);
        assert_eq!(costs.plan, 0.123);
        assert_eq!(costs.trans, 0.0);
        assert!(costs.fec > 0.0);
        assert!((costs.bec - 2.0 * costs.fec).abs() < 1e-15);
    }
}
