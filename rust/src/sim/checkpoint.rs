//! Checkpoint/resume for the simulator: snapshot completed iterations to
//! disk, kill the run, and resume to a [`SimReport`] bit-identical to the
//! uninterrupted one.
//!
//! The snapshot stores only *results* (the finished [`IterationResult`]s)
//! plus enough identity to refuse a mismatched resume — policy name, a
//! trace signature, and the fault-timeline specs.  Session state
//! (prophet histories, planner caches, drift detectors, health masks) is
//! deliberately NOT serialized: it is a pure function of the
//! decide→observe call sequence, so the simulator replays that sequence
//! from the (deterministic) trace instead — see
//! `sim::simulate_policy_faulted`.  That keeps the format small, stable
//! and honest: anything the replay cannot reconstruct bit-for-bit would
//! be a determinism bug the resume test suite is designed to catch.
//!
//! Numbers survive the JSON round trip bit-exactly: the writer emits
//! integral values as integers and everything else via shortest-roundtrip
//! formatting, and the parser goes through `str::parse::<f64>` (the one
//! exception, `-0.0`, cannot occur in the strictly non-negative fields
//! stored here).  Saves are atomic (write to a temp file, then rename) so
//! a kill mid-save leaves the previous snapshot intact.

use crate::sim::{IterationResult, SimReport};
use crate::sim::events::DeviceStats;
use crate::util::json::{self, Json};
use crate::workload::Trace;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Schema tag of `checkpoint.json`.
pub const SCHEMA: &str = "pro-prophet-checkpoint/v1";
/// Schema tag of a serialized [`SimReport`] (`--report-json`).
pub const REPORT_SCHEMA: &str = "pro-prophet-simreport/v1";

/// Map a breakdown key back to the scheduler's `'static` vocabulary
/// ([`crate::scheduler::Op::breakdown_key`]).
fn breakdown_key(name: &str) -> Result<&'static str, String> {
    for k in ["search", "place", "reduce", "a2a", "expert_comp", "non_moe_comp"] {
        if k == name {
            return Ok(k);
        }
    }
    Err(format!("checkpoint: unknown breakdown key `{name}`"))
}

fn get<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("checkpoint: missing `{key}`"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64, String> {
    get(j, key)?
        .as_f64()
        .ok_or_else(|| format!("checkpoint: `{key}` is not a number"))
}

fn get_usize(j: &Json, key: &str) -> Result<usize, String> {
    get(j, key)?
        .as_usize()
        .ok_or_else(|| format!("checkpoint: `{key}` is not a number"))
}

fn get_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    get(j, key)?
        .as_str()
        .ok_or_else(|| format!("checkpoint: `{key}` is not a string"))
}

fn get_arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], String> {
    get(j, key)?
        .as_arr()
        .ok_or_else(|| format!("checkpoint: `{key}` is not an array"))
}

/// One [`IterationResult`] as JSON (round-trips bit-exactly).
pub fn iteration_to_json(it: &IterationResult) -> Json {
    let breakdown = Json::Obj(
        it.breakdown
            .iter()
            .map(|(k, v)| (k.to_string(), json::num(*v)))
            .collect(),
    );
    let devices = json::arr(
        it.devices
            .iter()
            .map(|d| {
                json::obj(vec![
                    ("busy_comp", json::num(d.busy_comp)),
                    ("busy_comm", json::num(d.busy_comm)),
                    ("exposed_comm", json::num(d.exposed_comm)),
                    ("idle", json::num(d.idle)),
                    ("finish", json::num(d.finish)),
                ])
            })
            .collect(),
    );
    json::obj(vec![
        ("time", json::num(it.time)),
        ("barrier_time", json::num(it.barrier_time)),
        ("des_time", json::num(it.des_time)),
        ("breakdown", breakdown),
        ("per_block_time", json::num_arr(&it.per_block_time)),
        ("balance_before", json::num(it.balance_before)),
        ("balance_after", json::num(it.balance_after)),
        ("trans_copies", json::num(it.trans_copies as f64)),
        (
            "forecast_error",
            it.forecast_error.map_or(Json::Null, json::num),
        ),
        ("straggler", json::num(it.straggler as f64)),
        ("devices", devices),
    ])
}

/// Parse one [`IterationResult`] back (inverse of [`iteration_to_json`]).
pub fn iteration_from_json(j: &Json) -> Result<IterationResult, String> {
    let mut breakdown: BTreeMap<&'static str, f64> = BTreeMap::new();
    let bd = get(j, "breakdown")?
        .as_obj()
        .ok_or("checkpoint: `breakdown` is not an object")?;
    for (k, v) in bd {
        let val = v
            .as_f64()
            .ok_or_else(|| format!("checkpoint: breakdown `{k}` is not a number"))?;
        breakdown.insert(breakdown_key(k)?, val);
    }
    let per_block_time = get_arr(j, "per_block_time")?
        .iter()
        .map(|x| {
            x.as_f64()
                .ok_or("checkpoint: `per_block_time` entry is not a number".to_string())
        })
        .collect::<Result<Vec<f64>, String>>()?;
    let mut devices = Vec::new();
    for d in get_arr(j, "devices")? {
        devices.push(DeviceStats {
            busy_comp: get_f64(d, "busy_comp")?,
            busy_comm: get_f64(d, "busy_comm")?,
            exposed_comm: get_f64(d, "exposed_comm")?,
            idle: get_f64(d, "idle")?,
            finish: get_f64(d, "finish")?,
        });
    }
    let forecast_error = match get(j, "forecast_error")? {
        Json::Null => None,
        v => Some(
            v.as_f64()
                .ok_or("checkpoint: `forecast_error` is not a number")?,
        ),
    };
    Ok(IterationResult {
        time: get_f64(j, "time")?,
        barrier_time: get_f64(j, "barrier_time")?,
        breakdown,
        per_block_time,
        balance_before: get_f64(j, "balance_before")?,
        balance_after: get_f64(j, "balance_after")?,
        trans_copies: get_f64(j, "trans_copies")? as u64,
        forecast_error,
        des_time: get_f64(j, "des_time")?,
        devices,
        straggler: get_usize(j, "straggler")?,
    })
}

/// Serialize a whole [`SimReport`] (`simulate --report-json`): the
/// resume-bit-identity contract is "both runs serialize to the same
/// bytes under this formatter".
pub fn report_to_json(r: &SimReport) -> Json {
    json::obj(vec![
        ("schema", json::s(REPORT_SCHEMA)),
        ("policy", json::s(&r.policy)),
        ("plans_run", json::num(r.plans_run as f64)),
        ("plans_reused", json::num(r.plans_reused as f64)),
        ("drift_replans", json::num(r.drift_replans as f64)),
        (
            "iters",
            json::arr(r.iters.iter().map(iteration_to_json).collect()),
        ),
    ])
}

/// FNV-1a 64 over the trace's canonical serialization — cheap, stable,
/// dependency-free identity for "is this the same trace?".
pub fn trace_hash(trace: &Trace) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in trace.serialize().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// A simulator snapshot: everything needed to resume and to refuse a
/// mismatched resume.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Policy display name the run was started with.
    pub policy: String,
    /// First iteration the resumed run must execute live.
    pub next_iter: usize,
    /// Trace identity: (layers, devices, experts, iterations, hash).
    pub trace_shape: (usize, usize, usize, usize),
    pub trace_hash: String,
    /// Fault timeline as round-trippable specs
    /// ([`crate::faults::FaultTimeline::specs`]).
    pub fault_specs: Vec<String>,
    /// Completed iterations, verbatim.
    pub iters: Vec<IterationResult>,
}

impl Checkpoint {
    /// The snapshot file inside a checkpoint directory.
    pub fn file(dir: &Path) -> PathBuf {
        dir.join("checkpoint.json")
    }

    /// Build a snapshot of a partially completed run.
    pub fn of(policy: &str, trace: &Trace, fault_specs: Vec<String>, iters: &[IterationResult]) -> Checkpoint {
        Checkpoint {
            policy: policy.to_string(),
            next_iter: iters.len(),
            trace_shape: (trace.n_layers, trace.n_devices, trace.n_experts, trace.len()),
            trace_hash: trace_hash(trace),
            fault_specs,
            iters: iters.to_vec(),
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", json::s(SCHEMA)),
            ("policy", json::s(&self.policy)),
            ("next_iter", json::num(self.next_iter as f64)),
            (
                "trace",
                json::obj(vec![
                    ("layers", json::num(self.trace_shape.0 as f64)),
                    ("devices", json::num(self.trace_shape.1 as f64)),
                    ("experts", json::num(self.trace_shape.2 as f64)),
                    ("iters", json::num(self.trace_shape.3 as f64)),
                    ("hash", json::s(&self.trace_hash)),
                ]),
            ),
            (
                "faults",
                json::arr(self.fault_specs.iter().map(|s| json::s(s)).collect()),
            ),
            (
                "iters",
                json::arr(self.iters.iter().map(iteration_to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint, String> {
        let schema = get_str(j, "schema")?;
        if schema != SCHEMA {
            return Err(format!(
                "checkpoint: schema `{schema}`, this build reads `{SCHEMA}`"
            ));
        }
        let trace = get(j, "trace")?;
        let mut fault_specs = Vec::new();
        for s in get_arr(j, "faults")? {
            fault_specs.push(
                s.as_str()
                    .ok_or("checkpoint: `faults` entry is not a string")?
                    .to_string(),
            );
        }
        let mut iters = Vec::new();
        for it in get_arr(j, "iters")? {
            iters.push(iteration_from_json(it)?);
        }
        let ck = Checkpoint {
            policy: get_str(j, "policy")?.to_string(),
            next_iter: get_usize(j, "next_iter")?,
            trace_shape: (
                get_usize(trace, "layers")?,
                get_usize(trace, "devices")?,
                get_usize(trace, "experts")?,
                get_usize(trace, "iters")?,
            ),
            trace_hash: get_str(trace, "hash")?.to_string(),
            fault_specs,
            iters,
        };
        if ck.iters.len() != ck.next_iter {
            return Err(format!(
                "checkpoint: next_iter {} but {} stored iterations",
                ck.next_iter,
                ck.iters.len()
            ));
        }
        Ok(ck)
    }

    /// Write `checkpoint.json` atomically (temp file + rename): a kill
    /// mid-save leaves the previous snapshot intact.
    pub fn save(&self, dir: &Path) -> Result<(), String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("checkpoint: cannot create {}: {e}", dir.display()))?;
        let tmp = dir.join("checkpoint.json.tmp");
        let path = Self::file(dir);
        std::fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| format!("checkpoint: cannot write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("checkpoint: cannot rename into {}: {e}", path.display()))
    }

    /// Load `checkpoint.json` from a checkpoint directory.
    pub fn load(dir: &Path) -> Result<Checkpoint, String> {
        let path = Self::file(dir);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("checkpoint: cannot read {}: {e}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Refuse to resume a run that is not the one this snapshot came
    /// from: policy, trace identity and fault timeline must all match.
    pub fn check_compatible(
        &self,
        policy: &str,
        trace: &Trace,
        fault_specs: &[String],
    ) -> Result<(), String> {
        if self.policy != policy {
            return Err(format!(
                "checkpoint was taken with policy `{}`, resuming with `{policy}`",
                self.policy
            ));
        }
        let shape = (trace.n_layers, trace.n_devices, trace.n_experts, trace.len());
        if self.trace_shape != shape || self.trace_hash != trace_hash(trace) {
            return Err(format!(
                "checkpoint was taken on a different trace \
                 (snapshot {:?}/{}, run {:?}/{})",
                self.trace_shape,
                self.trace_hash,
                shape,
                trace_hash(trace)
            ));
        }
        if self.fault_specs != fault_specs {
            return Err(format!(
                "checkpoint was taken with faults {:?}, resuming with {:?}",
                self.fault_specs, fault_specs
            ));
        }
        if self.next_iter > trace.len() {
            return Err(format!(
                "checkpoint is {} iterations in, trace has {}",
                self.next_iter,
                trace.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_iteration() -> IterationResult {
        let mut breakdown = BTreeMap::new();
        breakdown.insert("a2a", 0.1 + 0.2); // deliberately non-representable
        breakdown.insert("expert_comp", 1.0 / 3.0);
        IterationResult {
            time: 0.123_456_789_012_345_6,
            barrier_time: 0.2,
            breakdown,
            per_block_time: vec![0.1, 1.0 / 7.0],
            balance_before: 3.5,
            balance_after: 1.25,
            trans_copies: 42,
            forecast_error: Some(0.062_5),
            des_time: 0.111_111_111_111_111_1,
            devices: vec![
                DeviceStats {
                    busy_comp: 1.0 / 9.0,
                    busy_comm: 0.25,
                    exposed_comm: 0.125,
                    idle: 0.0,
                    finish: 0.123,
                },
                DeviceStats::default(),
            ],
            straggler: 1,
        }
    }

    #[test]
    fn iteration_json_round_trip_is_bit_exact() {
        let it = sample_iteration();
        let text = iteration_to_json(&it).to_string();
        let back = iteration_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.time.to_bits(), it.time.to_bits());
        assert_eq!(back.barrier_time.to_bits(), it.barrier_time.to_bits());
        assert_eq!(back.des_time.to_bits(), it.des_time.to_bits());
        assert_eq!(back.breakdown, it.breakdown);
        assert_eq!(back.per_block_time, it.per_block_time);
        assert_eq!(back.balance_before.to_bits(), it.balance_before.to_bits());
        assert_eq!(back.trans_copies, it.trans_copies);
        assert_eq!(back.forecast_error, it.forecast_error);
        assert_eq!(back.devices, it.devices);
        assert_eq!(back.straggler, it.straggler);
        // None forecast round-trips as null.
        let mut it2 = sample_iteration();
        it2.forecast_error = None;
        let text2 = iteration_to_json(&it2).to_string();
        let back2 = iteration_from_json(&json::parse(&text2).unwrap()).unwrap();
        assert_eq!(back2.forecast_error, None);
    }

    #[test]
    fn checkpoint_save_load_round_trip() {
        let trace = {
            let mut t = Trace::new(1, 4, 4);
            t.push(vec![crate::moe::LoadMatrix::from_rows(vec![
                vec![10, 20, 30, 40];
                4
            ])]);
            t.push(vec![crate::moe::LoadMatrix::from_rows(vec![
                vec![40, 30, 20, 10];
                4
            ])]);
            t
        };
        let specs = vec!["down dev=1 start=1".to_string()];
        let ck = Checkpoint::of("Pro-Prophet", &trace, specs.clone(), &[sample_iteration()]);
        let dir = std::env::temp_dir().join(format!(
            "pro_prophet_ckpt_test_{}",
            std::process::id()
        ));
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.policy, "Pro-Prophet");
        assert_eq!(back.next_iter, 1);
        assert_eq!(back.trace_shape, (1, 4, 4, 2));
        assert_eq!(back.trace_hash, trace_hash(&trace));
        assert_eq!(back.fault_specs, specs);
        assert_eq!(back.iters.len(), 1);
        assert_eq!(back.iters[0].time.to_bits(), sample_iteration().time.to_bits());

        // Compatibility gate: right run passes, wrong ones are named.
        back.check_compatible("Pro-Prophet", &trace, &specs).unwrap();
        let err = back.check_compatible("deepspeed", &trace, &specs).unwrap_err();
        assert!(err.contains("policy"), "{err}");
        let err = back.check_compatible("Pro-Prophet", &trace, &[]).unwrap_err();
        assert!(err.contains("faults"), "{err}");
        let mut other = Trace::new(1, 4, 4);
        other.push(vec![crate::moe::LoadMatrix::from_rows(vec![
            vec![1, 1, 1, 1];
            4
        ])]);
        let err = back.check_compatible("Pro-Prophet", &other, &specs).unwrap_err();
        assert!(err.contains("different trace"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_schema_and_keys_are_rejected() {
        let err = Checkpoint::from_json(&json::obj(vec![(
            "schema",
            json::s("pro-prophet-checkpoint/v999"),
        )]))
        .unwrap_err();
        assert!(err.contains("schema"), "{err}");
        let bad = r#"{"breakdown": {"warp_drive": 1.0}, "per_block_time": [],
                      "devices": [], "forecast_error": null, "time": 1.0,
                      "barrier_time": 1.0, "des_time": 1.0, "balance_before": 0.0,
                      "balance_after": 0.0, "trans_copies": 0, "straggler": 0}"#;
        let err = iteration_from_json(&json::parse(bad).unwrap()).unwrap_err();
        assert!(err.contains("warp_drive"), "{err}");
    }
}
