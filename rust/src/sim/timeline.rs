//! Timeline export: convert a [`Schedule`] (global two-stream barrier
//! model) or an executed [`OpDag`] (device-level event timeline) into
//! Chrome-trace JSON (chrome://tracing / Perfetto) so an iteration's
//! comm/comp overlap can be inspected visually — the repo's equivalent
//! of the paper's Fig 7/8 timelines.  The DAG export emits **one comp +
//! comm lane pair per device**, so stragglers and per-device exposed
//! communication are visible at a glance.

use crate::scheduler::{OpDag, Schedule, Stream};
use crate::sim::events::DesResult;
use crate::util::json::{self, Json};

/// One placed event on the two-stream timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    pub name: String,
    pub stream: Stream,
    pub start: f64,
    pub dur: f64,
}

/// Lay the schedule out on absolute time: stages run back to back, ops
/// within one stage serialize per stream starting at the stage boundary.
pub fn layout(schedule: &Schedule) -> Vec<TimelineEvent> {
    let mut events = Vec::new();
    let mut t = 0.0;
    for stage in &schedule.stages {
        let mut tc = t;
        for op in &stage.comp {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comp,
                start: tc,
                dur: op.dur,
            });
            tc += op.dur;
        }
        let mut tm = t;
        for op in &stage.comm {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comm,
                start: tm,
                dur: op.dur,
            });
            tm += op.dur;
        }
        t += stage.time();
    }
    events
}

/// Chrome-trace JSON ("traceEvents" array of X events, µs timebase).
pub fn to_chrome_trace(schedule: &Schedule) -> Json {
    let events: Vec<Json> = layout(schedule)
        .into_iter()
        .map(|e| {
            json::obj(vec![
                ("name", json::s(&e.name)),
                ("ph", json::s("X")),
                ("ts", json::num(e.start * 1e6)),
                ("dur", json::num((e.dur * 1e6).max(0.01))),
                ("pid", json::num(1.0)),
                (
                    "tid",
                    json::num(match e.stream {
                        Stream::Comp => 1.0,
                        Stream::Comm => 2.0,
                    }),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write the trace next to other results.
pub fn save_chrome_trace(schedule: &Schedule, name: &str) -> std::io::Result<std::path::PathBuf> {
    crate::metrics::write_result(name, &to_chrome_trace(schedule))
}

/// Thread id of device `dev`'s lane (comp and comm interleave so a
/// device's pair sorts together in the viewer).
fn des_tid(dev: usize, stream: Stream) -> f64 {
    (2 * dev
        + match stream {
            Stream::Comp => 1,
            Stream::Comm => 2,
        }) as f64
}

/// Chrome-trace JSON of an executed device-level DAG: one comp + comm
/// lane pair per device (named via thread_name metadata), ops placed at
/// their simulated start times.
pub fn to_chrome_trace_des(dag: &OpDag, des: &DesResult) -> Json {
    let mut events: Vec<Json> = Vec::new();
    // Lane names: "dev3 comp" / "dev3 comm".
    for dev in 0..dag.n_devices {
        for (stream, label) in [(Stream::Comp, "comp"), (Stream::Comm, "comm")] {
            events.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::num(1.0)),
                ("tid", json::num(des_tid(dev, stream))),
                (
                    "args",
                    json::obj(vec![("name", json::s(&format!("dev{dev} {label}")))]),
                ),
            ]));
        }
    }
    for (i, node) in dag.nodes().iter().enumerate() {
        for dev in 0..dag.n_devices {
            if node.dur[dev] <= 0.0 {
                continue;
            }
            events.push(json::obj(vec![
                ("name", json::s(&format!("{:?}", node.op))),
                ("ph", json::s("X")),
                ("ts", json::num(des.start[i][dev] * 1e6)),
                ("dur", json::num((node.dur[dev] * 1e6).max(0.01))),
                ("pid", json::num(1.0)),
                ("tid", json::num(des_tid(dev, node.op.stream()))),
            ]));
        }
    }
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write an executed DAG's per-device trace next to other results.
pub fn save_chrome_trace_des(
    dag: &OpDag,
    des: &DesResult,
    name: &str,
) -> std::io::Result<std::path::PathBuf> {
    crate::metrics::write_result(name, &to_chrome_trace_des(dag, des))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Op, OpInstance, Stage};

    fn sched() -> Schedule {
        Schedule {
            stages: vec![
                Stage::pair(
                    vec![OpInstance::new(Op::Fec { block: 0 }, 2.0)],
                    vec![OpInstance::new(Op::Trans { block: 1, part: 0 }, 1.0)],
                ),
                Stage::comm_only(vec![OpInstance::new(
                    Op::A2a { block: 0, phase: crate::scheduler::A2aPhase::FwdCombine },
                    0.5,
                )]),
            ],
        }
    }

    #[test]
    fn layout_places_streams_in_parallel() {
        let evs = layout(&sched());
        assert_eq!(evs.len(), 3);
        // FEC and Trans start together.
        assert_eq!(evs[0].start, 0.0);
        assert_eq!(evs[1].start, 0.0);
        assert_eq!(evs[0].stream, Stream::Comp);
        assert_eq!(evs[1].stream, Stream::Comm);
        // A2A starts after the stage barrier at max(2.0, 1.0).
        assert_eq!(evs[2].start, 2.0);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let j = to_chrome_trace(&sched());
        let text = j.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
    }

    #[test]
    fn des_trace_has_one_lane_pair_per_device() {
        use crate::scheduler::dag::from_schedule;
        use crate::sim::events;
        let s = sched();
        let d = 3;
        let dag = from_schedule(&s, d);
        let des = events::execute(&dag);
        let j = to_chrome_trace_des(&dag, &des);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2*d thread_name metadata events + one X event per (op, device).
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .count();
        assert_eq!(metas, 2 * d);
        let xs: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 3 * d, "3 ops on {d} devices");
        // Distinct tids span every device lane that has an op.
        let tids: std::collections::BTreeSet<i64> = xs
            .iter()
            .map(|e| e.get("tid").unwrap().as_f64().unwrap() as i64)
            .collect();
        assert!(tids.len() >= d, "per-device lanes missing: {tids:?}");
    }

    #[test]
    fn layout_total_matches_schedule() {
        let s = sched();
        let evs = layout(&s);
        let end = evs
            .iter()
            .map(|e| e.start + e.dur)
            .fold(0.0f64, f64::max);
        assert!((end - s.total_time()).abs() < 1e-12);
    }
}
