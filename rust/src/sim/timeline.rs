//! Timeline export: convert a [`Schedule`] (global two-stream barrier
//! model) or an executed [`OpDag`] (device-level event timeline) into
//! Chrome-trace JSON (chrome://tracing / Perfetto) so an iteration's
//! comm/comp overlap can be inspected visually — the repo's equivalent
//! of the paper's Fig 7/8 timelines.  The DAG export emits **one comp +
//! comm lane pair per device**, so stragglers and per-device exposed
//! communication are visible at a glance.

use crate::scheduler::{OpDag, Schedule, Stream};
use crate::sim::events::DesResult;
use crate::util::json::{self, Json};

/// One placed event on the two-stream timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    pub name: String,
    pub stream: Stream,
    pub start: f64,
    pub dur: f64,
}

/// Lay the schedule out on absolute time: stages run back to back, ops
/// within one stage serialize per stream starting at the stage boundary.
pub fn layout(schedule: &Schedule) -> Vec<TimelineEvent> {
    let mut events = Vec::new();
    let mut t = 0.0;
    for stage in &schedule.stages {
        let mut tc = t;
        for op in &stage.comp {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comp,
                start: tc,
                dur: op.dur,
            });
            tc += op.dur;
        }
        let mut tm = t;
        for op in &stage.comm {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comm,
                start: tm,
                dur: op.dur,
            });
            tm += op.dur;
        }
        t += stage.time();
    }
    events
}

/// Chrome-trace JSON ("traceEvents" array of X events, µs timebase).
pub fn to_chrome_trace(schedule: &Schedule) -> Json {
    let events: Vec<Json> = layout(schedule)
        .into_iter()
        .map(|e| {
            json::obj(vec![
                ("name", json::s(&e.name)),
                ("ph", json::s("X")),
                ("ts", json::num(e.start * 1e6)),
                ("dur", json::num((e.dur * 1e6).max(0.01))),
                ("pid", json::num(1.0)),
                (
                    "tid",
                    json::num(match e.stream {
                        Stream::Comp => 1.0,
                        Stream::Comm => 2.0,
                    }),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write the trace next to other results.
pub fn save_chrome_trace(schedule: &Schedule, name: &str) -> std::io::Result<std::path::PathBuf> {
    crate::metrics::write_result(name, &to_chrome_trace(schedule))
}

/// Thread id of device `dev`'s lane (comp and comm interleave so a
/// device's pair sorts together in the viewer).
fn des_tid(dev: usize, stream: Stream) -> f64 {
    (2 * dev
        + match stream {
            Stream::Comp => 1,
            Stream::Comm => 2,
        }) as f64
}

/// Chrome-trace JSON of an executed device-level DAG: one comp + comm
/// lane pair per device (named via thread_name metadata), ops placed at
/// their simulated start times.
pub fn to_chrome_trace_des(dag: &OpDag, des: &DesResult) -> Json {
    to_chrome_trace_des_bounded(dag, des, None, None).0
}

/// Per-iteration scalars rendered as Chrome counter tracks ("C" events)
/// alongside the per-device lanes, so one trace file carries both the
/// timeline and the balance story.
#[derive(Clone, Debug)]
pub struct CounterTracks {
    /// Balance degree before placement (plotted at t = 0).
    pub balance_before: f64,
    /// Balance degree after placement (plotted at the makespan).
    pub balance_after: f64,
    /// Critical-path device id.
    pub straggler: usize,
    /// Per-device exposed communication seconds.
    pub exposed_comm: Vec<f64>,
}

/// What a bounded DES export kept (metadata and counter events are
/// never capped — only the per-(op, device) X events are).
#[derive(Clone, Copy, Debug, Default)]
pub struct DesTraceStats {
    /// X events the DAG would emit uncapped.
    pub total_ops: usize,
    pub emitted_ops: usize,
    pub dropped_ops: usize,
}

/// [`to_chrome_trace_des`] with optional counter tracks and an op-event
/// cap.  Dropped events are *counted*, never silent: callers print
/// [`DesTraceStats`] when `dropped_ops > 0`.
pub fn to_chrome_trace_des_bounded(
    dag: &OpDag,
    des: &DesResult,
    counters: Option<&CounterTracks>,
    max_events: Option<usize>,
) -> (Json, DesTraceStats) {
    to_chrome_trace_des_bounded_with_instants(dag, des, counters, &[], max_events)
}

/// [`to_chrome_trace_des_bounded`] plus global instant events ("i"
/// phase): (label, seconds) markers rendered as vertical lines across
/// every lane — used for active fault-timeline events, so a slowed or
/// downed device is annotated right on the timeline it distorts.
/// Instants are never capped (like metadata and counters).
pub fn to_chrome_trace_des_bounded_with_instants(
    dag: &OpDag,
    des: &DesResult,
    counters: Option<&CounterTracks>,
    instants: &[(String, f64)],
    max_events: Option<usize>,
) -> (Json, DesTraceStats) {
    let cap = max_events.unwrap_or(usize::MAX);
    let mut stats = DesTraceStats::default();
    let mut events: Vec<Json> = Vec::new();
    // Lane names: "dev3 comp" / "dev3 comm".
    for dev in 0..dag.n_devices {
        for (stream, label) in [(Stream::Comp, "comp"), (Stream::Comm, "comm")] {
            events.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::num(1.0)),
                ("tid", json::num(des_tid(dev, stream))),
                (
                    "args",
                    json::obj(vec![("name", json::s(&format!("dev{dev} {label}")))]),
                ),
            ]));
        }
    }
    // Straight over the SoA arena: one duration row per node, no
    // per-node or per-device temporaries.
    for i in 0..dag.len() {
        let op = dag.op(i);
        for (dev, &dur) in dag.dur(i).iter().enumerate() {
            if dur <= 0.0 {
                continue;
            }
            stats.total_ops += 1;
            if stats.emitted_ops >= cap {
                continue;
            }
            stats.emitted_ops += 1;
            events.push(json::obj(vec![
                ("name", json::s(&format!("{op:?}"))),
                ("ph", json::s("X")),
                ("ts", json::num(des.start(i, dev) * 1e6)),
                ("dur", json::num((dur * 1e6).max(0.01))),
                ("pid", json::num(1.0)),
                ("tid", json::num(des_tid(dev, op.stream()))),
            ]));
        }
    }
    stats.dropped_ops = stats.total_ops - stats.emitted_ops;
    if let Some(c) = counters {
        let end_us = des.makespan * 1e6;
        for (ts, value) in [(0.0, c.balance_before), (end_us, c.balance_after)] {
            events.push(json::obj(vec![
                ("name", json::s("balance_degree")),
                ("ph", json::s("C")),
                ("pid", json::num(1.0)),
                ("ts", json::num(ts)),
                ("args", json::obj(vec![("balance", json::num(value))])),
            ]));
        }
        events.push(json::obj(vec![
            ("name", json::s("straggler")),
            ("ph", json::s("C")),
            ("pid", json::num(1.0)),
            ("ts", json::num(0.0)),
            ("args", json::obj(vec![("device", json::num(c.straggler as f64))])),
        ]));
        let devs: std::collections::BTreeMap<String, Json> = c
            .exposed_comm
            .iter()
            .enumerate()
            .map(|(d, &v)| (format!("dev{d}"), json::num(v)))
            .collect();
        events.push(json::obj(vec![
            ("name", json::s("exposed_comm_s")),
            ("ph", json::s("C")),
            ("pid", json::num(1.0)),
            ("ts", json::num(end_us)),
            ("args", Json::Obj(devs)),
        ]));
    }
    for (label, ts) in instants {
        events.push(json::obj(vec![
            ("name", json::s(label)),
            ("ph", json::s("i")),
            ("s", json::s("g")),
            ("pid", json::num(1.0)),
            ("tid", json::num(0.0)),
            ("ts", json::num(ts * 1e6)),
        ]));
    }
    (
        json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", json::s("ms")),
        ]),
        stats,
    )
}

/// Write an executed DAG's per-device trace next to other results.
pub fn save_chrome_trace_des(
    dag: &OpDag,
    des: &DesResult,
    name: &str,
) -> std::io::Result<std::path::PathBuf> {
    crate::metrics::write_result(name, &to_chrome_trace_des(dag, des))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Op, OpInstance, Stage};

    fn sched() -> Schedule {
        Schedule {
            stages: vec![
                Stage::pair(
                    vec![OpInstance::new(Op::Fec { block: 0 }, 2.0)],
                    vec![OpInstance::new(Op::Trans { block: 1, part: 0 }, 1.0)],
                ),
                Stage::comm_only(vec![OpInstance::new(
                    Op::A2a { block: 0, phase: crate::scheduler::A2aPhase::FwdCombine },
                    0.5,
                )]),
            ],
        }
    }

    #[test]
    fn layout_places_streams_in_parallel() {
        let evs = layout(&sched());
        assert_eq!(evs.len(), 3);
        // FEC and Trans start together.
        assert_eq!(evs[0].start, 0.0);
        assert_eq!(evs[1].start, 0.0);
        assert_eq!(evs[0].stream, Stream::Comp);
        assert_eq!(evs[1].stream, Stream::Comm);
        // A2A starts after the stage barrier at max(2.0, 1.0).
        assert_eq!(evs[2].start, 2.0);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let j = to_chrome_trace(&sched());
        let text = j.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
    }

    #[test]
    fn des_trace_has_one_lane_pair_per_device() {
        use crate::scheduler::dag::from_schedule;
        use crate::sim::events;
        let s = sched();
        let d = 3;
        let dag = from_schedule(&s, d);
        let des = events::execute(&dag);
        let j = to_chrome_trace_des(&dag, &des);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2*d thread_name metadata events + one X event per (op, device).
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .count();
        assert_eq!(metas, 2 * d);
        let xs: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 3 * d, "3 ops on {d} devices");
        // Distinct tids span every device lane that has an op.
        let tids: std::collections::BTreeSet<i64> = xs
            .iter()
            .map(|e| e.get("tid").unwrap().as_f64().unwrap() as i64)
            .collect();
        assert!(tids.len() >= d, "per-device lanes missing: {tids:?}");
    }

    #[test]
    fn des_trace_counter_tracks_and_cap() {
        use crate::scheduler::dag::from_schedule;
        use crate::sim::events;
        let s = sched();
        let d = 3;
        let dag = from_schedule(&s, d);
        let des = events::execute(&dag);
        let tracks = CounterTracks {
            balance_before: 0.4,
            balance_after: 0.9,
            straggler: 2,
            exposed_comm: vec![0.1, 0.2, 0.3],
        };
        let (j, stats) = to_chrome_trace_des_bounded(&dag, &des, Some(&tracks), None);
        assert_eq!(stats.total_ops, 3 * d);
        assert_eq!(stats.emitted_ops, 3 * d);
        assert_eq!(stats.dropped_ops, 0);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let cs: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("C"))
            .collect();
        // 2 balance_degree samples + straggler + exposed_comm_s.
        assert_eq!(cs.len(), 4);
        let names: std::collections::BTreeSet<&str> =
            cs.iter().filter_map(|e| e.get("name").unwrap().as_str()).collect();
        assert!(names.contains("balance_degree"));
        assert!(names.contains("straggler"));
        assert!(names.contains("exposed_comm_s"));
        let exposed = cs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("exposed_comm_s"))
            .unwrap();
        let args = exposed.get("args").unwrap();
        assert_eq!(args.get("dev2").unwrap().as_f64(), Some(0.3));

        // Cap at 4 X events: metadata and counters survive, ops drop.
        let (jc, capped) = to_chrome_trace_des_bounded(&dag, &des, Some(&tracks), Some(4));
        assert_eq!(capped.total_ops, 3 * d);
        assert_eq!(capped.emitted_ops, 4);
        assert_eq!(capped.dropped_ops, 3 * d - 4);
        let parsed = crate::util::json::parse(&jc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let xs = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .count();
        assert_eq!(xs, 4);
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .count();
        assert_eq!(metas, 2 * d);
    }

    #[test]
    fn des_trace_instant_events_mark_faults() {
        use crate::scheduler::dag::from_schedule;
        use crate::sim::events;
        let dag = from_schedule(&sched(), 2);
        let des = events::execute(&dag);
        let instants = vec![
            ("fault: down dev=1".to_string(), 0.0),
            ("fault: transient dev=0 factor=2 start=1 dur=2".to_string(), 0.5),
        ];
        // A tiny op cap must not touch instants (only X events).
        let (j, _) =
            to_chrome_trace_des_bounded_with_instants(&dag, &des, None, &instants, Some(1));
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let is: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("i"))
            .collect();
        assert_eq!(is.len(), 2);
        assert_eq!(is[0].get("name").unwrap().as_str(), Some("fault: down dev=1"));
        assert_eq!(is[1].get("ts").unwrap().as_f64(), Some(0.5e6));
        // The plain bounded export emits none.
        let (j, _) = to_chrome_trace_des_bounded(&dag, &des, None, None);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert!(parsed
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .all(|e| e.get("ph").unwrap().as_str() != Some("i")));
    }

    #[test]
    fn layout_total_matches_schedule() {
        let s = sched();
        let evs = layout(&s);
        let end = evs
            .iter()
            .map(|e| e.start + e.dur)
            .fold(0.0f64, f64::max);
        assert!((end - s.total_time()).abs() < 1e-12);
    }
}
