//! Timeline export: convert a [`Schedule`] into Chrome-trace JSON
//! (chrome://tracing / Perfetto) so an iteration's comm/comp overlap can
//! be inspected visually — the repo's equivalent of the paper's Fig 7/8
//! timelines.

use crate::scheduler::{Schedule, Stream};
use crate::util::json::{self, Json};

/// One placed event on the two-stream timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    pub name: String,
    pub stream: Stream,
    pub start: f64,
    pub dur: f64,
}

/// Lay the schedule out on absolute time: stages run back to back, ops
/// within one stage serialize per stream starting at the stage boundary.
pub fn layout(schedule: &Schedule) -> Vec<TimelineEvent> {
    let mut events = Vec::new();
    let mut t = 0.0;
    for stage in &schedule.stages {
        let mut tc = t;
        for op in &stage.comp {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comp,
                start: tc,
                dur: op.dur,
            });
            tc += op.dur;
        }
        let mut tm = t;
        for op in &stage.comm {
            events.push(TimelineEvent {
                name: format!("{:?}", op.op),
                stream: Stream::Comm,
                start: tm,
                dur: op.dur,
            });
            tm += op.dur;
        }
        t += stage.time();
    }
    events
}

/// Chrome-trace JSON ("traceEvents" array of X events, µs timebase).
pub fn to_chrome_trace(schedule: &Schedule) -> Json {
    let events: Vec<Json> = layout(schedule)
        .into_iter()
        .map(|e| {
            json::obj(vec![
                ("name", json::s(&e.name)),
                ("ph", json::s("X")),
                ("ts", json::num(e.start * 1e6)),
                ("dur", json::num((e.dur * 1e6).max(0.01))),
                ("pid", json::num(1.0)),
                (
                    "tid",
                    json::num(match e.stream {
                        Stream::Comp => 1.0,
                        Stream::Comm => 2.0,
                    }),
                ),
            ])
        })
        .collect();
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write the trace next to other results.
pub fn save_chrome_trace(schedule: &Schedule, name: &str) -> std::io::Result<std::path::PathBuf> {
    crate::metrics::write_result(name, &to_chrome_trace(schedule))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{Op, OpInstance, Stage};

    fn sched() -> Schedule {
        Schedule {
            stages: vec![
                Stage::pair(
                    vec![OpInstance::new(Op::Fec { block: 0 }, 2.0)],
                    vec![OpInstance::new(Op::Trans { block: 1, part: 0 }, 1.0)],
                ),
                Stage::comm_only(vec![OpInstance::new(
                    Op::A2a { block: 0, phase: crate::scheduler::A2aPhase::FwdCombine },
                    0.5,
                )]),
            ],
        }
    }

    #[test]
    fn layout_places_streams_in_parallel() {
        let evs = layout(&sched());
        assert_eq!(evs.len(), 3);
        // FEC and Trans start together.
        assert_eq!(evs[0].start, 0.0);
        assert_eq!(evs[1].start, 0.0);
        assert_eq!(evs[0].stream, Stream::Comp);
        assert_eq!(evs[1].stream, Stream::Comm);
        // A2A starts after the stage barrier at max(2.0, 1.0).
        assert_eq!(evs[2].start, 2.0);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let j = to_chrome_trace(&sched());
        let text = j.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
    }

    #[test]
    fn layout_total_matches_schedule() {
        let s = sched();
        let evs = layout(&s);
        let end = evs
            .iter()
            .map(|e| e.start + e.dur)
            .fold(0.0f64, f64::max);
        assert!((end - s.total_time()).abs() < 1e-12);
    }
}
